"""Cooperative peer-cache tier over the threaded runtime, 3 nodes.

Each "node" is a (cache, PeerStore, loader) triple sharing one simulated
GCS bucket and one ``PeerCacheRegistry``.  Epoch 1 fills every node's cache
with its partition; epoch 2 re-randomizes partitions (PyTorch
DistributedSampler semantics), so ~2/3 of each node's new partition lives
in a *peer's* cache — without the tier those are all Class B bucket GETs.

    PYTHONPATH=src python examples/peer_cache_demo.py
"""
from repro.core import (
    CachingDataset,
    CappedCache,
    DeliLoader,
    DistributedPartitionSampler,
    PrefetchConfig,
    RealClock,
    SimulatedBucketStore,
    make_synthetic_payloads,
)
from repro.distributed import PeerCacheRegistry, PeerStore

N_SAMPLES = 1536
N_NODES = 3
BATCH = 64
CLOCK = RealClock(scale=2e-4)  # modelled I/O shrunk 5000x, ratios preserved


def make_node(rank, payloads, registry):
    bucket = SimulatedBucketStore(payloads, clock=CLOCK)
    cache = CappedCache()  # unlimited, the paper's best case
    registry.register(rank, cache)
    store = PeerStore(bucket, registry, node=rank, clock=CLOCK)
    dataset = CachingDataset(store, cache, insert_on_miss=True)
    sampler = DistributedPartitionSampler(N_SAMPLES, rank, N_NODES, seed=0)
    loader = DeliLoader(
        dataset, sampler, BATCH, PrefetchConfig.disabled(), clock=CLOCK, node=rank
    )
    return loader, store


def main():
    payloads = make_synthetic_payloads(N_SAMPLES, sample_bytes=784)
    registry = PeerCacheRegistry()
    nodes = [make_node(rank, payloads, registry) for rank in range(N_NODES)]
    for epoch in range(2):
        for rank, (loader, _) in enumerate(nodes):
            loader.set_epoch(epoch)
            for _ in loader:
                pass
            s = loader.last_epoch_stats
            print(
                f"epoch {epoch} node {rank}: miss {s.miss_rate:.1%} | "
                f"peer hits {s.peer_hits}/{s.misses} misses | "
                f"data-wait {s.data_wait_seconds:.3f}s"
            )
    class_b = sum(store.inner.stats.class_b_requests for _, store in nodes)
    peer_hits = sum(store.peer_hits for _, store in nodes)
    print(
        f"\ncluster: {class_b} Class B bucket GETs, {peer_hits} reads served "
        f"by peers (each one a Class B request avoided)"
    )


if __name__ == "__main__":
    main()
