"""Cooperative peer-cache tier over the threaded runtime, 3 nodes —
declared as one ``DataPlaneSpec`` instead of hand-wiring (cache, PeerStore,
loader) triples.

Epoch 1 fills every node's cache with its partition; epoch 2 re-randomizes
partitions (PyTorch DistributedSampler semantics), so ~2/3 of each node's
new partition lives in a *peer's* cache — without the tier those are all
Class B bucket GETs.  The per-tier breakdown comes straight from the
``EpochStats`` tier counters the ReadTier stack maintains.

    PYTHONPATH=src python examples/peer_cache_demo.py
"""
from repro.core import RealClock, aggregate_tier_hits
from repro.core.workloads import WorkloadSpec
from repro.pipeline import DataPlaneSpec

WORKLOAD = WorkloadSpec(
    name="peer-demo",
    n_samples=1536,
    sample_bytes=784,
    batch_size=64,
    compute_per_epoch_s=0.0,
    n_nodes=3,
)

SPEC = DataPlaneSpec(
    workload=WORKLOAD,
    cache_items=-1,  # unlimited, the paper's best case
    peer_cache=True,
)


def main():
    clock = RealClock(scale=2e-4)  # modelled I/O shrunk 5000x, ratios preserved
    with SPEC.build_runtime(clock=clock) as cluster:
        stats, store = cluster.run(epochs=2)
    for s in stats:
        print(
            f"epoch {s.epoch} node {s.node}: miss {s.miss_rate:.1%} | "
            f"peer hits {s.peer_hits}/{s.misses} misses | "
            f"data-wait {s.data_wait_seconds:.3f}s"
        )
    tiers = aggregate_tier_hits(stats)
    print(
        f"\ncluster: {store.class_b_requests} Class B bucket GETs, "
        f"{tiers.get('peer', 0)} reads served by peers (each one a Class B "
        f"request avoided) | tier breakdown {dict(sorted(tiers.items()))}"
    )


if __name__ == "__main__":
    main()
