"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with ALL training data served through the DELI pipeline (simulated cloud
bucket + capped cache + async pre-fetch, 50/50 policy), with step-atomic
checkpointing.  The loss must fall and the data plane must report near-zero
wait once the pre-fetcher is warm.

    PYTHONPATH=src python examples/train_lm_e2e.py [--steps 300]

The model is a 12-layer / d=768 GQA transformer (~103M params with its
8k vocab) — trained in float32 on CPU.
"""
import argparse
import tempfile

from repro.core import PrefetchConfig
from repro.data import decode_tokens, make_lm_pipeline
from repro.models.config import ArchConfig
from repro.training.loop import Trainer, TrainerConfig
from repro.training.optimizer import OptSettings

SEQ = 256
CACHE = 512


def make_model() -> ArchConfig:
    return ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=8192, dtype="float32", attn_chunk=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = make_model()
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")
    loader, service, _ = make_lm_pipeline(
        n_samples=8192, seq_len=SEQ, vocab=cfg.vocab, batch_size=args.batch,
        cache_items=CACHE, policy=PrefetchConfig.fifty_fifty(CACHE),
    )
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="deli_ckpt_")
    trainer = Trainer(
        cfg,
        loader,
        TrainerConfig(
            seq_len=SEQ, batch_size=args.batch, checkpoint_dir=ckpt_dir,
            checkpoint_every=100, log_every=20,
        ),
        decode_fn=decode_tokens,
        settings=OptSettings(lr=3e-4, moment_dtype="float32"),
    )
    with service:
        metrics = trainer.train(args.steps)
    first = sum(m.loss for m in metrics[:20]) / 20
    last = sum(m.loss for m in metrics[-20:]) / 20
    wait = sum(m.data_wait_s for m in metrics)
    comp = sum(m.compute_s for m in metrics)
    print(
        f"\nloss {first:.3f} -> {last:.3f} over {len(metrics)} steps | "
        f"total data-wait {wait:.2f}s vs compute {comp:.1f}s "
        f"({wait/(wait+comp):.1%} of step time) | checkpoints in {ckpt_dir}"
    )
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
