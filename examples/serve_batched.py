"""Batched serving: load a reduced hybrid (Mamba+attention+MoE) model and
serve a batch of prompts — batched prefill, then per-token decode steps
against the KV/SSM cache.  This is the small-scale twin of the decode_32k
dry-run cells.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses

import jax

from repro import configs
from repro.models import model as M
from repro.serving import ServeEngine


def main():
    cfg = configs.reduce_for_smoke(configs.get("jamba-1.5-large-398b"))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_len=96)

    B, L = 4, 32
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab)
    result = engine.generate([list(map(int, p)) for p in prompts], max_new_tokens=16)
    for i, toks in enumerate(result.tokens):
        print(f"seq {i}: +{len(toks)} tokens: {toks}")
    tps = result.total_new_tokens / max(result.decode_s, 1e-9)
    print(
        f"prefill {result.prefill_s*1e3:.0f}ms | decode {result.decode_s*1e3:.0f}ms "
        f"({tps:.1f} tok/s on CPU, batch {B})"
    )


if __name__ == "__main__":
    main()
