"""Quickstart: the DELI data plane in ~40 lines, declaratively.

One ``DataPlaneSpec`` describes the paper's node pipeline (simulated GCS
bucket -> capped cache -> async pre-fetch service -> loader) with the 50/50
policy; ``build_runtime()`` assembles it, we run two epochs and print the
paper's two metrics: per-epoch data-wait and miss rate (plus the per-tier
read breakdown the tier stack attributes).

    PYTHONPATH=src python examples/quickstart.py

Migration table — old manual wiring -> the declarative spec:

    old (hand-assembled)                      new (DataPlaneSpec)
    ----------------------------------------  -------------------------------
    SimulatedBucketStore(payloads, model,     spec = DataPlaneSpec(workload=,
        clock=...)                                bucket=model,
    CappedCache(max_items=N)                      cache_items=N,
    PrefetchConfig.fifty_fifty(N)                 prefetch=PrefetchConfig
    PrefetchService(store, cache, ...)                .fifty_fifty(N),
    CachingDataset(store, cache,                  payload_factory=...)
        insert_on_miss=...)
    DistributedPartitionSampler(n, r, w)      cluster = spec.build_runtime()
    DeliLoader(dataset, sampler, batch,       loader = cluster.loaders[rank]
        cfg, service, clock)
    # simulator: SimConfig(...) +             stats, store = spec.build_sim()
    #   simulate_cluster(spec, cfg)               .run(epochs=2)
    # peer tier: PeerCacheRegistry +          DataPlaneSpec(peer_cache=True)
    #   PeerStore(bucket, reg, node)
    # named conditions:                       pipeline.condition("cache+peer",
    #   (hand-rolled per benchmark)               workload, cache_items=512)

The old constructors still work (they are thin shims over the tier stack);
new code should declare a spec.  The same table lives in
``pydoc repro.pipeline``; start with README.md and docs/ARCHITECTURE.md
for the layer map, and docs/PARITY.md for the exact sim/runtime agreement
story (``spec.build_runtime()`` with no clock is the lock-step projection).
"""
from repro.core import BucketModel, PrefetchConfig, RealClock
from repro.core.workloads import WorkloadSpec
from repro.data import decode_tokens, make_lm_payloads
from repro.pipeline import DataPlaneSpec

CACHE = 512  # samples resident per node at a time (a fraction of the data)
SEQ_LEN, VOCAB = 128, 1024

WORKLOAD = WorkloadSpec(
    name="lm-quickstart",
    n_samples=4096,
    sample_bytes=(SEQ_LEN + 1) * 4,  # int32 tokens, inputs + shifted labels
    batch_size=64,
    compute_per_epoch_s=0.0,
    n_nodes=1,
)

SPEC = DataPlaneSpec(
    workload=WORKLOAD,
    cache_items=CACHE,
    prefetch=PrefetchConfig.fifty_fifty(CACHE),  # the paper's best config
    # fast-forwarded bucket: Table-I ratios at ~1/1000 wall time
    bucket=BucketModel(
        request_latency_s=0.020e-3, per_connection_bw=20e9, listing_latency_s=0.050e-3
    ),
    payload_factory=lambda spec: make_lm_payloads(
        spec.workload.n_samples, SEQ_LEN, VOCAB
    ),
)


def main():
    with SPEC.build_runtime(clock=RealClock()) as cluster:
        loader = cluster.loaders[0]
        for epoch in range(2):
            loader.set_epoch(epoch)
            n_tokens = 0
            for batch in loader:
                n_tokens += sum(decode_tokens(p).size for p in batch.payloads)
            s = loader.last_epoch_stats
            tiers = dict(sorted(s.tier_hits.items()))
            print(
                f"epoch {epoch}: {s.samples} samples, {n_tokens} tokens | "
                f"data-wait {s.data_wait_seconds:.3f}s | "
                f"miss rate {s.miss_rate:.1%} | tiers {tiers}"
            )
        print("bucket requests:", cluster.store_stats())


if __name__ == "__main__":
    main()
