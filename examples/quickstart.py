"""Quickstart: the DELI data plane in ~40 lines.

Builds the paper's node pipeline (simulated GCS bucket -> capped cache ->
async pre-fetch service -> loader) with the 50/50 policy, runs two epochs,
and prints the paper's two metrics: per-epoch data-wait and miss rate.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import PrefetchConfig
from repro.data import decode_tokens, make_lm_pipeline

CACHE = 512  # samples resident per node at a time (a fraction of the data)


def main():
    loader, service, dataset = make_lm_pipeline(
        n_samples=4096,
        seq_len=128,
        vocab=1024,
        batch_size=64,
        cache_items=CACHE,
        policy=PrefetchConfig.fifty_fifty(CACHE),  # the paper's best config
    )
    with service:  # starts the async pre-fetch worker
        for epoch in range(2):
            loader.set_epoch(epoch)
            n_tokens = 0
            for batch in loader:
                n_tokens += sum(decode_tokens(p).size for p in batch.payloads)
            s = loader.last_epoch_stats
            print(
                f"epoch {epoch}: {s.samples} samples, {n_tokens} tokens | "
                f"data-wait {s.data_wait_seconds:.3f}s | "
                f"miss rate {s.miss_rate:.1%} (hits {s.hits}, misses {s.misses})"
            )
    print("bucket requests:", dataset.store.stats)


if __name__ == "__main__":
    main()
