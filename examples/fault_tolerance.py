"""Fault tolerance demo: preempt a training run mid-epoch, restart from the
latest step-atomic checkpoint (params + optimizer + data-plane cursor), and
elastically re-partition when the world size changes.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import tempfile

from repro.core import PrefetchConfig
from repro.data import decode_tokens, make_lm_pipeline
from repro.models.config import ArchConfig
from repro.training.loop import Trainer, TrainerConfig, elastic_repartition
from repro.training.optimizer import OptSettings

SEQ, CACHE, BATCH = 128, 256, 8
CFG = ArchConfig(
    name="lm-tiny", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab=2048, dtype="float32", attn_chunk=128,
)


def make_trainer(ckpt_dir, rank=0, world=1):
    loader, service, _ = make_lm_pipeline(
        n_samples=2048, seq_len=SEQ, vocab=CFG.vocab, batch_size=BATCH,
        cache_items=CACHE, policy=PrefetchConfig.fifty_fifty(CACHE),
        rank=rank, world=world,
    )
    t = Trainer(
        CFG, loader,
        TrainerConfig(seq_len=SEQ, batch_size=BATCH, checkpoint_dir=ckpt_dir,
                      checkpoint_every=10, log_every=50),
        decode_fn=decode_tokens,
        settings=OptSettings(lr=1e-3, moment_dtype="float32"),
    )
    return t, service


def main():
    ckpt = tempfile.mkdtemp(prefix="deli_ft_")

    # --- run 1: train 25 steps, then 'die' (process exits mid-epoch) --------
    t1, svc1 = make_trainer(ckpt)
    with svc1:
        t1.train(25)
    print(f"run 1 stopped at step {t1.step} (simulated preemption)")

    # --- run 2: a fresh process restores params+opt+loader cursor -----------
    t2, svc2 = make_trainer(ckpt)
    restored = t2.try_restore()
    print(f"run 2 restored={restored} at step {t2.step} "
          f"(loader cursor {t2.loader.state_dict()})")
    assert restored and t2.step >= 20  # latest checkpoint at step 20
    with svc2:
        t2.train(15)
    print(f"run 2 advanced to step {t2.step}")

    # --- elastic: the cluster shrinks to world=2, this node becomes rank 0 --
    elastic_repartition(t2.loader, new_rank=0, new_world=2)
    t3_partition = len(t2.loader.sampler)
    print(f"elastic re-partition: node now owns {t3_partition} samples "
          f"(was {2048})")
    assert t3_partition == 1024
    with svc2:
        pass  # service already closed by the with-block above; re-use pattern
    print("OK: preempt -> restore -> elastic resize all succeeded")


if __name__ == "__main__":
    main()
