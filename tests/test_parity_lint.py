"""Negative fixtures for the parity linter (src/repro/analysis/).

Each rule is demonstrated to FIRE on a deliberately-broken snippet with
the right rule id, location, and hint — the acceptance criterion for
ISSUE 9 — plus the positive twin: the same snippet, repaired, passes.
Fixtures are synthetic sources checked under fake sim-domain/test paths;
nothing here touches the real tree (tests/test_tools.py holds the
repo-level gate checks).
"""
import pathlib
import textwrap

from repro.analysis.findings import Baseline, Finding
from repro.analysis.mirrors import check_mirrors, scan_mirror_regions
from repro.analysis.rules import run_rules_on_source


def _write(tmp_path: pathlib.Path, name: str, source: str) -> pathlib.Path:
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return p


def _scan_mirrors(tmp_path, sources):
    regions, findings = [], []
    for name, src in sources.items():
        p = _write(tmp_path, name.replace("/", "_"), src)
        rs, fs = scan_mirror_regions(p, name)
        regions += rs
        findings += fs
    return findings + check_mirrors(regions)


# -- PL001 mirror-drift ------------------------------------------------------
_SIM_HALF = """\
    class Sim:
        def sync_to(self, t, comm_s=0.0):
            # parity-mirror: sync-to begin clock=self.t stats=self._stats
            wait = t - self.t
            if wait > 0:
                if self._stats is not None:
                    self._stats.allreduce_wait_seconds += wait
                self.t = t
            if comm_s > 0:
                if self._stats is not None:
                    self._stats.allreduce_comm_seconds += comm_s
                self.t += comm_s
            # parity-mirror: sync-to end
"""

_LOADER_HALF_OK = """\
    class Loader:
        def sync_to(self, t, comm_s=0.0):
            # parity-mirror: sync-to begin clock=self.clock stats=self._active_stats
            wait = t - self.clock.now()
            if wait > 0:
                if self._active_stats is not None:
                    self._active_stats.allreduce_wait_seconds += wait
                self.clock.advance_to(t)
            if comm_s > 0:
                if self._active_stats is not None:
                    self._active_stats.allreduce_comm_seconds += comm_s
                self.clock.sleep(comm_s)
            # parity-mirror: sync-to end
"""

# Drifted: the comm charge happens BEFORE the stats record — same result
# for the clock, different stats/time interleaving, and exactly the kind
# of reorder a human review waves through.
_LOADER_HALF_DRIFTED = """\
    class Loader:
        def sync_to(self, t, comm_s=0.0):
            # parity-mirror: sync-to begin clock=self.clock stats=self._active_stats
            wait = t - self.clock.now()
            if wait > 0:
                if self._active_stats is not None:
                    self._active_stats.allreduce_wait_seconds += wait
                self.clock.advance_to(t)
            if comm_s > 0:
                self.clock.sleep(comm_s)
                if self._active_stats is not None:
                    self._active_stats.allreduce_comm_seconds += comm_s
            # parity-mirror: sync-to end
"""


def test_mirror_equivalent_halves_pass(tmp_path):
    findings = _scan_mirrors(
        tmp_path, {"src/repro/core/a.py": _SIM_HALF, "src/repro/core/b.py": _LOADER_HALF_OK}
    )
    assert findings == []


def test_mirror_drift_fires_with_location_and_hint(tmp_path):
    findings = _scan_mirrors(
        tmp_path,
        {"src/repro/core/a.py": _SIM_HALF, "src/repro/core/b.py": _LOADER_HALF_DRIFTED},
    )
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "mirror-drift" and f.code == "PL001"
    assert f.symbol == "sync-to"
    # anchored at a begin marker of one of the two declared halves
    assert f.path in ("src/repro/core/a.py", "src/repro/core/b.py")
    assert f.line == 3
    assert "drifted" in f.message
    assert "PARITY.md" in f.hint


def test_mirror_orphan_half_fires(tmp_path):
    findings = _scan_mirrors(tmp_path, {"src/repro/core/a.py": _SIM_HALF})
    assert [f.rule for f in findings] == ["mirror-drift"]
    assert "exactly two halves" in findings[0].message


def test_mirror_unclosed_region_fires(tmp_path):
    src = "# parity-mirror: lost begin\nx = 1\n"
    p = _write(tmp_path, "lost.py", src)
    _, findings = scan_mirror_regions(p, "src/repro/core/lost.py")
    assert [f.rule for f in findings] == ["mirror-drift"]
    assert "without end" in findings[0].message


def test_mirror_call_shape_catches_keyword_drift(tmp_path):
    ok = """\
        # parity-mirror: build begin mode=call-shape callee=Machine
        m = Machine(now=lambda: self.t, charge=self._charge, kernel=k)
        # parity-mirror: build end
    """
    drifted = """\
        # parity-mirror: build begin mode=call-shape callee=Machine
        m = Machine(now=clock.now, charge=clock.sleep, kernel=k, extra=1)
        # parity-mirror: build end
    """
    findings = _scan_mirrors(
        tmp_path, {"src/repro/core/a.py": ok, "src/repro/core/b.py": drifted}
    )
    assert len(findings) == 1
    assert findings[0].rule == "mirror-drift"
    assert "extra" in findings[0].message

    # operands may differ freely when the keyword surface agrees
    same_shape = """\
        # parity-mirror: build begin mode=call-shape callee=Machine
        m = Machine(now=clock.now, charge=clock.sleep, kernel=other_kernel)
        # parity-mirror: build end
    """
    assert (
        _scan_mirrors(
            tmp_path, {"src/repro/core/c.py": ok, "src/repro/core/d.py": same_shape}
        )
        == []
    )


def test_mirror_marker_in_docstring_is_not_a_marker(tmp_path):
    src = '"""example: # parity-mirror: ghost begin"""\nx = 1\n'
    p = _write(tmp_path, "doc.py", src)
    regions, findings = scan_mirror_regions(p, "src/repro/core/doc.py")
    assert regions == [] and findings == []


# -- PL002 clock-discipline --------------------------------------------------
def test_clock_discipline_fires_on_time_time():
    src = "import time\n\ndef step(self):\n    t0 = time.time()\n    return t0\n"
    findings = run_rules_on_source("src/repro/core/broken.py", src)
    assert [f.rule for f in findings] == ["clock-discipline"]
    f = findings[0]
    assert f.code == "PL002" and f.line == 4 and f.symbol == "step"
    assert f.key == "time.time"
    assert "clock.now()" in f.hint


def test_clock_discipline_fires_on_from_import_and_random():
    src = (
        "from time import perf_counter\n"
        "import random\n"
        "def jitter():\n"
        "    return perf_counter() + random.random()\n"
    )
    findings = run_rules_on_source("src/repro/oracle/broken.py", src)
    assert sorted(f.key for f in findings) == ["random.random", "time.perf_counter"]


def test_clock_discipline_allows_seeded_rng_and_allowlist():
    seeded = "import random\nrng = random.Random(1234)\n"
    assert run_rules_on_source("src/repro/core/fine.py", seeded) == []
    # the wall-clock abstraction itself is allowlisted
    wall = "import time\n\ndef now(self):\n    return time.monotonic()\n"
    assert run_rules_on_source("src/repro/core/clock.py", wall) == []
    # ...but only inside the sim domain does the rule even apply
    assert run_rules_on_source("src/repro/launch/bench.py", wall) == []


# -- PL003 float-determinism -------------------------------------------------
def test_float_determinism_fires_on_np_sum_time_chain():
    src = (
        "import numpy as np\n"
        "def total(self, spans):\n"
        "    self.wait_seconds = np.sum(spans)\n"
    )
    findings = run_rules_on_source("src/repro/engine/broken.py", src)
    assert [f.key for f in findings] == ["np.sum"]
    f = findings[0]
    assert f.rule == "float-determinism" and f.code == "PL003" and f.line == 3
    assert "cumsum" in f.hint


def test_float_determinism_fires_on_builtin_sum_over_floats():
    src = "def mean_wait(rows):\n    return sum(r.wait_seconds for r in rows) / len(rows)\n"
    findings = run_rules_on_source("src/repro/core/broken.py", src)
    assert [f.key for f in findings] == ["sum"]
    # int counters are not the target of this rule
    ok = "def n_hits(rows):\n    return sum(r.hits for r in rows)\n"
    assert run_rules_on_source("src/repro/core/fine.py", ok) == []


def test_float_determinism_fires_on_set_iteration_accumulator():
    src = (
        "def drain(self, keys):\n"
        "    for k in set(keys):\n"
        "        self.wait_seconds += self.cost(k)\n"
    )
    findings = run_rules_on_source("src/repro/core/broken.py", src)
    assert [f.key for f in findings] == ["set-iteration"]
    assert "sorted()" in findings[0].hint
    ok = src.replace("set(keys)", "sorted(keys)")
    assert run_rules_on_source("src/repro/core/fine.py", ok) == []


# -- PL004 no-tolerance ------------------------------------------------------
def test_no_tolerance_fires_on_pytest_approx_in_parity_test():
    src = (
        "import pytest\n"
        "from repro.pipeline.parity import assert_parity\n"
        "def test_sim_matches_runtime(sim, rt):\n"
        "    assert sim.t == pytest.approx(rt.clock.now())\n"
    )
    findings = run_rules_on_source("tests/test_broken.py", src)
    assert [f.key for f in findings] == ["pytest.approx"]
    f = findings[0]
    assert f.rule == "no-tolerance" and f.code == "PL004" and f.line == 4
    assert "exact ==" in f.message and "baselined exception" in f.hint


def test_no_tolerance_fires_on_isclose_and_abs_eps():
    src = (
        "import math\n"
        "def test_parity_epoch(a, b, eps):\n"
        "    assert math.isclose(a, b)\n"
        "    assert abs(a - b) < 1e-9\n"
        "    assert abs(a - b) < eps\n"
    )
    # parity-named file: in scope even without the assert_parity import
    findings = run_rules_on_source("tests/test_parity_broken.py", src)
    assert [f.key for f in findings] == ["math.isclose", "abs<eps", "abs<eps"]


def test_no_tolerance_ignores_non_parity_tests():
    src = "import pytest\ndef test_cost_model(x):\n    assert x == pytest.approx(1.5)\n"
    assert run_rules_on_source("tests/test_costs.py", src) == []


# -- PL005 shared-state ------------------------------------------------------
def test_shared_state_fires_outside_lockstep():
    src = (
        "class Planner:\n"
        "    def on_issue(self, keys):\n"
        "        self.in_flight.update(keys)\n"
        "    def on_done(self, k):\n"
        "        self.in_flight.discard(k)\n"
    )
    findings = run_rules_on_source("src/repro/oracle/placement_broken.py", src)
    assert [f.key for f in findings] == [".update", ".discard"]
    f = findings[0]
    assert f.rule == "shared-state" and f.code == "PL005"
    assert f.symbol == "Planner.on_issue"
    assert "lockstep" in f.hint


def test_shared_state_allows_lockstep_home_and_wiring():
    src = "class S:\n    def issue(self, keys):\n        self._in_flight.update(keys)\n"
    assert run_rules_on_source("src/repro/core/lockstep.py", src) == []
    # plain rebinding (wiring the shared set into a view) is fine anywhere
    wiring = "class V:\n    def attach(self, shared):\n        self.in_flight = shared\n"
    assert run_rules_on_source("src/repro/oracle/view.py", wiring) == []


# -- PL006 observer-purity ---------------------------------------------------
def test_observer_purity_fires_on_mutators_in_obs_package():
    src = (
        "class Tracer:\n"
        "    def on_insert(self, idx, payload):\n"
        "        self.cache.put(idx, payload)\n"
        "    def on_batch(self, stats, dt):\n"
        "        stats.data_wait_seconds += dt\n"
    )
    findings = run_rules_on_source("src/repro/obs/broken.py", src)
    assert [f.key for f in findings] == [".put", "augassign:data_wait_seconds"]
    f = findings[0]
    assert f.rule == "observer-purity" and f.code == "PL006"
    assert f.symbol == "Tracer.on_insert"
    assert "observe-only" in f.message and "emit events" in f.hint


def test_observer_purity_allows_pure_observation():
    src = (
        "class Tracer:\n"
        "    def on_insert(self, idx):\n"
        "        self.trace.emit('insert', self.node, self.now(), idx=idx)\n"
        "        self.count += 1\n"  # recorder-local counter, not a stats field
    )
    assert run_rules_on_source("src/repro/obs/fine.py", src) == []
    # the same mutator call OUTSIDE obs/ is the host's business
    host = "def fill(self, idx, p):\n    self.cache.put(idx, p)\n"
    assert run_rules_on_source("src/repro/distributed/host.py", host) == []


def test_observer_purity_fires_on_raw_emit_inside_mirror_region():
    src = (
        "def sync_to(self, t, comm_s=0.0):\n"
        "    # parity-mirror: sync-to begin clock=self.t\n"
        "    wait = t - self.t\n"
        "    self._trace.emit('allreduce-wait', self.node_id, self.t, wait)\n"
        "    trace_demand(self._trace, self.node_id, self.t, wait, 0, 'ram')\n"
        "    trace_sync(self._trace, self.node_id, self.t, wait, comm_s)\n"
        "    # parity-mirror: sync-to end\n"
    )
    findings = run_rules_on_source("src/repro/core/broken.py", src)
    pl6 = [f for f in findings if f.rule == "observer-purity"]
    assert sorted(f.key for f in pl6) == [".emit", "trace_demand"]
    assert all(f.symbol == "sync-to" for f in pl6)
    assert "trace_sync" in pl6[0].hint  # the sanctioned shared helper


def test_observer_purity_allows_emits_outside_mirror_regions():
    src = (
        "def _access(self, idx):\n"
        "    self._trace.emit('demand', self.node_id, self.t, 0.1, idx=idx)\n"
    )
    assert run_rules_on_source("src/repro/core/fine.py", src) == []


# -- baseline mechanics ------------------------------------------------------
def _finding(**kw):
    base = dict(
        rule="no-tolerance",
        path="tests/test_x.py",
        line=10,
        symbol="test_a",
        key="pytest.approx",
        message="m",
        hint="h",
    )
    base.update(kw)
    return Finding(**base)


def test_baseline_count_budget_and_staleness():
    baseline = Baseline(
        [
            {
                "rule": "no-tolerance",
                "path": "tests/test_x.py",
                "symbol": "test_a",
                "key": "pytest.approx",
                "count": 2,
                "reason": "closed-form pin",
            }
        ]
    )
    # two covered (line numbers irrelevant), a third is new
    new, stale = baseline.filter([_finding(line=1), _finding(line=99)])
    assert new == [] and stale == []
    new, stale = baseline.filter([_finding(line=1), _finding(line=2), _finding(line=3)])
    assert len(new) == 1 and stale == []
    # unused budget is reported stale
    new, stale = baseline.filter([_finding(line=1)])
    assert new == [] and len(stale) == 1 and stale[0]["unused"] == 1
    # a different symbol is not covered
    new, _ = baseline.filter([_finding(symbol="test_b")])
    assert len(new) == 1
