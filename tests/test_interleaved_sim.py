"""ISSUE 3 tentpole: the event-interleaved cluster scheduler and the
lock-step prefetch model — mid-epoch peer visibility, schedule equivalence
for non-interacting nodes, determinism, and the BSP epoch barrier."""
import dataclasses

import pytest

from repro.core import MNIST, PrefetchConfig, SharedShuffleSampler, SimConfig, simulate_cluster
from repro.core.types import aggregate_tier_hits
from repro.core.workloads import WorkloadSpec
from repro.pipeline import DataPlaneSpec, assert_parity, condition


def _two_node_shared(n_samples=600, cache_items=-1) -> DataPlaneSpec:
    w = WorkloadSpec(
        name="shared",
        n_samples=n_samples,
        sample_bytes=784,
        batch_size=32,
        compute_per_epoch_s=0.2,
        n_nodes=2,
    )
    return DataPlaneSpec(
        workload=w, cache_items=cache_items, peer_cache=True, sampler="shared-shuffle"
    )


def _per_node_peer_hits(stats):
    return {(s.epoch, s.node): s.tier_hits.get("peer", 0) for s in stats}


# ---------------------------------------------------------------------------
# SharedShuffleSampler (the regime where same-epoch visibility exists).
# ---------------------------------------------------------------------------
def test_shared_shuffle_sampler_full_pass_per_node():
    s0 = SharedShuffleSampler(100, rank=0, world=2, seed=3)
    s1 = SharedShuffleSampler(100, rank=1, world=2, seed=3)
    s0.set_epoch(1)
    s1.set_epoch(1)
    assert sorted(s0.indices()) == list(range(100))  # every node sees all
    assert sorted(s1.indices()) == list(range(100))
    assert s0.indices() != s1.indices()  # ...in its own order
    assert s0.indices() == s0.indices()  # deterministic
    s0.set_epoch(2)
    two = s0.indices()
    s0.set_epoch(1)
    assert s0.indices() != two  # re-shuffled per epoch


# ---------------------------------------------------------------------------
# Mid-epoch peer-cache visibility (ISSUE 3 satellite).
# ---------------------------------------------------------------------------
def test_interleaved_node_hits_samples_peer_cached_same_epoch():
    """Two nodes stream the full dataset in different orders.  Under the
    legacy sequential schedule, rank 0 runs its whole epoch before rank 1
    even starts, so in epoch 0 rank 0 can never hit anything (rank 1's
    cache is empty all epoch) while rank 1 sees rank 0's *complete* epoch.
    The event-interleaved scheduler lets rank 0 hit samples rank 1 cached
    *during the same epoch* — the fidelity the sequential loop could not
    represent."""
    spec = _two_node_shared()
    seq_stats, seq_store = dataclasses.replace(spec, interleaved=False).build_sim().run(
        epochs=1
    )
    int_stats, int_store = spec.build_sim().run(epochs=1)
    seq_hits = _per_node_peer_hits(seq_stats)
    int_hits = _per_node_peer_hits(int_stats)
    assert seq_hits[(0, 0)] == 0  # rank 0 sequential: peers frozen empty
    assert int_hits[(0, 0)] > 0  # interleaved: same-epoch fills visible
    # Every sample is still bucket-fetched exactly once cluster-wide
    # (unlimited caches): the schedules move *who* pays, not the total.
    assert seq_store.class_b_requests == int_store.class_b_requests == 600


def test_interleaved_changes_capped_peer_tier_hits_in_expected_direction():
    """Partition sampler + capped caches: the sequential schedule's
    epoch-boundary snapshot let late ranks read early ranks' *complete*
    epoch cache — an optimistic bias (documented in PR 1).  Interleaving
    removes it: peers' same-epoch evictions are visible too, so the peer
    tier serves strictly fewer reads and the cluster pays strictly more
    Class B requests for this configuration."""
    w = dataclasses.replace(MNIST.scaled(0.05), n_nodes=4)
    spec = condition("cache+peer", w, cache_items=w.partition_size // 2)
    seq_stats, seq_store = dataclasses.replace(spec, interleaved=False).build_sim().run(
        epochs=2
    )
    int_stats, int_store = spec.build_sim().run(epochs=2)
    seq_peer = aggregate_tier_hits(seq_stats).get("peer", 0)
    int_peer = aggregate_tier_hits(int_stats).get("peer", 0)
    assert int_peer < seq_peer
    assert int_store.class_b_requests > seq_store.class_b_requests
    assert int_peer > 0  # the tier still works, it is just honest now


def test_interleaved_prefetch_sees_more_peer_fills():
    """With the pre-fetch service on, rounds probe peers at issue time;
    mid-epoch visibility lets them find same-epoch fills, so the
    interleaved schedule pulls MORE from peers and pays FEWER Class B
    requests than the sequential snapshot schedule."""
    spec = condition(
        "cache+peer",
        MNIST.scaled(0.02),
        cache_items=300,
        prefetch=PrefetchConfig.fifty_fifty(300),
    )
    seq_stats, seq_store = dataclasses.replace(spec, interleaved=False).build_sim().run(
        epochs=2
    )
    int_stats, int_store = spec.build_sim().run(epochs=2)
    assert aggregate_tier_hits(int_stats)["peer"] > aggregate_tier_hits(seq_stats)["peer"]
    assert int_store.class_b_requests < seq_store.class_b_requests


def test_interleaved_shared_shuffle_parity_is_exact():
    """Cross-node exactness: the lock-step runtime reproduces the
    interleaved schedule bit-for-bit even when every peer probe depends on
    another node's mid-epoch state."""
    assert_parity(_two_node_shared(), epochs=2)
    assert_parity(_two_node_shared(cache_items=400), epochs=2)


# ---------------------------------------------------------------------------
# Schedule equivalence + determinism.
# ---------------------------------------------------------------------------
def test_interleaved_equals_sequential_for_non_interacting_nodes():
    """Prefetch-free nodes without a peer tier never observe each other;
    the interleaved schedule must not change their results at all."""
    spec = MNIST.scaled(0.04)
    cfg = SimConfig(cache_items=spec.partition_size // 2)
    a, sa = simulate_cluster(spec, cfg, epochs=2, seed=0, interleaved=True)
    b, sb = simulate_cluster(spec, cfg, epochs=2, seed=0, interleaved=False)
    assert [(s.epoch, s.node, s.samples, s.tier_hits) for s in a] == [
        (s.epoch, s.node, s.samples, s.tier_hits) for s in b
    ]
    assert [s.data_wait_seconds for s in a] == [s.data_wait_seconds for s in b]
    assert (sa.class_a_requests, sa.class_b_requests) == (
        sb.class_a_requests,
        sb.class_b_requests,
    )


def test_interleaved_schedule_is_deterministic():
    spec = _two_node_shared(cache_items=400)
    r1 = spec.build_sim().run(epochs=2)
    r2 = spec.build_sim().run(epochs=2)
    assert [dataclasses.asdict(s) for s in r1[0]] == [
        dataclasses.asdict(s) for s in r2[0]
    ]
    assert r1[1] == r2[1]


def test_epoch_barrier_synchronizes_clocks():
    """BSP epoch boundary: all nodes leave epoch e at the slowest node's
    virtual time (data-parallel training synchronizes at least per epoch)."""
    from repro.core.simulator import NodeSimulator

    w = _two_node_shared().workload
    cfg = SimConfig(cache_items=-1, peer_cache=True)
    # Run through simulate_cluster's machinery by hand to observe clocks.
    import heapq

    from repro.distributed.peer_cache import PeerCacheRegistry

    nodes = [NodeSimulator(w, cfg, node_id=r) for r in range(2)]
    reg = PeerCacheRegistry()
    for n in nodes:
        n.join_peer_registry(reg)
    samplers = [SharedShuffleSampler(w.n_samples, r, 2, seed=0) for r in range(2)]
    for rank, (node, sampler) in enumerate(zip(nodes, samplers)):
        sampler.set_epoch(0)
        node.begin_epoch(0, sampler.indices(), node=rank)
    heap = [(n.t, r) for r, n in enumerate(nodes)]
    heapq.heapify(heap)
    while heap:
        t, rank = heapq.heappop(heap)
        for n in nodes:
            n.fold_inserts_until(t)
        if nodes[rank].step():
            heapq.heappush(heap, (nodes[rank].t, rank))
    assert nodes[0].t != nodes[1].t  # different work -> different finish
    barrier = max(n.t for n in nodes)
    for n in nodes:
        n.t = barrier
    for n in nodes:
        n.finish_epoch()
    assert nodes[0].t == nodes[1].t == barrier


def test_simulate_cluster_rejects_wrong_sampler_count():
    spec = MNIST.scaled(0.02)
    with pytest.raises(ValueError):
        simulate_cluster(
            spec,
            SimConfig(cache_items=-1),
            samplers=[SharedShuffleSampler(spec.n_samples, 0, 1)],
        )
