"""ISSUE 3 satellite: the documentation surface exists, its intra-repo
links resolve, and the docs state the load-bearing claims accurately."""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_docs_exist():
    assert (REPO / "README.md").is_file()
    assert (REPO / "docs" / "ARCHITECTURE.md").is_file()
    assert (REPO / "docs" / "PARITY.md").is_file()


def test_docs_links_resolve():
    """Same checker the CI docs lane runs; broken intra-repo paths fail."""
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_docs_links
    finally:
        sys.path.pop(0)
    broken = check_docs_links.check()
    assert broken == [], "\n".join(broken)


def test_docs_link_checker_cli_passes():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs_links.py")],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_readme_documents_verify_command_and_interleaving():
    text = (REPO / "README.md").read_text()
    assert "python -m pytest -x -q" in text  # the tier-1 verify command
    assert "event-interleaved" in text
    assert "DataPlaneSpec" in text


def test_pydoc_pipeline_importable_pipeline_first():
    """ISSUE 3 satellite: ``pydoc repro.pipeline`` must work, which means
    importing repro.pipeline BEFORE repro.core must not cycle (the seed
    only survived core-first entry)."""
    proc = subprocess.run(
        [sys.executable, "-c", "import repro.pipeline; import repro.core"],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr


def test_parity_doc_forbids_tolerances():
    text = (REPO / "docs" / "PARITY.md").read_text()
    assert "tolerance" in text.lower()
    assert "lock-step" in text.lower()
    # The policy line the parity harness itself must keep honouring.
    assert "Do not add" in text
