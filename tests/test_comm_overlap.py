"""ISSUE 8 tentpole: the allreduce as a first-class modeled operation —
``CollectiveModel`` cost closed forms, gradient-bucket overlap
(``overlap="buckets"``), straggler mitigation (``backup_workers`` /
``staleness_bound``) — with closed-form pins, exact sim/runtime parity,
and seed-swept invariants."""
import dataclasses
import math

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (
    DEFAULT_NETWORK,
    MNIST,
    CollectiveModel,
    NodeProfile,
    PrefetchConfig,
    SimConfig,
    mnist_cnn_gradient_bytes,
    straggler_profiles,
)
from repro.core.types import aggregate_tier_hits
from repro.core.workloads import WorkloadSpec
from repro.pipeline import DataPlaneSpec, assert_parity, condition

GRAD = mnist_cnn_gradient_bytes()


def _workload(n_samples=600, batch=25, n_nodes=3, compute_s=0.2):
    """Batch-divisible shape (see test_batch_sync): every node runs the
    same number of gradient batches."""
    assert (n_samples // n_nodes) % batch == 0
    return WorkloadSpec(
        name="comm",
        n_samples=n_samples,
        sample_bytes=784,
        batch_size=batch,
        compute_per_epoch_s=compute_s,
        n_nodes=n_nodes,
    )


def _spec(**overrides):
    w = overrides.pop("workload", _workload())
    kw = dict(workload=w, cache_items=-1, sync="batch")
    kw.update(overrides)
    return DataPlaneSpec(**kw)


# ---------------------------------------------------------------------------
# Closed-form pins (satellite 3): durations asserted exactly — the model
# IS the arithmetic, so the test states the arithmetic.
# ---------------------------------------------------------------------------
def test_mnist_cnn_gradient_bytes_pin():
    # conv1 32*(1*25+1) + conv2 64*(32*25+1) + fc1 (3136*128+128)
    # + fc2 (128*10+10) parameters, fp32.
    params = 832 + 51264 + 401536 + 1290
    assert GRAD == 4 * params == 1_819_688


def test_ring_allreduce_closed_form_exact():
    cm = CollectiveModel(gradient_bytes=GRAD)
    for n in (2, 3, 4, 8):
        expected = 2 * (n - 1) * (
            DEFAULT_NETWORK.rtt_s + (GRAD / n) / DEFAULT_NETWORK.bw
        )
        assert cm.allreduce_seconds(DEFAULT_NETWORK, n) == expected


def test_tree_allreduce_closed_form_exact():
    cm = CollectiveModel(gradient_bytes=GRAD, algorithm="tree")
    for n in (2, 3, 4, 8):
        expected = 2 * math.ceil(math.log2(n)) * (
            DEFAULT_NETWORK.rtt_s + GRAD / DEFAULT_NETWORK.bw
        )
        assert cm.allreduce_seconds(DEFAULT_NETWORK, n) == expected


def test_allreduce_degenerate_cases_are_free():
    assert CollectiveModel(gradient_bytes=0).allreduce_seconds(DEFAULT_NETWORK, 8) == 0.0
    assert CollectiveModel(gradient_bytes=GRAD).allreduce_seconds(DEFAULT_NETWORK, 1) == 0.0


def test_both_algorithms_dominate_the_bandwidth_lower_bound():
    """Every modeled duration >= the bandwidth-optimal closed form
    2(n-1)/n * bytes / bw (each rank must move that much gradient)."""
    for algorithm in ("ring", "tree"):
        for n in (2, 3, 4, 7, 16):
            cm = CollectiveModel(gradient_bytes=GRAD, algorithm=algorithm)
            assert cm.allreduce_seconds(DEFAULT_NETWORK, n) >= cm.ring_lower_bound_seconds(
                DEFAULT_NETWORK, n
            )


def test_bucket_seconds_partition_allreduce_exactly():
    """Buckets partition the full duration exactly (latency amortized with
    the payload): B * bucket_seconds == allreduce_seconds up to float
    division/multiplication round-trip, and is the literal quotient."""
    for n_buckets in (1, 2, 4, 8):
        cm = CollectiveModel(gradient_bytes=GRAD, n_buckets=n_buckets)
        full = cm.allreduce_seconds(DEFAULT_NETWORK, 4)
        assert cm.bucket_seconds(DEFAULT_NETWORK, 4) == full / n_buckets


def test_lm_config_gradient_bytes_pin():
    """Table-scale gradients come from the real model configs (lazy jax
    import): 4 bytes per parameter, exactly."""
    pytest.importorskip("jax")
    from repro.core import arch_gradient_bytes
    from repro import configs

    assert arch_gradient_bytes("mamba2-130m") == 4 * configs.get("mamba2-130m").param_count()


def test_node_profile_identity_keeps_allreduce_bitwise():
    """NodeProfile(1.0, 1.0) rebuilds a bit-identical network, so the
    per-rank allreduce duration is the same float — homogeneous clusters
    stay at their unscaled values."""
    cm = CollectiveModel(gradient_bytes=GRAD)
    scaled = NodeProfile().scale_network(DEFAULT_NETWORK)
    assert cm.allreduce_seconds(scaled, 3) == cm.allreduce_seconds(DEFAULT_NETWORK, 3)
    slow = NodeProfile(bandwidth=2.0).scale_network(DEFAULT_NETWORK)
    assert cm.allreduce_seconds(slow, 3) > cm.allreduce_seconds(DEFAULT_NETWORK, 3)


# ---------------------------------------------------------------------------
# Validation: every new knob refuses loudly when misused.
# ---------------------------------------------------------------------------
def test_collective_model_validation():
    with pytest.raises(ValueError):
        CollectiveModel(gradient_bytes=-1)
    with pytest.raises(ValueError):
        CollectiveModel(gradient_bytes=GRAD, algorithm="butterfly")
    with pytest.raises(ValueError):
        CollectiveModel(gradient_bytes=GRAD, n_buckets=0)


def test_spec_knob_validation():
    w = _workload()
    cm = CollectiveModel(gradient_bytes=GRAD)
    # collective and overlap require the per-batch schedule.
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=-1, collective=cm)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=-1, sync="batch", overlap="buckets")
    with pytest.raises(ValueError):
        _spec(collective=cm, overlap="pipelined")
    # mitigation requires batch sync and the knobs are mutually exclusive.
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=-1, backup_workers=1)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=-1, staleness_bound=1)
    with pytest.raises(ValueError):
        _spec(backup_workers=-1)
    with pytest.raises(ValueError):
        _spec(staleness_bound=-1)
    with pytest.raises(ValueError):
        _spec(backup_workers=1, staleness_bound=1)
    # backup_workers must leave at least one syncing rank.
    with pytest.raises(ValueError):
        _spec(backup_workers=w.n_nodes).build_sim().run(epochs=1)
    with pytest.raises(ValueError):
        SimConfig(cache_items=-1, sync="batch", overlap="buckets")


# ---------------------------------------------------------------------------
# Satellite 4 (bugfix pin): blocked time now splits into wait + comm, and
# the zero-cost collective reproduces the historical totals bit-for-bit.
# ---------------------------------------------------------------------------
def test_zero_cost_collective_is_bit_identical_to_plain_batch_sync():
    """CollectiveModel(gradient_bytes=0) charges nothing, so wait + comm
    must reproduce the pre-ISSUE-8 wall exactly — comm identically zero,
    every other stat bit-equal.  This is the pin that keeps fig11's
    straggler-tax claims meaningful after the accounting split."""
    w = _workload()
    nodes = straggler_profiles(w.n_nodes, slow_ranks=(2,), compute=2.0, bandwidth=2.0)
    plain = _spec(workload=w, nodes=nodes)
    free = dataclasses.replace(plain, collective=CollectiveModel(gradient_bytes=0))
    p_stats, p_store = plain.build_sim().run(epochs=2)
    f_stats, f_store = free.build_sim().run(epochs=2)
    assert [dataclasses.asdict(s) for s in p_stats] == [
        dataclasses.asdict(s) for s in f_stats
    ]
    assert p_store == f_store
    assert all(s.allreduce_comm_seconds == 0.0 for s in f_stats)


def test_costed_barrier_splits_wait_from_comm():
    """With a real gradient, every rank pays the same transfer time per
    barrier (the collective runs at the slowest member's pace) on top of
    whatever skew wait it had; comm = batches * allreduce_seconds exactly."""
    w = _workload()
    cm = CollectiveModel(gradient_bytes=GRAD)
    plain = _spec(workload=w)
    cost = dataclasses.replace(plain, collective=cm)
    p_stats, _ = plain.build_sim().run(epochs=1)
    c_stats, _ = cost.build_sim().run(epochs=1)
    per_batch = cm.allreduce_seconds(DEFAULT_NETWORK, w.n_nodes)
    batches = w.partition_size // w.batch_size
    for p, c in zip(p_stats, c_stats):
        assert c.allreduce_wait_seconds == p.allreduce_wait_seconds
        assert c.allreduce_comm_seconds == pytest.approx(batches * per_batch, rel=1e-12)
        assert c.wall_clock_seconds > p.wall_clock_seconds


def test_overlap_hides_comm_behind_backprop():
    """Bucketed overlap: only the exposed tail of the last bucket's
    allreduce is charged, so comm drops versus overlap="none" while Class
    A/B and tier outcomes stay identical (the data plane cannot tell)."""
    w = _workload()
    cm = CollectiveModel(gradient_bytes=GRAD)
    none = _spec(workload=w, collective=cm)
    ovl = dataclasses.replace(none, overlap="buckets")
    n_stats, n_store = none.build_sim().run(epochs=1)
    o_stats, o_store = ovl.build_sim().run(epochs=1)
    assert aggregate_tier_hits(n_stats) == aggregate_tier_hits(o_stats)
    assert (n_store.class_a_requests, n_store.class_b_requests) == (
        o_store.class_a_requests,
        o_store.class_b_requests,
    )
    for n, o in zip(n_stats, o_stats):
        assert o.allreduce_comm_seconds < n.allreduce_comm_seconds
        assert o.wall_clock_seconds <= n.wall_clock_seconds * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Mitigation semantics.
# ---------------------------------------------------------------------------
def test_backup_workers_release_barrier_without_the_straggler():
    """backup_workers=1 on a straggler cluster: barriers release without
    the slow rank, whose gradient is dropped — it pays no collective comm
    at all, the surviving collective runs at the fast ranks' (unscaled)
    pace, the epoch wall shrinks, and every sample is still accounted
    exactly once."""
    w = _workload(n_nodes=4, n_samples=800)
    cm = CollectiveModel(gradient_bytes=GRAD)
    nodes = straggler_profiles(w.n_nodes, slow_ranks=(0,), compute=3.0, bandwidth=3.0)
    plain = _spec(workload=w, nodes=nodes, collective=cm)
    backup = dataclasses.replace(plain, backup_workers=1)
    p_stats, _ = plain.build_sim().run(epochs=1)
    b_stats, _ = backup.build_sim().run(epochs=1)
    straggler = [s for s in b_stats if s.node == 0][0]
    assert straggler.allreduce_comm_seconds == 0.0  # dropped from collectives
    fast = [s for s in b_stats if s.node != 0]
    fast_plain = [s for s in p_stats if s.node != 0]
    assert sum(s.allreduce_comm_seconds for s in fast) < sum(
        s.allreduce_comm_seconds for s in fast_plain
    )
    assert max(s.wall_clock_seconds for s in b_stats) < max(
        s.wall_clock_seconds for s in p_stats
    )
    assert sum(s.samples for s in b_stats) == w.n_samples


def test_staleness_bound_elides_barriers():
    """staleness_bound=s: a rank may run up to s batches past the barrier
    round before parking, so the first s barriers of the epoch never fire
    — exactly s fewer collectives per epoch (comm = (batches - s) * the
    closed form), a strictly smaller wall, and the run-ahead stays bounded
    (wall still >= every node's own busy time)."""
    w = _workload()
    cm = CollectiveModel(gradient_bytes=GRAD)
    nodes = straggler_profiles(w.n_nodes, slow_ranks=(0,), compute=2.0, bandwidth=2.0)
    plain = _spec(workload=w, nodes=nodes, collective=cm)
    stale = dataclasses.replace(plain, staleness_bound=2)
    p_stats, _ = plain.build_sim().run(epochs=1)
    s_stats, _ = stale.build_sim().run(epochs=1)
    batches = w.partition_size // w.batch_size
    for p_row, s_row in zip(p_stats, s_stats):
        assert s_row.allreduce_comm_seconds == pytest.approx(
            p_row.allreduce_comm_seconds * (batches - 2) / batches, rel=1e-9
        )
        assert s_row.wall_clock_seconds < p_row.wall_clock_seconds
        busy = s_row.data_wait_seconds + s_row.compute_seconds
        assert s_row.wall_clock_seconds >= busy * (1 - 1e-9)
    assert sum(s.samples for s in s_stats) == w.n_samples


def test_mitigation_zero_is_plain_batch_sync_event_for_event():
    """backup_workers=0 and staleness_bound=0 ARE batch sync: the driver
    reduces to the historical release condition, so stats and store are
    bit-identical, not merely close."""
    w = _workload()
    nodes = straggler_profiles(w.n_nodes, slow_ranks=(1,), compute=2.0, bandwidth=1.5)
    plain = _spec(workload=w, nodes=nodes)
    p_stats, p_store = plain.build_sim().run(epochs=2)
    for knob in (dict(backup_workers=0), dict(staleness_bound=0)):
        k_stats, k_store = dataclasses.replace(plain, **knob).build_sim().run(epochs=2)
        assert [dataclasses.asdict(s) for s in p_stats] == [
            dataclasses.asdict(s) for s in k_stats
        ]
        assert p_store == k_store


# ---------------------------------------------------------------------------
# Satellite 2: the parity matrix — {overlap x mitigation} x {substep,
# straggler} x {oracle, cluster-oracle} x engines, exact == including the
# new comm column (row[5]).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "tag,overrides,prefetch",
    [
        ("comm-cache", dict(), False),
        ("comm-straggler", dict(straggler=True), False),
        ("ovl-cache", dict(overlap="buckets"), False),
        ("ovl-substep-peer-pf", dict(overlap="buckets", granularity="substep", peer_cache=True), True),
        ("ovl-straggler-pf", dict(overlap="buckets", straggler=True, peer_cache=True), True),
        ("backup-straggler", dict(backup_workers=1, straggler=True), False),
        ("backup-straggler-pf", dict(backup_workers=1, straggler=True, peer_cache=True), True),
        # Staleness rows need >s gradient batches per epoch for the bound
        # to bind (MNIST.scaled(0.02) has one), hence the bigger slice.
        ("stale-cache", dict(staleness_bound=2, big=True), False),
        ("stale-straggler-pf", dict(staleness_bound=2, big=True, straggler=True, peer_cache=True), True),
        ("ovl-backup-straggler", dict(overlap="buckets", backup_workers=1, straggler=True), False),
        ("tree-substep", dict(algorithm="tree", granularity="substep"), False),
    ],
)
def test_sim_runtime_parity_exact_comm_overlap(tag, overrides, prefetch):
    """ISSUE 8 acceptance: assert_parity (exact ==; per-tier hits, Class
    A+B, data-wait, allreduce wait AND comm floats; no tolerances) covers
    the collective-cost, bucket-overlap and mitigation knobs composed with
    sub-step granularity, stragglers and prefetch."""
    overrides = dict(overrides)
    w = MNIST.scaled(0.05 if overrides.pop("big", False) else 0.02)
    if overrides.pop("straggler", False):
        overrides["nodes"] = straggler_profiles(
            w.n_nodes, slow_ranks=(0,), compute=2.0, bandwidth=2.0
        )
    cm = CollectiveModel(
        gradient_bytes=GRAD, algorithm=overrides.pop("algorithm", "ring")
    )
    spec = DataPlaneSpec(
        workload=w,
        cache_items=300,
        sync="batch",
        collective=cm,
        prefetch=PrefetchConfig.fifty_fifty(300) if prefetch else None,
        **overrides,
    )
    report = assert_parity(spec, epochs=2)
    assert sum(row[5] for row in report.sim_samples) > 0  # comm modeled
    if prefetch:
        assert report.sim_tiers.get("ram", 0) > 0


@pytest.mark.parametrize("name", ["oracle", "cluster-oracle"])
@pytest.mark.parametrize(
    "knobs",
    [
        dict(overlap="buckets"),
        dict(backup_workers=1, nodes=straggler_profiles(3, (0,), 2.0, 2.0)),
        dict(staleness_bound=2, granularity="substep"),
    ],
    ids=["overlap", "backup", "stale-substep"],
)
def test_oracle_parity_exact_with_comm_knobs(name, knobs):
    """The clairvoyant data planes stay exact under every new knob: the
    collective schedule perturbs clock trajectories, and the oracle's
    cursor/planner machinery is shared, so parity must not budge."""
    # Staleness needs > s gradient batches per epoch to bind.
    scale = 0.05 if "staleness_bound" in knobs else 0.02
    spec = condition(
        name,
        MNIST.scaled(scale),
        cache_items=200,
        sync="batch",
        collective=CollectiveModel(gradient_bytes=GRAD),
        **knobs,
    )
    report = assert_parity(spec, epochs=2)
    assert sum(row[5] for row in report.sim_samples) > 0


def test_vector_engine_parity_with_collective_cost():
    """overlap="none" collective specs stay on the vector engine (barrier
    clock jumps land between segments); overlap="buckets" falls back to
    the scalar stepper.  Both must hold exact parity."""
    w = MNIST.scaled(0.02)
    cm = CollectiveModel(gradient_bytes=GRAD)
    for overlap in ("none", "buckets"):
        spec = DataPlaneSpec(
            workload=w,
            cache_items=300,
            sync="batch",
            collective=cm,
            overlap=overlap,
            engine="vector",
        )
        assert_parity(spec, epochs=2)


# ---------------------------------------------------------------------------
# Satellite 1: seed-swept invariants.
# ---------------------------------------------------------------------------
@settings(max_examples=8)
@given(
    seed=st.integers(0, 10_000),
    slow=st.integers(0, 2),
    comp=st.sampled_from([1.0, 1.5, 2.0, 4.0]),
    grad=st.sampled_from([0, 100_000, GRAD]),
    n_buckets=st.sampled_from([1, 2, 4, 8]),
)
def test_comm_overlap_invariants_seed_swept(seed, slow, comp, grad, n_buckets):
    """For cache-only (non-interacting) straggler clusters, at every swept
    (seed, straggler, gradient, bucketing) point:

    1. bucket overlap never increases any node's wall clock versus
       overlap="none" at equal collective cost (it can only hide comm);
    2. charged comm under overlap="none" equals batches * the closed-form
       duration, which dominates the bandwidth lower bound;
    3. tier outcomes and Class A/B totals are unchanged by EVERY
       sync/overlap/mitigation knob — the communication schedule moves
       clocks, never cache behaviour;
    4. the whole family is deterministic across runs.
    """
    w = _workload()
    cm = CollectiveModel(gradient_bytes=grad, n_buckets=n_buckets)
    # bandwidth=1.0 keeps every rank's network unscaled, so the barrier
    # comm (a max over the parked ranks' durations) IS the closed form.
    base = DataPlaneSpec(
        workload=w,
        cache_items=w.partition_size // 2,
        nodes=straggler_profiles(
            w.n_nodes, slow_ranks=(slow,), compute=comp, bandwidth=1.0
        ),
        seed=seed % 7,
        sync="batch",
    )
    variants = {
        "none": dataclasses.replace(base, collective=cm),
        "buckets": dataclasses.replace(base, collective=cm, overlap="buckets"),
        "backup": dataclasses.replace(base, collective=cm, backup_workers=1),
        "stale": dataclasses.replace(base, collective=cm, staleness_bound=2),
    }
    runs = {k: s.build_sim().run(epochs=2) for k, s in variants.items()}
    base_run = base.build_sim().run(epochs=2)

    # (1) overlap never worse than unoverlapped at equal cost.
    for n_row, o_row in zip(runs["none"][0], runs["buckets"][0]):
        assert o_row.wall_clock_seconds <= n_row.wall_clock_seconds * (1 + 1e-9)

    # (2) charged comm == batches * closed form >= lower bound.
    per_batch = cm.allreduce_seconds(DEFAULT_NETWORK, w.n_nodes)
    assert per_batch >= cm.ring_lower_bound_seconds(DEFAULT_NETWORK, w.n_nodes)
    batches = 2 * (w.partition_size // w.batch_size)
    for node in range(w.n_nodes):
        total = sum(
            r.allreduce_comm_seconds for r in runs["none"][0] if r.node == node
        )
        assert total == pytest.approx(batches * per_batch, rel=1e-12)

    # (3) the data plane cannot tell any of the knobs apart.
    reference = (
        aggregate_tier_hits(base_run[0]),
        base_run[1].class_a_requests,
        base_run[1].class_b_requests,
        sorted((s.epoch, s.node, s.samples) for s in base_run[0]),
    )
    for key, (stats, store) in runs.items():
        assert (
            aggregate_tier_hits(stats),
            store.class_a_requests,
            store.class_b_requests,
            sorted((s.epoch, s.node, s.samples) for s in stats),
        ) == reference, key

    # (4) determinism.
    again = variants["buckets"].build_sim().run(epochs=2)
    assert [dataclasses.asdict(s) for s in runs["buckets"][0]] == [
        dataclasses.asdict(s) for s in again[0]
    ]


# ---------------------------------------------------------------------------
# Registry conditions.
# ---------------------------------------------------------------------------
def test_comm_conditions_registered():
    w = MNIST.scaled(0.02)
    cost = condition("bsync-cost", w, cache_items=300)
    assert cost.sync == "batch" and cost.collective is not None
    assert cost.collective.gradient_bytes == GRAD
    assert "+comm" in cost.label()
    ovl = condition("overlap", w, cache_items=300)
    assert ovl.overlap == "buckets" and "+ovl" in ovl.label()
    backup = condition("backup-1", w, cache_items=300)
    assert backup.backup_workers == 1 and backup.nodes is not None
    assert "+backup1" in backup.label()
    stale = condition("stale-2", w, cache_items=300)
    assert stale.staleness_bound == 2 and "+stale2" in stale.label()
    # gradient_bytes= override threads through to the model.
    tiny = condition("bsync-cost", w, cache_items=300, gradient_bytes=4)
    assert tiny.collective.gradient_bytes == 4
