"""Stores, pre-fetch service and the threaded DeliLoader end-to-end."""
import pytest

from repro.core import (
    CachingDataset,
    CappedCache,
    DeliLoader,
    DistributedPartitionSampler,
    FileSystemStore,
    InMemoryStore,
    ListingCache,
    PrefetchConfig,
    PrefetchService,
    RealClock,
    ReliableStore,
    SimulatedBucketStore,
    StoreError,
    run_epochs,
)

FAST = RealClock(scale=1e-4)  # simulated I/O durations shrunk 10^4x


def test_in_memory_store(payloads_1k):
    s = InMemoryStore(payloads_1k)
    assert s.get(0) == payloads_1k[0]
    assert s.size_of(3) == 1024
    assert s.list_objects() == sorted(payloads_1k)
    assert s.stats.class_b_requests == 1
    assert s.stats.class_a_requests == 1
    with pytest.raises(StoreError):
        s.get(10_000)


def test_filesystem_store_roundtrip(tmp_store_dir, payloads_1k):
    s = FileSystemStore.write_dataset(tmp_store_dir, payloads_1k)
    assert s.get(5) == payloads_1k[5]
    assert set(s.list_objects()) == set(payloads_1k)
    assert s.size_of(7) == 1024


def test_simulated_bucket_timing_accounting(payloads_1k):
    s = SimulatedBucketStore(payloads_1k, clock=FAST)
    s.get(0)
    assert s.stats.class_b_requests == 1
    assert s.stats.read_seconds > 0
    s.bulk_get([1, 2, 3], n_connections=4)
    assert s.stats.class_b_requests == 4
    s.list_objects()
    assert s.stats.class_a_requests >= 1


def test_bulk_get_faster_than_sequential(payloads_1k):
    s = SimulatedBucketStore(payloads_1k, clock=FAST)
    seq = sum(s.model.get_seconds(1024) for _ in range(16))
    par = s.model.bulk_get_seconds([1024] * 16, n_connections=16)
    assert par < seq / 4  # calibrated ~5.66x parallel efficiency


def test_reliable_store_retries(payloads_1k):
    flaky = SimulatedBucketStore(payloads_1k, clock=FAST, failure_rate=0.5, seed=1)
    rel = ReliableStore(flaky, max_attempts=50, base_backoff_s=1e-6, clock=FAST)
    for i in range(32):
        assert rel.get(i) == payloads_1k[i]
    assert rel.retries > 0


def test_reliable_store_gives_up():
    always_fail = SimulatedBucketStore({0: b"x"}, clock=FAST, failure_rate=1.0)
    rel = ReliableStore(always_fail, max_attempts=3, base_backoff_s=1e-6, clock=FAST)
    with pytest.raises(StoreError, match="after 3 attempts"):
        rel.get(0)


def test_caching_dataset_hit_miss_paths(payloads_1k):
    store = InMemoryStore(payloads_1k)
    cache = CappedCache(max_items=8)
    ds = CachingDataset(store, cache, insert_on_miss=True)
    r = ds.get(1)
    assert not r.hit
    r = ds.get(1)
    assert r.hit
    assert ds.hits == 1 and ds.misses == 1


def test_caching_dataset_no_insert_when_prefetcher_owns_population(payloads_1k):
    store = InMemoryStore(payloads_1k)
    cache = CappedCache(max_items=8)
    ds = CachingDataset(store, cache, insert_on_miss=False)
    ds.get(1)
    assert not cache.contains(1)  # §IV-C: the worker does not insert


def test_prefetch_service_populates_cache(payloads_1k):
    store = SimulatedBucketStore(payloads_1k, clock=FAST)
    cache = CappedCache(max_items=64)
    with PrefetchService(store, cache, clock=FAST) as svc:
        svc.request(list(range(32)))
        assert svc.drain(timeout=30)
    assert all(cache.contains(i) for i in range(32))
    assert svc.rounds_completed == 1
    assert svc.samples_fetched == 32


def test_prefetch_service_skips_already_cached(payloads_1k):
    store = SimulatedBucketStore(payloads_1k, clock=FAST)
    cache = CappedCache(max_items=64)
    cache.put(0, payloads_1k[0])
    with PrefetchService(store, cache, clock=FAST) as svc:
        svc.request([0, 1])
        svc.drain(timeout=30)
    assert store.stats.class_b_requests == 1  # only object 1 fetched


def test_prefetch_hedged_fast_results_are_cached(payloads_1k):
    """Regression: with hedge_after_s set AND streaming_insert on, payloads
    that resolved before the hedge deadline skipped every insert path and
    were never cached."""
    store = InMemoryStore(payloads_1k)  # resolves instantly => pre-deadline
    cache = CappedCache(max_items=64)
    with PrefetchService(
        store, cache, clock=FAST, hedge_after_s=0.5, streaming_insert=True
    ) as svc:
        svc.request(list(range(16)))
        assert svc.drain(timeout=30)
    assert all(cache.contains(i) for i in range(16))
    assert svc.hedges == 0
    for i in range(16):
        assert cache.get(i) == payloads_1k[i]


class _SlowFirstGetStore(InMemoryStore):
    """First GET of any key stalls; duplicates return instantly (straggler)."""

    def __init__(self, payloads, stall_s):
        super().__init__(payloads)
        self.stall_s = stall_s
        self._seen = set()
        self._seen_lock = __import__("threading").Lock()

    def get(self, index):
        with self._seen_lock:
            first = index not in self._seen
            self._seen.add(index)
        if first:
            import time

            time.sleep(self.stall_s)
        return super().get(index)


@pytest.mark.slow  # threaded, real-clock stall
def test_prefetch_hedged_straggler_cached_exactly_once(payloads_1k):
    store = _SlowFirstGetStore(payloads_1k, stall_s=0.3)
    cache = CappedCache(max_items=64)
    with PrefetchService(
        store, cache, clock=FAST, hedge_after_s=0.02, streaming_insert=True
    ) as svc:
        svc.request([0, 1])
        assert svc.drain(timeout=30)
    assert svc.hedges == 2
    assert cache.contains(0) and cache.contains(1)
    assert cache.stats.inserts == 2  # exactly once per payload
    assert cache.get(0) == payloads_1k[0]


def test_listing_cache_collapses_class_a(payloads_1k):
    store = SimulatedBucketStore(payloads_1k, clock=FAST)
    lc = ListingCache(clock=FAST)
    for _ in range(5):
        lc.list(store)
    assert lc.lists_issued == 1
    assert lc.lists_served_from_cache == 4
    assert store.stats.class_a_requests == 1


def _make_loader(payloads, cfg, world=1, rank=0, batch=16):
    store = SimulatedBucketStore(payloads, clock=FAST)
    cache = CappedCache(max_items=cfg.cache_items) if cfg.cache_items else CappedCache()
    svc = PrefetchService(store, cache, clock=FAST).start() if cfg.enabled else None
    ds = CachingDataset(store, cache, insert_on_miss=not cfg.enabled)
    sampler = DistributedPartitionSampler(len(payloads), rank, world, seed=0)
    return DeliLoader(ds, sampler, batch, cfg, service=svc, clock=FAST), svc


def test_loader_end_to_end_with_prefetch(payloads_1k):
    cfg = PrefetchConfig.fifty_fifty(128)
    loader, svc = _make_loader(payloads_1k, cfg)
    stats = run_epochs(loader, epochs=2)
    svc.close()
    assert [s.epoch for s in stats] == [0, 1]
    assert all(s.samples == 256 for s in stats)
    # With prefetching most accesses should be hits even in epoch 1.
    assert stats[0].miss_rate < 0.8
    assert stats[0].hits + stats[0].misses == stats[0].samples


def test_loader_batches_and_len(payloads_1k):
    cfg = PrefetchConfig.disabled()
    loader, _ = _make_loader(payloads_1k, cfg, batch=32)
    loader.set_epoch(0)
    batches = list(loader)
    assert len(batches) == len(loader) == 8
    assert all(len(b.indices) == 32 for b in batches)
    seen = [i for b in batches for i in b.indices]
    assert sorted(seen) == sorted(payloads_1k)


def test_loader_payload_integrity(payloads_1k):
    """Samples coming through cache+prefetch are byte-identical to source."""
    cfg = PrefetchConfig.fifty_fifty(64)
    loader, svc = _make_loader(payloads_1k, cfg)
    loader.set_epoch(0)
    for b in loader:
        for idx, payload in zip(b.indices, b.payloads):
            assert payload == payloads_1k[idx]
    svc.close()


def test_loader_checkpoint_resume(payloads_1k):
    """Mid-epoch resume yields exactly the not-yet-consumed remainder."""
    cfg = PrefetchConfig.disabled()
    loader, _ = _make_loader(payloads_1k, cfg, batch=16)
    loader.set_epoch(0)
    it = iter(loader)
    first = [next(it) for _ in range(4)]
    state = loader.state_dict()
    assert state["epoch"] == 0 and state["cursor"] == 64
    assert state["history"] == []  # epoch 0 not finished yet
    # New loader (fresh process) restores and finishes the epoch.
    loader2, _ = _make_loader(payloads_1k, cfg, batch=16)
    loader2.load_state_dict(state)
    rest = list(loader2)
    consumed = [i for b in first + rest for i in b.indices]
    assert sorted(consumed) == sorted(payloads_1k)
    assert len(consumed) == len(set(consumed))


def test_loader_state_dict_preserves_epoch_history(payloads_1k):
    """ISSUE 2 satellite: the seed dropped ``epoch_history`` across a
    checkpoint restore; resumed runs must report the full trajectory."""
    import json

    cfg = PrefetchConfig.disabled()
    loader, _ = _make_loader(payloads_1k, cfg)
    run_epochs(loader, epochs=2)
    state = loader.state_dict()
    assert len(state["history"]) == 2
    # Fresh loader (new process) restores the whole trajectory.
    loader2, _ = _make_loader(payloads_1k, cfg)
    loader2.load_state_dict(state)
    assert [s.epoch for s in loader2.epoch_history] == [0, 1]
    assert loader2.epoch_history[0].samples == 256
    assert loader2.epoch_history[1].tier_hits == loader.epoch_history[1].tier_hits
    assert loader2.epoch_history[1].miss_rate == loader.epoch_history[1].miss_rate
    # The checkpoint manifest is JSON; the state must round-trip through it.
    loader3, _ = _make_loader(payloads_1k, cfg)
    loader3.load_state_dict(json.loads(json.dumps(state)))
    assert loader3.epoch_history[1].hits == loader.epoch_history[1].hits
    # Legacy (pre-history) checkpoints: accumulated stats are kept as-is.
    loader3.load_state_dict({"epoch": 1, "cursor": 0})
    assert len(loader3.epoch_history) == 2


class _SynchronousService(PrefetchService):
    """Deterministic service: every announced round completes before the
    announcing call returns (removes the thread-scheduling race so Class B
    accounting is exact on a virtual clock)."""

    def request(self, keys, stats=None, replay=False):
        req = super().request(keys, stats=stats, replay=replay)
        assert self.drain(timeout=30)
        return req


def test_mid_epoch_resume_with_prefetch_exact_class_b(payloads_1k):
    """ISSUE 2 satellite: a mid-epoch state_dict/load_state_dict round trip
    with prefetching enabled replays the announced rounds on resume without
    double-counting ``EpochStats.samples`` and without re-issuing Class B
    GETs (replayed rounds are fully cache-resident and filtered out)."""
    from repro.core import VirtualClock

    clock = VirtualClock()
    store = SimulatedBucketStore(payloads_1k, clock=clock)
    cache = CappedCache()  # unlimited: interrupted-epoch fetches stay resident
    cfg = PrefetchConfig.fifty_fifty(64)
    svc = _SynchronousService(store, cache, clock=clock, list_every_fetch=False).start()
    ds = CachingDataset(store, cache, insert_on_miss=False)

    def fresh_loader():
        sampler = DistributedPartitionSampler(len(payloads_1k), 0, 1, seed=0)
        return DeliLoader(ds, sampler, 16, cfg, service=svc, clock=clock)

    loader = fresh_loader()
    loader.set_epoch(0)
    it = iter(loader)
    first = [next(it) for _ in range(4)]
    state = loader.state_dict()
    it.close()  # simulated crash mid-epoch

    loader2 = fresh_loader()  # restart: cache/store/service survive on-node
    loader2.load_state_dict(state)
    rest = list(loader2)
    svc.close()
    consumed = [i for b in first + rest for i in b.indices]
    assert sorted(consumed) == sorted(payloads_1k)
    assert len(consumed) == len(set(consumed))
    # No double-counted samples: the resumed epoch stats cover exactly the
    # remainder, and partial + remainder == the partition.
    s = loader2.last_epoch_stats
    assert s.samples == 256 - 64
    assert sum(len(b.indices) for b in first) + s.samples == 256
    # Announced rounds were replayed, but every replayed key was already
    # cached: each object was fetched from the bucket exactly once.
    assert store.stats.class_b_requests == len(payloads_1k)
    assert svc.samples_fetched == len(payloads_1k)


def test_mid_epoch_resume_batch_schedule_alignment_and_no_rebilling(payloads_1k):
    """ISSUE 4 satellite: the sample-granular ``_resume_cursor`` under the
    per-batch allreduce schedule.  A resume landing *inside* a gradient
    batch must (a) complete that partial batch at its TRUE epoch boundary
    — the batch counter resumes at ``cursor % batch_size``, so the partial
    batch reaches exactly one allreduce point instead of re-spanning a
    full batch from the resume offset — and (b) not re-issue the replayed
    rounds' Class B GETs or per-round listings (the lock-step service
    filters cache-resident keys from ``replay`` rounds).  Pinned against a
    crash-free control run: the crashed+resumed run bills identical Class
    A/B totals and hits the identical batch boundaries."""
    from repro.core import (
        DEFAULT_NETWORK,
        STEP_BATCH_END,
        LockstepPrefetchService,
        VirtualClock,
    )

    BATCH, CURSOR = 16, 70  # 70 % 16 == 6: the checkpoint is mid-batch
    cfg = PrefetchConfig.fifty_fifty(64)

    def build():
        clock = VirtualClock()
        store = SimulatedBucketStore(payloads_1k, clock=clock)
        cache = CappedCache()  # unlimited: interrupted fetches stay resident
        svc = LockstepPrefetchService(
            cache,
            sample_bytes=1024,
            n_samples=len(payloads_1k),
            bucket=store.model,
            network=DEFAULT_NETWORK,
            store_stats=store.stats,
            payload_for=payloads_1k.__getitem__,
            clock=clock,
        )
        ds = CachingDataset(store, cache, insert_on_miss=False)

        def loader():
            sampler = DistributedPartitionSampler(len(payloads_1k), 0, 1, seed=0)
            return DeliLoader(ds, sampler, BATCH, cfg, service=svc, clock=clock)

        return store, svc, loader

    def drive(loader, limit=None):
        """step_epoch drive collecting (samples_consumed, batch_end) marks."""
        signals = []
        gen = loader.step_epoch()
        for sig in gen:
            signals.append(sig)
            if limit is not None and len(signals) >= limit:
                gen.close()
                break
        return signals

    # Control: one uninterrupted epoch.
    store_a, svc_a, make_a = build()
    ctl = make_a()
    ctl.set_epoch(0)
    ctl_signals = drive(ctl)
    ctl_boundaries = [i for i, s in enumerate(ctl_signals) if s == STEP_BATCH_END]

    # Crash at sample CURSOR (mid-batch), then resume in a fresh loader.
    store_b, svc_b, make_b = build()
    first = make_b()
    first.set_epoch(0)
    drive(first, limit=CURSOR)
    svc_b.advance_to(float("1e12"))  # restart gap: in-flight rounds land
    second = make_b()
    second.load_state_dict({"epoch": 0, "cursor": CURSOR})
    res_signals = drive(second)

    # (a) Partial-batch alignment: the first allreduce point of the resumed
    # run is the true boundary (sample 80 => 10 post-resume events), and
    # every later boundary matches the control's grid shifted by CURSOR.
    boundaries = [i for i, s in enumerate(res_signals) if s == STEP_BATCH_END]
    assert boundaries[0] == (BATCH - CURSOR % BATCH) - 1
    assert [b + CURSOR for b in boundaries] == [
        b for b in ctl_boundaries if b >= CURSOR
    ]
    assert len(boundaries) == len(payloads_1k) // BATCH - CURSOR // BATCH
    # No double-counted samples and exactly the remainder accounted.
    s = second.last_epoch_stats
    assert s.samples == len(payloads_1k) - CURSOR

    # (b) No re-billed traffic: identical Class A/B to the crash-free run.
    assert store_b.stats.class_b_requests == store_a.stats.class_b_requests
    assert store_b.stats.class_a_requests == store_a.stats.class_a_requests
    assert svc_b.samples_fetched == svc_a.samples_fetched == len(payloads_1k)
