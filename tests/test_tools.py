"""Tools-level coverage: tools/check_docs_links.py unit paths (previously
untested) and the repo-level parity-lint gate self-checks — the real tree
scans clean against the committed baseline, every declared mirror pair
verifies, and the baseline never hides a mirror-drift finding.
"""
import importlib.util
import json
import pathlib

from repro.analysis.cli import main as lint_main
from repro.analysis.cli import run_analysis
from repro.analysis.findings import Baseline
from repro.analysis.mirrors import check_mirrors, scan_mirror_regions
from repro.core.types import EpochStats, RunStats, sequential_sum

REPO = pathlib.Path(__file__).resolve().parent.parent
BASELINE = REPO / "tools" / "parity_lint_baseline.json"


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs_links", REPO / "tools" / "check_docs_links.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# -- check_docs_links: target extraction -------------------------------------
def test_targets_skips_external_links_and_pure_anchors():
    cdl = _load_checker()
    text = (
        "[paper](https://arxiv.org/abs/2108.06322) "
        "[mail](mailto:x@y.z) [sec](#parity) [real](PARITY.md)"
    )
    assert list(cdl.targets_in(text)) == [("PARITY.md", "link")]


def test_targets_strips_anchor_from_file_links():
    cdl = _load_checker()
    assert list(cdl.targets_in("[s](ARCHITECTURE.md#layer-map)")) == [
        ("ARCHITECTURE.md", "link")
    ]


def test_targets_code_paths_need_path_suffix():
    cdl = _load_checker()
    # dotted module names and extension-less pseudo-paths stay prose
    text = "`src/repro/core/loader.py` and `repro.pipeline` and `a/b`"
    assert list(cdl.targets_in(text)) == [
        ("src/repro/core/loader.py", "code-path"),
    ]


# -- check_docs_links: resolution against a tmp tree -------------------------
def _tmp_repo(tmp_path, readme: str, extra=()):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(readme)
    for rel in extra:
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text("x")
    return tmp_path


def test_check_reports_missing_file(tmp_path, monkeypatch):
    cdl = _load_checker()
    monkeypatch.setattr(cdl, "REPO", _tmp_repo(tmp_path, "[gone](missing.md)"))
    broken = cdl.check()
    assert len(broken) == 1
    assert "missing.md" in broken[0] and "README.md" in broken[0]


def test_check_resolves_relative_links_and_repo_relative_code_paths(
    tmp_path, monkeypatch
):
    cdl = _load_checker()
    readme = "[d](docs/GUIDE.md) and `src/mod.py` and [ext](https://x.y) and [a](#top)"
    monkeypatch.setattr(
        cdl, "REPO", _tmp_repo(tmp_path, readme, extra=["docs/GUIDE.md", "src/mod.py"])
    )
    assert cdl.check() == []
    # code-paths resolve repo-relative even when mentioned inside docs/
    (tmp_path / "docs" / "GUIDE.md").write_text("`src/mod.py` `src/nope.py`")
    broken = cdl.check()
    assert len(broken) == 1 and "src/nope.py" in broken[0]


def test_check_flags_absolute_paths(tmp_path, monkeypatch):
    cdl = _load_checker()
    monkeypatch.setattr(cdl, "REPO", _tmp_repo(tmp_path, "[abs](/etc/hosts)"))
    broken = cdl.check()
    assert len(broken) == 1 and "absolute path" in broken[0]


def test_main_exit_codes(tmp_path, monkeypatch, capsys):
    cdl = _load_checker()
    monkeypatch.setattr(cdl, "REPO", _tmp_repo(tmp_path, "[ok](docs/)"))
    assert cdl.main() == 0
    (tmp_path / "README.md").write_text("[gone](missing.md)")
    assert cdl.main() == 1
    assert "BROKEN" in capsys.readouterr().err


# -- parity-lint: the real tree ----------------------------------------------
def test_repo_scans_clean_against_committed_baseline():
    findings = run_analysis(REPO)
    new, _stale = Baseline.load(BASELINE).filter(findings)
    assert new == [], "\n".join(f.render() for f in new)


def test_cli_gate_exits_zero_on_repo():
    assert lint_main(["--root", str(REPO), "--baseline", str(BASELINE)]) == 0


def test_all_declared_mirror_pairs_verify():
    regions = []
    for sub in ("src", "tests", "tools"):
        for path in sorted((REPO / sub).rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            rs, fs = scan_mirror_regions(path, path.relative_to(REPO).as_posix())
            regions += rs
            assert fs == []
    names = sorted({r.name for r in regions})
    # the five pairs ISSUE 9 annotates; each must have exactly two halves
    assert names == [
        "oracle-cursor",
        "overlap-build",
        "placement-install",
        "substep-build",
        "sync-to",
    ]
    for name in names:
        assert sum(1 for r in regions if r.name == name) == 2, name
    assert check_mirrors(regions) == []


def test_baseline_contains_no_mirror_drift_entries():
    # CI self-check (ISSUE 9): mirror drift can never be baselined away —
    # a drifted mirror is always a build failure, not an accepted exception.
    data = json.loads(BASELINE.read_text())
    assert data["entries"], "baseline exists and documents its exceptions"
    assert all(e["rule"] != "mirror-drift" for e in data["entries"])
    assert all(e.get("reason") for e in data["entries"])


# -- pins for the PL003 fixes ------------------------------------------------
def test_sequential_sum_matches_left_to_right_fold():
    xs = [0.1, 0.2, 0.3, 1e-9, 7.7, 0.1]
    acc = 0.0
    for x in xs:
        acc += x
    assert sequential_sum(xs) == acc
    assert sequential_sum([]) == 0.0


def test_run_stats_means_are_sequential_folds():
    rows = []
    for n, (h, w) in enumerate(zip([3, 7, 5], [0.1, 0.25, 1e-9])):
        r = EpochStats(epoch=0, node=n, samples=10)
        r.record("ram", h)
        r.record("bucket", 10 - h)
        r.data_wait_seconds = w
        rows.append(r)
    stats = RunStats(epochs=rows)
    acc_mr = 0.0
    for r in rows:
        acc_mr += r.miss_rate
    acc_w = 0.0
    for r in rows:
        acc_w += r.data_wait_seconds
    assert stats.mean_miss_rate(0) == acc_mr / 3
    assert stats.mean_data_wait(0) == acc_w / 3
    assert stats.total_data_wait() == acc_w
    assert RunStats(epochs=[]).mean_miss_rate(0) == 0.0
