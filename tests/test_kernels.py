"""Pallas kernel validation: interpret=True on CPU, shape/dtype sweeps,
assert_allclose against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # XLA-compile-heavy; excluded from the smoke lane

from repro.kernels import ops, ref

_ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _qkv(key, B, Sq, Sk, H, KV, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,hd,block",
    [
        (1, 128, 4, 4, 64, 64),   # MHA, one block row
        (2, 256, 8, 2, 32, 64),   # GQA 4:1
        (1, 384, 6, 1, 16, 128),  # MQA, uneven blocks (384 = 3x128)
        (2, 96, 4, 2, 64, 32),    # small seq, multiple blocks
    ],
)
def test_flash_attention_causal(B, S, H, KV, hd, block, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, S, H, KV, hd, dtype)
    out = ops.flash_attention(
        q, k, v, causal=True, block_q=block, block_k=block, interpret=True
    )
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_ATOL[dtype], rtol=_ATOL[dtype],
    )


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_attention_sliding_window(window):
    B, S, H, KV, hd = 2, 256, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(1), B, S, S, H, KV, hd, jnp.float32)
    out = ops.flash_attention(
        q, k, v, causal=True, window=window, block_q=64, block_k=64, interpret=True
    )
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=2e-5, rtol=2e-5
    )


def test_flash_attention_padded_seq():
    """Sq not a block multiple exercises the pad/mask path."""
    B, S, H, KV, hd = 1, 200, 4, 4, 32
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, S, H, KV, hd, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=2e-5, rtol=2e-5
    )


def test_flash_attention_noncausal_encoder():
    B, S, H, KV, hd = 2, 128, 4, 4, 64
    q, k, v = _qkv(jax.random.PRNGKey(3), B, S, S, H, KV, hd, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=2e-5, rtol=2e-5
    )


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
def _ssd_inputs(key, B, S, H, P, G, N, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, G, N), jnp.float32)
    D = jnp.ones((H,), jnp.float32)
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,G,N,chunk",
    [
        (1, 64, 2, 16, 1, 16, 16),   # minimal
        (2, 128, 4, 32, 2, 16, 32),  # grouped B/C
        (1, 96, 3, 16, 1, 32, 32),   # odd head count, 3 chunks
    ],
)
def test_ssd_scan(B, S, H, P, G, N, chunk, dtype):
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(4), B, S, H, P, G, N, dtype)
    y, st = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    y_ref, st_ref = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(
        np.asarray(st, np.float32), np.asarray(st_ref, np.float32), atol=tol, rtol=tol
    )


def test_ssd_kernel_matches_model_chunked_path():
    """Kernel vs the model's XLA chunked implementation (not just the
    sequential oracle) — the two production paths must agree."""
    from repro.models.ssm import ssd_chunked

    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(5), 2, 128, 4, 32, 2, 16, jnp.float32)
    y_k, st_k = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=32, interpret=True)
    y_m, st_m = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=32)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_m, np.float32), atol=1e-4, rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(st_k, np.float32), np.asarray(st_m, np.float32), atol=1e-4, rtol=1e-4
    )


def test_model_forward_with_pallas_kernels_matches_xla():
    """End-to-end: a reduced hybrid model (attention + SSD layers) with
    use_pallas=True must match the XLA reference path."""
    import dataclasses

    from repro import configs
    from repro.models import model as M

    base = configs.reduce_for_smoke(configs.get("jamba-1.5-large-398b"))
    base = dataclasses.replace(base, dtype="float32", capacity_factor=16.0)
    kcfg = dataclasses.replace(base, use_pallas=True)
    key = jax.random.PRNGKey(7)
    params = M.init_params(key, base)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0, base.vocab)}
    h_x, _ = M.forward(params, base, batch, remat=False)
    h_k, _ = M.forward(params, kcfg, batch, remat=False)
    np.testing.assert_allclose(
        np.asarray(h_x, np.float32), np.asarray(h_k, np.float32), atol=2e-3, rtol=2e-3
    )
