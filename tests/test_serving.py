"""Serving engine integration: batched generate vs manual prefill+decode,
determinism, and SSM/hybrid cache handling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # XLA-compile-heavy; excluded from the smoke lane

from repro import configs
from repro.models import model as M
from repro.serving import ServeEngine


def _engine(arch, seed=0):
    cfg = configs.reduce_for_smoke(configs.get(arch))
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=16.0)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params, ServeEngine(cfg, params, max_len=64)


@pytest.mark.parametrize("arch", ["internlm2-20b", "mamba2-130m", "jamba-1.5-large-398b"])
def test_generate_matches_manual_decode(arch):
    cfg, params, engine = _engine(arch)
    B, L, N = 2, 16, 6
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab)
    )
    res = engine.generate([list(map(int, p)) for p in prompts], max_new_tokens=N)
    assert all(len(t) == N for t in res.tokens)

    # manual loop: prefill then N-1 greedy decode steps
    logits, (caches, kv_len) = M.prefill(params, cfg, {"tokens": jnp.asarray(prompts)})
    caches = {
        pos: {k: (jnp.pad(v, ((0, 0), (0, 0), (0, N), (0, 0), (0, 0)))
                  if k in ("k", "v") else v)
              for k, v in sub.items()}
        for pos, sub in caches.items()
    }
    state = (caches, kv_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    manual = [np.asarray(tok[:, 0])]
    for step in range(N - 1):
        logits, state = M.decode_step(params, cfg, tok, state, jnp.int32(L + step))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        manual.append(np.asarray(tok[:, 0]))
    manual = np.stack(manual, 1)  # (B, N)
    got = np.asarray(res.tokens)
    np.testing.assert_array_equal(got, manual)


def test_generate_rejects_ragged_prompts():
    _, _, engine = _engine("internlm2-20b")
    with pytest.raises(ValueError):
        engine.generate([[1, 2, 3], [1, 2]], max_new_tokens=2)


def test_encoder_has_no_engine():
    cfg = configs.reduce_for_smoke(configs.get("hubert-xlarge"))
    with pytest.raises(ValueError):
        ServeEngine(cfg, {}, max_len=8)
