"""Shared fixtures for the DELI-JAX test suite.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benchmarks must see the real single CPU device.  Only
``launch/dryrun.py`` (and tests that exec it as a subprocess) use the
512-device placeholder mesh.
"""
import os
import sys

# Make `src/` importable regardless of how pytest is invoked.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import pytest  # noqa: E402


@pytest.fixture
def tmp_store_dir(tmp_path):
    return str(tmp_path / "store")


@pytest.fixture
def payloads_1k():
    from repro.core import make_synthetic_payloads

    return make_synthetic_payloads(n=256, sample_bytes=1024, seed=7)
