"""Integration: Trainer over the real threaded DELI pipeline — loss falls,
checkpoint/restore resumes exactly, elastic re-partitioning works."""
import tempfile

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # XLA-compile-heavy; excluded from the smoke lane

from repro.core import PrefetchConfig, RealClock
from repro.data import decode_tokens, make_lm_spec
from repro.models.config import ArchConfig
from repro.training import checkpoint as ckpt
from repro.training.loop import Trainer, TrainerConfig, elastic_repartition
from repro.training.optimizer import OptSettings

SEQ, CACHE, BATCH = 64, 128, 4
CFG = ArchConfig(
    name="lm-test", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, dtype="float32", attn_chunk=64,
)


def _trainer(ckpt_dir=None, every=5, n_samples=512):
    # ISSUE 4 satellite: the trainer's pipeline comes from the declarative
    # LM spec (make_lm_pipeline folded into DataPlaneSpec).
    spec = make_lm_spec(
        n_samples=n_samples, seq_len=SEQ, vocab=CFG.vocab, batch_size=BATCH,
        cache_items=CACHE, policy=PrefetchConfig.fifty_fifty(CACHE),
    )
    cluster = spec.build_runtime(clock=RealClock())
    loader, service = cluster.loaders[0], cluster.services[0]
    t = Trainer(
        CFG, loader,
        TrainerConfig(seq_len=SEQ, batch_size=BATCH, checkpoint_dir=ckpt_dir,
                      checkpoint_every=every, log_every=1000),
        decode_fn=decode_tokens,
        settings=OptSettings(lr=3e-3, moment_dtype="float32"),
    )
    return t, service


def test_loss_decreases_through_deli_pipeline():
    t, svc = _trainer()
    with svc:
        metrics = t.train(30)
    assert len(metrics) == 30
    first = np.mean([m.loss for m in metrics[:5]])
    last = np.mean([m.loss for m in metrics[-5:]])
    assert last < first, (first, last)
    assert all(np.isfinite(m.loss) for m in metrics)


def test_checkpoint_restore_resumes_exactly():
    d = tempfile.mkdtemp()
    t1, svc1 = _trainer(ckpt_dir=d, every=5)
    with svc1:
        t1.train(12)
    assert ckpt.latest_step(d) == 10

    t2, svc2 = _trainer(ckpt_dir=d, every=5)
    assert t2.try_restore()
    assert t2.step == 10
    # params match the checkpointed run bit-exactly
    p1 = jax.tree.leaves(
        ckpt.restore_checkpoint(d, 10)[0]
    )
    p2 = jax.tree.leaves(t2.params)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(a, np.float32).ravel(),
                                      np.asarray(b, np.float32).ravel())
    with svc2:
        t2.train(3)
    assert t2.step == 13


def test_checkpoint_atomic_and_gc():
    d = tempfile.mkdtemp()
    t, svc = _trainer(ckpt_dir=d, every=2)
    t.tcfg = TrainerConfig(seq_len=SEQ, batch_size=BATCH, checkpoint_dir=d,
                           checkpoint_every=2, keep_checkpoints=2, log_every=1000)
    t._ckpt.keep = 2  # the AsyncCheckpointer captured keep at __init__
    with svc:
        t.train(10)
    steps = ckpt.list_steps(d)
    assert len(steps) <= 2 and steps[-1] == 10  # gc keeps the latest


def test_elastic_repartition_halves_partition():
    t, svc = _trainer(n_samples=512)
    with svc:
        t.train(3)
    assert len(t.loader.sampler) == 512
    elastic_repartition(t.loader, new_rank=1, new_world=2)
    assert len(t.loader.sampler) == 256
    assert t.loader.sampler.rank == 1
    with svc:
        t.train(3)  # keeps training on the new partition
    assert t.step == 6
