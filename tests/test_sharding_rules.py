"""Sharding rules: property tests (hypothesis) for the divisibility-aware
PartitionSpec construction, plus per-arch full-config spec validity."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed.sharding import (
    ShardingRules,
    kv_cache_spec,
    ssm_state_spec,
)
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M

RULES = ShardingRules(make_smoke_mesh(1, 1), fsdp_axes=("data",))


def _mesh_sizes(rules, spec_axes):
    n = 1
    for a in spec_axes or ():
        n *= rules.mesh.shape[a]
    return n


class FakeRules(ShardingRules):
    """ShardingRules over a fake mesh shape dict (no devices needed)."""

    def __init__(self, data, model):
        class FakeMesh:
            shape = {"data": data, "model": model}
            axis_names = ("data", "model")

        object.__setattr__(self, "mesh", FakeMesh())
        object.__setattr__(self, "fsdp_axes", ("data",))
        object.__setattr__(self, "model_axis", "model")
        object.__setattr__(self, "fsdp_params", True)


@settings(max_examples=200, deadline=None)
@given(
    data=st.sampled_from([1, 2, 4, 8, 16, 32, 256]),
    model=st.sampled_from([1, 2, 4, 8, 16]),
    batch=st.integers(1, 512),
    seq=st.sampled_from([1, 128, 4096, 32768, 524288]),
    kv=st.sampled_from([1, 2, 7, 8, 16, 24, 56]),
)
def test_kv_cache_spec_every_axis_divides(data, model, batch, seq, kv):
    """Every sharded dim of the KV-cache spec must be divisible by the
    product of its assigned axis sizes, and no mesh axis may appear twice."""
    rules = FakeRules(data, model)
    spec = kv_cache_spec(rules, batch, seq, kv)
    dims = (batch, seq, kv, 128)
    seen = []
    for dim, axes in zip(dims, spec):
        if axes is None:
            continue
        axes = axes if isinstance(axes, tuple) else (axes,)
        seen += list(axes)
        n = 1
        for a in axes:
            n *= rules.mesh.shape[a]
        assert dim % n == 0, (dim, axes)
    assert len(seen) == len(set(seen)), spec


@settings(max_examples=200, deadline=None)
@given(
    data=st.sampled_from([1, 4, 16, 32, 256]),
    model=st.sampled_from([1, 4, 16]),
    batch=st.integers(1, 512),
    heads=st.sampled_from([1, 3, 24, 64, 128, 256]),
)
def test_ssm_state_spec_every_axis_divides(data, model, batch, heads):
    rules = FakeRules(data, model)
    spec = ssm_state_spec(rules, batch, heads)
    dims = (batch, heads, 64, 128)
    seen = []
    for dim, axes in zip(dims, spec):
        if axes is None:
            continue
        axes = axes if isinstance(axes, tuple) else (axes,)
        seen += list(axes)
        n = 1
        for a in axes:
            n *= rules.mesh.shape[a]
        assert dim % n == 0, (dim, axes)
    assert len(seen) == len(set(seen)), spec


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_specs_divide_for_all_archs(arch):
    """For every full-size arch: every sharded param dim divides by its
    assigned axes on the production mesh shape (16 x 16)."""
    cfg = configs.get(arch)
    rules = FakeRules(16, 16)
    shapes = M.param_shapes(cfg)

    # param_shardings builds NamedShardings (needs a real mesh) — use the
    # internal spec function instead.
    from repro.distributed.sharding import _leaf_spec, _tree_paths

    for path, leaf in _tree_paths(shapes):
        spec = _leaf_spec(rules, cfg, path, leaf)
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, axes in zip(leaf.shape, spec):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            n = 1
            for a in axes:
                n *= rules.mesh.shape[a]
            assert dim % n == 0, (arch, path, dim, axes)
