"""Launch-path integration under pytest (1 CPU device): step_for_cell +
input_specs + jit lowering work end to end for reduced configs on a 1x1
mesh.  (The full 512-device production meshes are exercised by
launch/dryrun.py, which must own the process to set XLA_FLAGS first.)"""

import jax
import pytest

from repro import configs
from repro.distributed.sharding import ShardingRules, param_shardings
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import step_for_cell
from repro.models import model as M
from repro.models.config import ShapeConfig
from repro.training.optimizer import OptSettings, opt_state_shapes

SMALL_TRAIN = ShapeConfig("train_small", 128, 4, "train")
SMALL_DECODE = ShapeConfig("decode_small", 128, 4, "decode")


def _structs(shapes, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shapes, shardings,
    )


@pytest.mark.parametrize("arch", ["internlm2-20b", "phi3.5-moe-42b-a6.6b", "mamba2-130m"])
@pytest.mark.parametrize("shape", [SMALL_TRAIN, SMALL_DECODE])
def test_lower_reduced_cell_on_smoke_mesh(arch, shape):
    cfg = configs.reduce_for_smoke(configs.get(arch))
    mesh = make_smoke_mesh(1, 1)
    rules = ShardingRules(mesh, fsdp_axes=("data",))
    pshapes = M.param_shapes(cfg)
    pshard = param_shardings(rules, cfg, pshapes)
    step, takes_opt, n_micro = step_for_cell(cfg, shape, rules, microbatches=2)
    args = list(input_specs(cfg, shape, rules))
    if takes_opt:
        st = OptSettings.auto(cfg.param_count())
        oshapes = opt_state_shapes(pshapes, st)
        oshard = {
            "m": pshard, "v": pshard,
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        args = [_structs(pshapes, pshard), _structs(oshapes, oshard)] + args
    else:
        args = [_structs(pshapes, pshard)] + args
    with mesh:
        lowered = jax.jit(step).lower(*args)
    assert "module" in lowered.as_text()[:200] or lowered.as_text()
