"""ISSUE 7 tentpole: cluster clairvoyant placement — the cross-rank
ClusterPlacementPlanner, ownership-partitioned prefetch, the shared
in-flight set, cost-aware round sizing, oracle-guided spill ordering, and
exact sim/runtime parity for placement specs."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (
    MNIST,
    CappedCache,
    DistributedPartitionSampler,
    SimConfig,
    straggler_profiles,
)
from repro.core.bandwidth import DEFAULT_BUCKET, DEFAULT_PIPELINE
from repro.core.sampler import SharedShuffleSampler
from repro.core.types import SampleKey
from repro.oracle import (
    ClusterPlacementPlanner,
    NodeAccessView,
    OraclePrefetchPlanner,
    OracleSpillOrder,
    PlacementPrefetchPlanner,
    RoundCostModel,
    planner_for,
)
from repro.pipeline import DataPlaneSpec, assert_parity, condition


def _samplers(n, world, seed, shared=False):
    cls = SharedShuffleSampler if shared else DistributedPartitionSampler
    out = [cls(n, rank=r, world=world, seed=seed) for r in range(world)]
    for s in out:
        s.set_epoch(0)
    return out


# ---------------------------------------------------------------------------
# Ownership partition invariants (the tentpole's plan).
# ---------------------------------------------------------------------------
@settings(max_examples=15)
@given(
    n=st.integers(min_value=6, max_value=120),
    world=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=10_000),
    epoch=st.integers(min_value=0, max_value=3),
    shared=st.sampled_from([False, True]),
)
def test_exactly_one_owner_per_key(n, world, seed, epoch, shared):
    """Each key in the union of the epoch's orders appears in exactly ONE
    rank's owned set, and the union of owned sets covers every key."""
    planner = ClusterPlacementPlanner(_samplers(n, world, seed, shared))
    owned = planner.owned_sets(epoch)
    union = set()
    for rank, keys in enumerate(owned):
        assert not (union & keys), f"rank {rank} re-owns {union & keys}"
        union |= keys
    accessed = set()
    for order in planner.epoch_orders(epoch):
        accessed |= set(order)
    assert union == accessed


@settings(max_examples=15)
@given(
    n=st.integers(min_value=6, max_value=120),
    world=st.integers(min_value=2, max_value=4),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_owner_first_use_is_the_cluster_earliest(n, world, seed):
    """The owner of a key is the rank whose first use of it is the
    cluster-wide earliest (ties to the lowest rank)."""
    planner = ClusterPlacementPlanner(_samplers(n, world, seed, shared=True))
    orders = planner.epoch_orders(0)
    owned = planner.owned_sets(0)
    firsts = [{k: p for p, k in reversed(list(enumerate(o)))} for o in orders]
    for rank, keys in enumerate(owned):
        for k in keys:
            mine = firsts[rank][k]
            for other in range(world):
                if k not in firsts[other]:
                    continue
                theirs = firsts[other][k]
                assert (mine, rank) <= (theirs, other)


@settings(max_examples=10)
@given(
    n=st.integers(min_value=6, max_value=90),
    world=st.integers(min_value=2, max_value=3),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_owner_announces_before_cluster_first_use_uncapped(n, world, seed):
    """With no capacity cap, the owner's announce position for each owned
    key is at or before its own first use — which IS the cluster-wide
    first use — so the owning fetch is issued before any rank needs it."""
    planner = ClusterPlacementPlanner(_samplers(n, world, seed, shared=True))
    for rank in range(world):
        order = planner.epoch_orders(0)[rank]
        rank_planner = planner.planner(rank, order)
        assert isinstance(rank_planner, PlacementPrefetchPlanner)
        announced_at = {}
        for pos, (idx, round_) in enumerate(rank_planner):
            if round_ is None:
                continue
            for k in round_:
                announced_at.setdefault(k, pos)
        first = {}
        for pos, k in enumerate(order):
            first.setdefault(k, pos)
        for k in rank_planner.owned:
            assert announced_at[k] <= first[k]


def test_placement_rejects_locality_and_empty():
    from repro.core import LocalityAwareSampler

    with pytest.raises(ValueError, match="at least one sampler"):
        ClusterPlacementPlanner([])
    bad = [LocalityAwareSampler(30, rank=0, world=1, seed=0)]
    with pytest.raises(ValueError, match="replayable"):
        ClusterPlacementPlanner(bad)


def test_planner_for_requires_a_placement_for_cluster_oracle():
    with pytest.raises(ValueError, match="cluster-oracle"):
        planner_for([1, 2, 3], policy="cluster-oracle", config=None)


def test_rank_planners_share_the_in_flight_set():
    planner = ClusterPlacementPlanner(_samplers(30, 3, 0, shared=True))
    built = [
        planner.planner(r, planner.epoch_orders(0)[r]) for r in range(3)
    ]
    assert all(b.in_flight is planner.in_flight for b in built)


# ---------------------------------------------------------------------------
# Parity: placement specs stay inside the exact == domain.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["cluster-oracle", "cluster-oracle+peer-capped"])
@pytest.mark.parametrize(
    "schedule",
    [
        {},
        dict(sync="batch"),
        dict(granularity="substep"),
        dict(
            sync="batch",
            granularity="substep",
            nodes=straggler_profiles(3, (0,), 2.0, 2.0),
        ),
    ],
    ids=["epoch-step", "batch", "substep", "batch+substep+straggler"],
)
def test_placement_parity_exact(name, schedule):
    """assert_parity passes with exact == (per-tier hits, Class A+B,
    data-wait, allreduce waits) for cluster-placement specs under every
    cluster schedule — extended by sharing the implementation (the one
    ClusterPlacementPlanner + LockstepPrefetchService partition), never by
    tolerances."""
    kw = dict(schedule)
    if name == "cluster-oracle":
        kw["cache_items"] = 256
    spec = condition(name, MNIST.scaled(0.02), **kw)
    report = assert_parity(spec, epochs=2)
    assert report.sim_samples == report.runtime_samples
    assert report.sim_tiers.get("peer", 0) > 0  # the peer tier is in play


@pytest.mark.parametrize("sampler", ["partition", "shared-shuffle"])
@pytest.mark.parametrize("engine", ["scalar", "vector"])
def test_placement_parity_exact_across_samplers_and_engines(sampler, engine):
    spec = condition(
        "cluster-oracle",
        MNIST.scaled(0.02),
        sampler=sampler,
        cache_items=256,
        engine=engine,
    )
    assert_parity(spec, epochs=2)


@settings(max_examples=5)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_placement_parity_exact_seed_sweep(seed):
    spec = condition(
        "cluster-oracle",
        MNIST.scaled(0.02),
        sampler="shared-shuffle",
        cache_items=300,
        seed=seed,
    )
    assert_parity(spec, epochs=2)


def test_placement_tiny_cache_degrades_gracefully():
    """A cache far too small to hold the plan must not deadlock or starve:
    every sample is still served (deferral falls through to planned
    duplicates / demand fetches), and parity stays exact."""
    spec = condition(
        "cluster-oracle",
        MNIST.scaled(0.02),
        sampler="shared-shuffle",
        cache_items=8,
    )
    report = assert_parity(spec, epochs=2)
    w = spec.workload
    served = sum(row[2] for row in report.sim_samples)
    assert served == w.n_samples * w.n_nodes * 2


def test_placement_tiny_cache_with_stragglers_parity():
    spec = condition(
        "cluster-oracle",
        MNIST.scaled(0.02),
        cache_items=8,
        sync="batch",
        nodes=straggler_profiles(3, (1,), 2.0, 2.0),
    )
    assert_parity(spec, epochs=2)


def test_cluster_oracle_fetches_each_key_about_once():
    """The headline: cluster-wide Class B collapses from ~world x unique
    keys (every rank fetches everything) to about the unique key count —
    residual duplicates are the bounded epoch-start in-flight races."""
    w = MNIST.scaled(0.02)
    per_rank = condition(
        "oracle+peer", w, sampler="shared-shuffle", cache_items=-1
    )
    placed = condition(
        "cluster-oracle", w, sampler="shared-shuffle", cache_items=-1
    )
    _, store_pr = per_rank.build_sim().run(epochs=2)
    _, store_pl = placed.build_sim().run(epochs=2)
    unique = w.n_samples
    assert store_pl.class_b_requests < store_pr.class_b_requests
    # every key is fetched at least once, and duplicates stay within one
    # listing round (the fig14 claim, pinned here at the ample-capacity
    # regime where the plan is fully holdable)
    assert unique <= store_pl.class_b_requests <= unique + DEFAULT_BUCKET.page_size


# ---------------------------------------------------------------------------
# Satellite: cost-aware round sizing.
# ---------------------------------------------------------------------------
def _cost_model():
    return RoundCostModel.from_models(
        bucket=DEFAULT_BUCKET,
        pipeline=DEFAULT_PIPELINE,
        sample_bytes=784,
        n_connections=16,
    )


@settings(max_examples=20)
@given(
    pending=st.integers(min_value=0, max_value=512),
    cap=st.integers(min_value=1, max_value=1024),
)
def test_deadline_size_invariants(pending, cap):
    """The solved round size is within [1, cap], and whenever it exceeds 1
    its round duration fits inside the time the pending backlog buys."""
    m = _cost_model()
    size = m.deadline_size(pending, cap)
    assert 1 <= size <= cap
    budget = max(pending, 1) * m.floor_s
    if size > 1:
        assert m.round_seconds(size) <= budget
    if size < cap:  # maximality: one more key would blow the budget
        assert m.round_seconds(size + 1) > budget


def test_deadline_size_monotone_in_pending():
    m = _cost_model()
    sizes = [m.deadline_size(p, 1024) for p in range(0, 512, 17)]
    assert sizes == sorted(sizes)


def test_ramp_sizing_is_the_pinned_default():
    """sizing='ramp' (and the default) reproduce the historical doubling
    ramp schedule exactly; 'cost' changes it only through the model."""
    order = list(range(64))
    default = list(OraclePrefetchPlanner(order, capacity=16))
    ramp = list(OraclePrefetchPlanner(order, capacity=16, sizing="ramp"))
    assert default == ramp
    cfg = SimConfig(cache_items=64)
    assert cfg.round_sizing == "ramp"


def test_cost_sizing_requires_clairvoyant_policy():
    with pytest.raises(ValueError, match="clairvoyant"):
        planner_for(
            [1, 2, 3], policy="paper", config=None, sizing="cost"
        )
    with pytest.raises(ValueError, match="round_sizing"):
        SimConfig(cache_items=64, round_sizing="bogus")
    with pytest.raises(ValueError, match="clairvoyant"):
        SimConfig(cache_items=64, round_sizing="cost")  # paper policy


def test_cost_sizing_parity_and_label():
    spec = condition("oracle-cost", MNIST.scaled(0.02))
    assert spec.round_sizing == "cost"
    assert ",cost" in spec.to_sim_config().label()
    assert_parity(spec, epochs=2)


def test_cluster_oracle_cost_sizing_parity():
    spec = condition(
        "cluster-oracle", MNIST.scaled(0.02), cache_items=256, round_sizing="cost"
    )
    assert_parity(spec, epochs=2)


# ---------------------------------------------------------------------------
# Satellite: oracle-guided spill ordering.
# ---------------------------------------------------------------------------
def _keys(indices):
    return [SampleKey(index=i) for i in indices]


def test_spill_order_defaults_to_fifo_slice():
    """No view bound => the selection IS the historical FIFO slice."""
    order = OracleSpillOrder()
    keys = _keys([5, 3, 9, 1])
    assert order.select(keys, 2) == keys[:2]


def test_spill_order_prefers_farthest_future_use():
    view = NodeAccessView()
    view.begin_epoch(0, [9, 3, 5, 1])
    order = OracleSpillOrder(view)
    keys = _keys([5, 3, 9, 1])  # insertion (FIFO) order
    # next uses: 5->2, 3->1, 9->0, 1->3  => spill 1 first, then 5
    assert [k.index for k in order.select(keys, 2)] == [1, 5]


def test_spill_order_never_used_keys_spill_first_with_fifo_ties():
    view = NodeAccessView()
    view.begin_epoch(0, [4])
    order = OracleSpillOrder(view)
    keys = _keys([7, 8, 4])  # 7 and 8 are NEVER-used: spill in FIFO order
    assert [k.index for k in order.select(keys, 2)] == [7, 8]


def test_capped_cache_spill_order_hook(tmp_path):
    """CappedCache consults spill_order for WHICH payloads leave RAM; the
    oracle order keeps the soonest-needed payloads in RAM."""
    view = NodeAccessView()
    view.begin_epoch(0, [1, 2, 3])
    c = CappedCache(
        max_items=8,
        ram_items=1,
        spill_dir=str(tmp_path / "spill"),
        spill_order=OracleSpillOrder(view),
    )
    for i in (1, 2, 3):
        c.put(i, bytes([i]))
    in_ram = [k.index for k, v in c._entries.items() if v is not None]
    assert in_ram == [1]  # next_use(1)=0 is the soonest; 2 and 3 spilled
    assert c.get(2) == bytes([2])  # spilled entries still served (disk tier)


def test_capped_cache_default_spill_is_byte_pinned(tmp_path):
    """spill_order=None keeps the historical oldest-first behaviour."""
    c = CappedCache(max_items=8, ram_items=2, spill_dir=str(tmp_path / "s"))
    for i in range(5):
        c.put(i, bytes([i]))
    in_ram = [k.index for k, v in c._entries.items() if v is not None]
    assert in_ram == [3, 4]


# ---------------------------------------------------------------------------
# Spec validation and labels.
# ---------------------------------------------------------------------------
def test_cluster_oracle_spec_validation():
    w = MNIST.scaled(0.02)
    with pytest.raises(ValueError, match="peer"):
        SimConfig(cache_items=64, prefetch_policy="cluster-oracle")
    with pytest.raises(ValueError, match="locality"):
        SimConfig(
            cache_items=64,
            peer_cache=True,
            prefetch_policy="cluster-oracle",
            locality_aware=True,
        )
    cfg = condition(
        "cluster-oracle", w, cache_items=64
    ).to_sim_config()
    assert "cluster-oracle" in cfg.label()
    spec = DataPlaneSpec.from_sim_config(w, cfg)
    assert spec.prefetch_policy == "cluster-oracle"
    assert spec.round_sizing == cfg.round_sizing
