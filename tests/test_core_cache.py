"""Unit + property tests for the capped FIFO cache (paper §IV-B semantics)."""
import threading

import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: deterministic fallback sweep
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import CappedCache


def test_put_get_roundtrip():
    c = CappedCache(max_items=4)
    assert c.put(1, b"one")
    assert c.get(1) == b"one"
    assert c.get(2) is None
    assert c.stats.hits == 1 and c.stats.misses == 1


def test_put_is_idempotent_and_preserves_fifo_order():
    c = CappedCache(max_items=2)
    c.put(1, b"a")
    c.put(2, b"b")
    c.put(1, b"a2")  # no refresh: FIFO order is insertion order
    c.put(3, b"c")  # evicts 1 (oldest), not 2
    assert c.get(1) is None
    assert c.get(2) == b"b"
    assert c.get(3) == b"c"


def test_fifo_eviction_order():
    c = CappedCache(max_items=3)
    for i in range(6):
        c.put(i, bytes([i]))
    assert c.keys() == [3, 4, 5]
    assert c.stats.evictions == 3


def test_byte_capacity():
    c = CappedCache(max_bytes=10)
    c.put(1, b"aaaa")  # 4
    c.put(2, b"bbbb")  # 8
    c.put(3, b"cccc")  # 12 -> evict 1
    assert c.get(1) is None and c.get(2) is not None
    assert c.total_bytes == 8


def test_unlimited_cache_never_evicts():
    c = CappedCache()
    for i in range(1000):
        c.put(i, b"x")
    assert len(c) == 1000 and c.stats.evictions == 0


def test_session_isolation():
    """Stale entries from a previous session never hit (multi-key index)."""
    c1 = CappedCache(session="run-1")
    c1.put(1, b"old")
    c2 = CappedCache(session="run-2")
    assert c2.get(1) is None


def test_spill_tier_roundtrip(tmp_path):
    c = CappedCache(max_items=8, ram_items=2, spill_dir=str(tmp_path / "spill"))
    for i in range(6):
        c.put(i, bytes([i]) * 32)
    # Oldest 4 spilled to disk, newest 2 in RAM.
    assert c.get(0) == bytes([0]) * 32  # disk-tier hit
    assert c.stats.disk_hits >= 1
    assert c.get(5) == bytes([5]) * 32  # ram-tier hit
    assert c.stats.ram_hits >= 1


def test_spilled_entries_removed_on_eviction(tmp_path):
    spill = tmp_path / "spill"
    c = CappedCache(max_items=2, ram_items=1, spill_dir=str(spill))
    for i in range(5):
        c.put(i, b"pay")
    files = list(spill.glob("*.bin"))
    assert len(files) <= 2


def test_invalid_capacities():
    with pytest.raises(ValueError):
        CappedCache(max_items=0)
    with pytest.raises(ValueError):
        CappedCache(max_bytes=-1)


@pytest.mark.slow
def test_thread_safety_under_concurrent_put_get():
    c = CappedCache(max_items=64)
    errors = []

    def writer(base):
        try:
            for i in range(200):
                c.put(base + i, b"p" * 16)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def reader():
        try:
            for i in range(400):
                c.get(i % 256)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(k * 200,)) for k in range(4)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(c) <= 64


def test_spill_race_deleted_file_is_a_miss(tmp_path):
    """Regression: a spilled entry whose file vanished between the lock
    release and the read (concurrent insert evicted it) must be a clean
    miss, not a FileNotFoundError."""
    c = CappedCache(max_items=8, ram_items=1, spill_dir=str(tmp_path / "spill"))
    c.put(1, b"one")
    c.put(2, b"two")  # spills key 1 to disk
    import os

    os.remove(c._spill_path(c._key(1)))  # simulate the concurrent eviction
    assert c.get(1) is None
    assert c.stats.hits == 0 and c.stats.disk_hits == 0
    assert c.stats.misses == 1


@pytest.mark.slow
def test_spill_race_threaded_get_vs_evicting_puts(tmp_path):
    """Hammer the disk tier with readers while writers evict + delete spill
    files; no reader may crash, every get returns payload-or-None."""
    c = CappedCache(max_items=4, ram_items=1, spill_dir=str(tmp_path / "spill"))
    errors = []
    stop = threading.Event()

    def writer():
        try:
            i = 0
            while not stop.is_set():
                c.put(i % 64, b"w" * 8)
                i += 1
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def reader():
        try:
            for i in range(400):
                got = c.get(i % 64)
                assert got is None or got == b"w" * 8
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(2)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads[2:]:
        t.join()
    stop.set()
    for t in threads[:2]:
        t.join()
    assert not errors, errors


@given(
    cap=st.integers(min_value=1, max_value=50),
    ops=st.lists(st.integers(min_value=0, max_value=99), min_size=1, max_size=300),
)
@settings(max_examples=60, deadline=None)
def test_property_capacity_and_membership(cap, ops):
    """Invariants: size <= cap; contents match a reference FIFO simulation
    (re-inserting a currently-resident key is a no-op; re-inserting an
    evicted key is a fresh insert at the back)."""
    c = CappedCache(max_items=cap)
    model = []  # reference FIFO of resident keys
    for idx in ops:
        if idx not in model:
            model.append(idx)
            if len(model) > cap:
                model.pop(0)
        c.put(idx, b"x")
    assert len(c) <= cap
    assert c.keys() == model


@given(
    cap_bytes=st.integers(min_value=8, max_value=200),
    sizes=st.lists(st.integers(min_value=1, max_value=64), min_size=1, max_size=100),
)
@settings(max_examples=40, deadline=None)
def test_property_byte_budget_respected(cap_bytes, sizes):
    c = CappedCache(max_bytes=cap_bytes)
    for i, s in enumerate(sizes):
        c.put(i, b"z" * s)
        assert c.total_bytes <= max(cap_bytes, s)  # a single over-size entry evicts to itself
