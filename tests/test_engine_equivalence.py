"""ISSUE 6 tentpole: scalar/vector engine equivalence is EXACT ``==``.

The vector engine (``repro.engine.vector``) batches each node's
between-interaction segment into numpy array ops; the scalar engine steps
one event at a time.  Both share the per-sample cost kernel
(``repro.engine.kernels.DemandKernel``) and the vector engine accumulates
every float chain with sequential ``np.cumsum`` scans — the same rounding
as the scalar ``t += c`` chain — so the two engines must agree
bit-for-bit, with no tolerances (docs/PARITY.md), across the full
condition matrix: registry conditions x sync schedule x event granularity
x straggler profiles x samplers x seeds.

Compared exactly per run: aggregated per-tier hit counters, Class A and
Class B request counts, bytes read, and per-(epoch, node) tuples of
(samples, data-wait, compute, allreduce-wait, evictions).
"""
import dataclasses

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import MNIST, SimConfig, straggler_profiles
from repro.core.types import aggregate_tier_hits
from repro.core.workloads import WorkloadSpec
from repro.pipeline import condition

#: Registry conditions spanning every engine code path: constant-tier
#: baselines (disk source, direct bucket), demand-populated caches (FIFO
#: and Belady eviction), the paper's prefetch planner (50/50 and
#: full-fetch shapes), the clairvoyant planner (+ Belady), the
#: cache-state-dependent sampler, and peer-registry conditions (which
#: exercise the per-node scalar fallback inside a vector-engine cluster).
CONDITIONS = (
    ("disk", {}),
    ("gcp-direct", {}),
    ("cache", {"cache_items": 64}),
    ("belady-only", {"cache_items": 64}),
    ("fifty-fifty", {"cache_items": 64}),
    ("full-fetch", {"fetch_size": 64}),
    ("oracle", {"cache_items": 64}),
    ("locality", {"cache_items": 64}),
    ("cache+peer", {"cache_items": 64}),
    ("oracle+peer", {"cache_items": 64}),
)
CONDITION_NAMES = tuple(name for name, _ in CONDITIONS)
_KW = dict(CONDITIONS)

_W = MNIST.scaled(0.01)  # 600 samples, 3 nodes, batch 64 — fast but real


def _fingerprint(spec, engine, epochs=2):
    stats, store = (
        dataclasses.replace(spec, engine=engine).build_sim().run(epochs=epochs)
    )
    return (
        aggregate_tier_hits(stats),
        store.class_a_requests,
        store.class_b_requests,
        store.bytes_read,
        [
            (s.epoch, s.node, s.samples, s.data_wait_seconds,
             s.compute_seconds, s.allreduce_wait_seconds, s.evictions)
            for s in stats
        ],
    )


def _assert_engines_agree(spec, epochs=2):
    scalar = _fingerprint(spec, "scalar", epochs)
    vector = _fingerprint(spec, "vector", epochs)
    assert scalar == vector  # exact ==, field for field, no tolerances


# ---------------------------------------------------------------------------
# The full matrix, seed-swept.
# ---------------------------------------------------------------------------
@settings(max_examples=30)
@given(
    name=st.sampled_from(CONDITION_NAMES),
    sync=st.sampled_from(["epoch", "batch"]),
    granularity=st.sampled_from(["step", "substep"]),
    seed=st.integers(min_value=0, max_value=5),
)
def test_engine_equivalence_matrix(name, sync, granularity, seed):
    spec = condition(name, _W, seed=seed, **_KW[name])
    spec = dataclasses.replace(spec, sync=sync, granularity=granularity)
    _assert_engines_agree(spec)


@settings(max_examples=10)
@given(
    name=st.sampled_from(["cache", "fifty-fifty", "oracle", "cache+peer"]),
    sync=st.sampled_from(["epoch", "batch"]),
    seed=st.integers(min_value=0, max_value=3),
)
def test_engine_equivalence_under_stragglers(name, sync, seed):
    """Heterogeneous profiles: rank 0 slowed 2x in compute and I/O — the
    kernel is built from the profile-scaled models, so per-node floats
    differ across ranks but must still agree across engines."""
    profs = straggler_profiles(_W.n_nodes, (0,), 2.0, 2.0)
    spec = condition(name, _W, seed=seed, **_KW[name])
    spec = dataclasses.replace(spec, nodes=profs, sync=sync)
    _assert_engines_agree(spec)


@settings(max_examples=8)
@given(
    sampler=st.sampled_from(["partition", "shared-shuffle", "locality"]),
    seed=st.integers(min_value=0, max_value=3),
)
def test_engine_equivalence_across_samplers(sampler, seed):
    """Sampler sweep on a capped demand cache — the locality sampler's
    order depends on evolving cluster cache state, so exact equivalence
    here proves cache membership evolves identically too."""
    spec = condition("cache", _W, cache_items=64, seed=seed)
    spec = dataclasses.replace(spec, sampler=sampler)
    _assert_engines_agree(spec)


# ---------------------------------------------------------------------------
# Targeted edges.
# ---------------------------------------------------------------------------
def test_engine_equivalence_partial_final_batch():
    """An epoch whose partition is not batch-divisible ends mid-batch: the
    vector engine's final commit must signal STEP_CONTINUE and leave the
    partial batch's compute uncharged, like the scalar stepper."""
    w = WorkloadSpec(
        name="ragged", n_samples=90, sample_bytes=784, batch_size=8,
        compute_per_epoch_s=0.2, n_nodes=3,
    )  # partition 30 = 3 batches + 6 leftover samples
    for name in ("cache", "fifty-fifty", "oracle"):
        spec = condition(name, w, cache_items=16)
        _assert_engines_agree(spec)
        _assert_engines_agree(dataclasses.replace(spec, sync="batch"))


def test_engine_equivalence_tiny_cache_churn():
    """cache < fetch size — the Fig. 7 churn regime: rounds evict each
    other mid-epoch, maximizing prefetch-completion truncation points."""
    spec = condition("fifty-fifty", _W, cache_items=8)
    _assert_engines_agree(spec, epochs=3)


def test_engine_equivalence_unlimited_cache():
    """Uncapped demand cache: epoch 2 is all RAM hits — one maximal
    segment with no interaction points at all."""
    spec = condition("cache", _W, cache_items=-1)
    _assert_engines_agree(spec, epochs=3)


def test_vector_engine_actually_engages():
    """Guard against silent scalar fallback: a registry-free interleaved
    cluster with engine='vector' must instantiate VectorNodeEngine."""
    from repro.engine.vector import VectorNodeEngine

    spec = condition("fifty-fifty", _W, cache_items=64)
    cluster = dataclasses.replace(spec, engine="vector").build_sim()
    cfg = spec.to_sim_config()
    assert cfg.engine == "scalar"  # spec default untouched
    vcfg = dataclasses.replace(spec, engine="vector").to_sim_config()
    assert vcfg.engine == "vector"
    # The cluster driver picks the engine class per run; probe it the same
    # way simulate_cluster does.
    from repro.core.simulator import NodeSimulator

    assert issubclass(VectorNodeEngine, NodeSimulator)
    stats, _ = cluster.run(epochs=1)
    assert sum(s.samples for s in stats) == _W.n_samples


def test_engine_field_validated_once():
    """engine= is validated in SimConfig.__post_init__, surfaced through
    DataPlaneSpec construction (same single-point discipline as PR 5)."""
    with pytest.raises(ValueError, match="engine"):
        SimConfig(engine="turbo")
    with pytest.raises(ValueError, match="engine"):
        condition("cache", _W, cache_items=64, engine="turbo").to_sim_config()


def test_vector_engine_rejected_for_free_running_runtime():
    """The free-running threaded runtime (shared real clock) cannot batch
    virtual time — spec.build_runtime must reject engine='vector' loudly
    before any thread starts."""
    from repro.core.clock import RealClock

    spec = condition("cache", _W, cache_items=64, engine="vector")
    with pytest.raises(ValueError, match="vector"):
        spec.build_runtime(clock=RealClock())
    # The lock-step runtime (no clock) accepts the spec: it never builds
    # simulator nodes, so engine='vector' is simply inert there.
    spec.build_runtime()
