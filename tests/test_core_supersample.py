"""Super-sample packing (beyond-paper §VI) round-trips and grouped sampling."""
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: deterministic fallback sweep
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (
    GroupedPartitionSampler,
    build_supersample_store_payloads,
    make_synthetic_payloads,
    pack_supersample,
    unpack_supersample,
)


@given(st.lists(st.binary(min_size=0, max_size=200), min_size=0, max_size=30))
@settings(max_examples=80, deadline=None)
def test_property_pack_unpack_roundtrip(payloads):
    assert unpack_supersample(pack_supersample(payloads)) == payloads


def test_unpack_rejects_trailing_garbage():
    blob = pack_supersample([b"ab", b"c"]) + b"junk"
    with pytest.raises(ValueError):
        unpack_supersample(blob)


def test_build_store_payloads_mapping():
    payloads = make_synthetic_payloads(10, 64)
    groups, mapping = build_supersample_store_payloads(payloads, group_size=4)
    assert set(groups) == {0, 1, 2}  # 4+4+2
    for i in range(10):
        g, off = mapping[i]
        assert unpack_supersample(groups[g])[off] == payloads[i]


def test_group_size_validation():
    with pytest.raises(ValueError):
        build_supersample_store_payloads({0: b"x"}, group_size=0)


def test_grouped_sampler_partitions_groups():
    world = 3
    samplers = [GroupedPartitionSampler(30, r, world, seed=4) for r in range(world)]
    for s in samplers:
        s.set_epoch(1)
    parts = [set(s.indices()) for s in samplers]
    flat = set().union(*parts)
    assert len(flat) == 30 and all(len(p) == 10 for p in parts)
