"""ISSUE 2 tentpole: the declarative DataPlaneSpec + composable ReadTier
stack — tier attribution, named conditions, and sim/runtime parity."""
import dataclasses

import pytest

from repro.core import (
    MNIST,
    CachingDataset,
    CappedCache,
    InMemoryStore,
    PrefetchConfig,
    RealClock,
    SimConfig,
    SimulatedBucketStore,
    StoreError,
    VirtualClock,
    aggregate_tier_hits,
)
from repro.distributed import PeerCacheRegistry, PeerStore
from repro.pipeline import (
    BucketTier,
    DataPlaneSpec,
    DiskTier,
    RamTier,
    TierResult,
    TierStack,
    assert_parity,
    condition,
    list_conditions,
    list_samplers,
    tiers_for_store,
)


# ---------------------------------------------------------------------------
# Tier stack.
# ---------------------------------------------------------------------------
def test_tier_stack_orders_and_attributes(payloads_1k):
    store = InMemoryStore(payloads_1k)
    cache = CappedCache(max_items=8)
    stack = TierStack([RamTier(cache), DiskTier(cache), BucketTier(store)])
    assert stack.names() == ["ram", "disk", "bucket"]
    r = stack.fetch(3)
    assert isinstance(r, TierResult)
    assert r.tier == "bucket" and r.class_b == 1 and r.payload == payloads_1k[3]
    assert not r.local_hit
    cache.put(3, payloads_1k[3])
    r = stack.fetch(3)
    assert r.tier == "ram" and r.class_b == 0 and r.local_hit


def test_tier_stack_disk_tier_serves_spilled_entries(tmp_path, payloads_1k):
    cache = CappedCache(max_items=8, ram_items=1, spill_dir=str(tmp_path / "spill"))
    store = InMemoryStore(payloads_1k)
    stack = TierStack([RamTier(cache), DiskTier(cache), BucketTier(store)])
    cache.put(1, payloads_1k[1])
    cache.put(2, payloads_1k[2])  # spills 1 to disk (ram_items=1)
    assert stack.fetch(2).tier == "ram"
    r = stack.fetch(1)
    assert r.tier == "disk" and r.payload == payloads_1k[1]


def test_tier_stack_raises_when_no_tier_serves():
    stack = TierStack([BucketTier(InMemoryStore({0: b"x"}))])
    with pytest.raises(StoreError):
        stack.fetch(99)
    with pytest.raises(ValueError):
        TierStack([])


def test_tiers_for_store_maps_peer_store(payloads_1k):
    clock = VirtualClock()
    bucket = SimulatedBucketStore(payloads_1k, clock=clock)
    reg = PeerCacheRegistry()
    reg.register(0, CappedCache())
    reg.register(1, CappedCache())
    peer = PeerStore(bucket, reg, node=0, clock=clock)
    assert [t.name for t in tiers_for_store(peer)] == ["peer", "bucket"]
    assert [t.name for t in tiers_for_store(bucket)] == ["bucket"]


def test_peer_tier_attribution_flows_through_tier_result(payloads_1k):
    """Acceptance: peer attribution via TierResult, not duck-typed flags."""
    clock = VirtualClock()
    bucket = SimulatedBucketStore(payloads_1k, clock=clock)
    reg = PeerCacheRegistry()
    mine, theirs = CappedCache(), CappedCache()
    reg.register(0, mine)
    reg.register(1, theirs)
    theirs.put(5, payloads_1k[5])
    ds = CachingDataset(PeerStore(bucket, reg, node=0, clock=clock), mine)
    r = ds.get(5)
    assert r.tier == "peer" and r.peer_hit and not r.hit and r.class_b == 0
    assert bucket.stats.class_b_requests == 0
    r = ds.get(6)
    assert r.tier == "bucket" and not r.peer_hit and r.class_b == 1


# ---------------------------------------------------------------------------
# Spec construction + registry.
# ---------------------------------------------------------------------------
def test_spec_validation():
    w = MNIST.scaled(0.02)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, source="tape")
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, peer_cache=True)  # needs a cache
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=64, replication_aware_eviction=True)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=0)


def test_spec_sim_config_round_trip():
    w = MNIST.scaled(0.02)
    cfg = SimConfig(
        cache_items=128,
        prefetch=PrefetchConfig.fifty_fifty(128),
        peer_cache=True,
        locality_aware=True,
        streaming_insert=True,
    )
    spec = DataPlaneSpec.from_sim_config(w, cfg, seed=3)
    assert spec.sampler == "locality" and spec.seed == 3
    assert spec.to_sim_config() == cfg
    assert spec.label() == cfg.label()


def test_registry_named_conditions():
    w = MNIST.scaled(0.02)
    assert {"disk", "gcp-direct", "cache", "cache+peer", "cache+peer+repl",
            "fifty-fifty", "full-fetch", "locality"} <= set(list_conditions())
    assert {"partition", "locality"} <= set(list_samplers())
    spec = condition("cache+peer+repl", w, cache_items=64)
    assert spec.peer_cache and spec.replication_aware_eviction
    assert spec.cache_items == 64
    with pytest.raises(ValueError):
        condition("no-such-condition", w)


def test_spec_disk_source_runs_on_both_paths():
    """ISSUE 3 satellite: the disk baseline materializes through
    FileSystemStore and runs (and agrees exactly) on the runtime path too."""
    spec = condition("disk", MNIST.scaled(0.02))
    report = assert_parity(spec, epochs=1)
    assert report.sim_tiers == {"disk-source": 1200}
    assert report.sim_class_b == 0  # local disk is not object storage
    with spec.build_runtime() as cluster:
        root = cluster._disk_root
        assert root is not None
    import os

    assert not os.path.exists(root)  # close() cleans the materialized files


# ---------------------------------------------------------------------------
# Sim/runtime parity (acceptance criterion).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "name,kw",
    [
        ("cache", dict(cache_items=300)),
        ("cache", dict(cache_items=-1)),
        ("gcp-direct", {}),
        ("cache+peer", dict(cache_items=300)),
        ("cache+peer+repl", dict(cache_items=250)),
    ],
)
def test_sim_runtime_parity_exact(name, kw):
    """The same DataPlaneSpec, built via build_sim() and build_runtime()
    (lock-step, per-node virtual clocks) with the same seed, yields
    identical per-tier hit counts, Class A/B totals, and per-node-epoch
    sample counts AND data-wait seconds for a 2-epoch MNIST-scale run."""
    spec = condition(name, MNIST.scaled(0.02), **kw)  # 1200 samples, 3 nodes
    report = assert_parity(spec, epochs=2)
    assert report.sim_samples == report.runtime_samples
    assert sum(row[2] for row in report.sim_samples) == 2 * 1200


@pytest.mark.parametrize(
    "name,kw",
    [
        ("fifty-fifty", dict(cache_items=128)),
        ("full-fetch", dict(fetch_size=128)),
        ("cache+peer", dict(cache_items=300, prefetch=PrefetchConfig.fifty_fifty(300))),
        (
            "cache+peer+repl",
            dict(cache_items=250, prefetch=PrefetchConfig.fifty_fifty(250)),
        ),
    ],
)
def test_sim_runtime_parity_exact_with_prefetch(name, kw):
    """ISSUE 3 acceptance: exact parity now extends to prefetch-ENABLED
    specs — the lock-step scheduler turns service completions into
    deterministic virtual-time events on both projections.  No tolerances:
    per-tier hits, Class A/B, and data-wait are compared with ==."""
    spec = condition(name, MNIST.scaled(0.02), **kw)
    report = assert_parity(spec, epochs=2)
    assert report.sim_tiers.get("ram", 0) > 0  # prefetch produced cache hits
    if spec.peer_cache:
        # Service-side peer pulls are attributed to epochs identically.
        assert report.sim_tiers.get("peer", 0) > 0


def test_parity_prefetch_streaming_insert_and_listing_cache():
    spec = dataclasses.replace(
        condition("fifty-fifty", MNIST.scaled(0.02), cache_items=128),
        streaming_insert=True,
        list_every_fetch=False,
    )
    report = assert_parity(spec, epochs=2)
    assert report.sim_class_a == report.runtime_class_a


def test_parity_with_disabled_prefetch_config_is_exact():
    """Regression: a present-but-disabled PrefetchConfig must behave like
    no prefetch on BOTH projections (the demand path inserts on miss), not
    diverge — the sim used to gate inserts on ``prefetch is None`` while
    the runtime checked ``.enabled``."""
    spec = dataclasses.replace(
        condition("cache", MNIST.scaled(0.02), cache_items=300),
        prefetch=PrefetchConfig.disabled(),
    )
    report = assert_parity(spec, epochs=2)
    assert report.sim_tiers.get("ram", 0) > 0  # miss-inserts produced hits


def test_parity_peer_miss_lookup_charged_exactly_once():
    """ISSUE 4 satellite (audit): a demand read that probes a peer and
    misses charges ``NetworkModel.lookup_seconds()`` exactly once before
    the bucket fallback, on BOTH projections — including steps where the
    lock-step prefetch service probes the same key at round issue (the
    service's probe charges the *round's* duration, never the training
    loop's clock).  Pinned two ways:

    1. exact (``==``) data-wait parity on a capped-cache spec where peer
       misses dominate — any double charge on either side diverges the
       float timelines immediately;
    2. analytically: with the partition sampler and capped caches, peers
       never hold this node's samples, so every bucket read's wait is
       lookup + GET + cpu — the accounted total matches the one-lookup
       closed form and is far from the two-lookup one.
    """
    import math

    from repro.core import DEFAULT_BUCKET, DEFAULT_NETWORK, DEFAULT_PIPELINE, MNIST

    w = MNIST.scaled(0.02)
    # Pin 1: demand path only, cache far below the 400-sample partition —
    # nearly every access is a failed peer probe + bucket GET.
    demand = condition("cache+peer", w, cache_items=60)
    report = assert_parity(demand, epochs=2)
    assert report.sim_tiers.get("bucket", 0) > report.sim_tiers.get("ram", 0)
    # Pin 2: prefetch on — the service probes round keys at issue while the
    # demand path probes the same keys in the same steps; data-wait parity
    # stays exact, so neither projection slipped in a second loop charge.
    assert_parity(
        condition(
            "cache+peer", w, cache_items=60, prefetch=PrefetchConfig.fifty_fifty(60)
        ),
        epochs=2,
    )

    # Analytic closed form, epoch 0 (partitions are disjoint and nothing is
    # cached cluster-wide at the start, so every probe misses: peer == 0):
    # every access pays cpu; ram hits add ram_hit_s; every bucket read adds
    # ONE lookup + the sequential GET.
    lookup = DEFAULT_NETWORK.lookup_seconds()
    get_s = DEFAULT_BUCKET.get_seconds(w.sample_bytes)
    sim_stats, _ = demand.build_sim().run(epochs=1)
    for row in sim_stats:
        assert row.peer_hits == 0
        expect_1 = (
            row.samples * DEFAULT_PIPELINE.cpu_overhead_s
            + row.ram_hits * DEFAULT_PIPELINE.ram_hit_s
            + row.bucket_reads * (lookup + get_s)
        )
        expect_2 = expect_1 + row.bucket_reads * lookup  # a double charge
        assert math.isclose(row.data_wait_seconds, expect_1, rel_tol=1e-9)
        assert not math.isclose(row.data_wait_seconds, expect_2, rel_tol=1e-3)


def test_parity_peer_tier_counts_nonzero():
    spec = condition("cache+peer", MNIST.scaled(0.02), cache_items=-1)
    report = assert_parity(spec, epochs=2)
    assert report.sim_tiers.get("peer", 0) > 0
    assert report.runtime_tiers.get("peer", 0) > 0


def test_runtime_cluster_prefetch_smoke():
    """Prefetch-enabled runtime built from a spec runs end-to-end and
    attributes reads per tier (exact parity is prefetch-free by design;
    statistical agreement is covered in test_core_sim_and_cost)."""
    spec = dataclasses.replace(
        condition("fifty-fifty", MNIST.scaled(0.02), cache_items=128),
        list_every_fetch=False,
    )
    with spec.build_runtime(clock=RealClock(scale=2e-4)) as cluster:
        stats, store = cluster.run(epochs=2)
    tiers = aggregate_tier_hits(stats)
    assert sum(s.samples for s in stats) == 2 * 1200
    assert tiers.get("ram", 0) > 0  # prefetched rounds produced cache hits
    assert store.class_b_requests > 0
    for s in stats:
        assert s.hits + s.misses == s.samples


def test_spec_payload_factory_overrides_runtime_payloads():
    w = dataclasses.replace(MNIST.scaled(0.02), n_nodes=1)
    marker = {i: bytes([i % 251]) * 8 for i in range(w.n_samples)}
    spec = DataPlaneSpec(workload=w, cache_items=-1, payload_factory=lambda s: marker)
    with spec.build_runtime() as cluster:
        loader = cluster.loaders[0]
        loader.set_epoch(0)
        batch = next(iter(loader))
    assert batch.payloads[0] == marker[batch.indices[0]]
