"""ISSUE 2 tentpole: the declarative DataPlaneSpec + composable ReadTier
stack — tier attribution, named conditions, and sim/runtime parity."""
import dataclasses

import pytest

from repro.core import (
    MNIST,
    CachingDataset,
    CappedCache,
    InMemoryStore,
    PrefetchConfig,
    RealClock,
    SimConfig,
    SimulatedBucketStore,
    StoreError,
    VirtualClock,
    aggregate_tier_hits,
    make_synthetic_payloads,
)
from repro.distributed import PeerCacheRegistry, PeerStore
from repro.pipeline import (
    BucketTier,
    DataPlaneSpec,
    DiskTier,
    RamTier,
    TierResult,
    TierStack,
    assert_parity,
    condition,
    list_conditions,
    list_samplers,
    run_parity,
    tiers_for_store,
)


# ---------------------------------------------------------------------------
# Tier stack.
# ---------------------------------------------------------------------------
def test_tier_stack_orders_and_attributes(payloads_1k):
    store = InMemoryStore(payloads_1k)
    cache = CappedCache(max_items=8)
    stack = TierStack([RamTier(cache), DiskTier(cache), BucketTier(store)])
    assert stack.names() == ["ram", "disk", "bucket"]
    r = stack.fetch(3)
    assert isinstance(r, TierResult)
    assert r.tier == "bucket" and r.class_b == 1 and r.payload == payloads_1k[3]
    assert not r.local_hit
    cache.put(3, payloads_1k[3])
    r = stack.fetch(3)
    assert r.tier == "ram" and r.class_b == 0 and r.local_hit


def test_tier_stack_disk_tier_serves_spilled_entries(tmp_path, payloads_1k):
    cache = CappedCache(max_items=8, ram_items=1, spill_dir=str(tmp_path / "spill"))
    store = InMemoryStore(payloads_1k)
    stack = TierStack([RamTier(cache), DiskTier(cache), BucketTier(store)])
    cache.put(1, payloads_1k[1])
    cache.put(2, payloads_1k[2])  # spills 1 to disk (ram_items=1)
    assert stack.fetch(2).tier == "ram"
    r = stack.fetch(1)
    assert r.tier == "disk" and r.payload == payloads_1k[1]


def test_tier_stack_raises_when_no_tier_serves():
    stack = TierStack([BucketTier(InMemoryStore({0: b"x"}))])
    with pytest.raises(StoreError):
        stack.fetch(99)
    with pytest.raises(ValueError):
        TierStack([])


def test_tiers_for_store_maps_peer_store(payloads_1k):
    clock = VirtualClock()
    bucket = SimulatedBucketStore(payloads_1k, clock=clock)
    reg = PeerCacheRegistry()
    reg.register(0, CappedCache())
    reg.register(1, CappedCache())
    peer = PeerStore(bucket, reg, node=0, clock=clock)
    assert [t.name for t in tiers_for_store(peer)] == ["peer", "bucket"]
    assert [t.name for t in tiers_for_store(bucket)] == ["bucket"]


def test_peer_tier_attribution_flows_through_tier_result(payloads_1k):
    """Acceptance: peer attribution via TierResult, not duck-typed flags."""
    clock = VirtualClock()
    bucket = SimulatedBucketStore(payloads_1k, clock=clock)
    reg = PeerCacheRegistry()
    mine, theirs = CappedCache(), CappedCache()
    reg.register(0, mine)
    reg.register(1, theirs)
    theirs.put(5, payloads_1k[5])
    ds = CachingDataset(PeerStore(bucket, reg, node=0, clock=clock), mine)
    r = ds.get(5)
    assert r.tier == "peer" and r.peer_hit and not r.hit and r.class_b == 0
    assert bucket.stats.class_b_requests == 0
    r = ds.get(6)
    assert r.tier == "bucket" and not r.peer_hit and r.class_b == 1


# ---------------------------------------------------------------------------
# Spec construction + registry.
# ---------------------------------------------------------------------------
def test_spec_validation():
    w = MNIST.scaled(0.02)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, source="tape")
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, peer_cache=True)  # needs a cache
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=64, replication_aware_eviction=True)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=0)


def test_spec_sim_config_round_trip():
    w = MNIST.scaled(0.02)
    cfg = SimConfig(
        cache_items=128,
        prefetch=PrefetchConfig.fifty_fifty(128),
        peer_cache=True,
        locality_aware=True,
        streaming_insert=True,
    )
    spec = DataPlaneSpec.from_sim_config(w, cfg, seed=3)
    assert spec.sampler == "locality" and spec.seed == 3
    assert spec.to_sim_config() == cfg
    assert spec.label() == cfg.label()


def test_registry_named_conditions():
    w = MNIST.scaled(0.02)
    assert {"disk", "gcp-direct", "cache", "cache+peer", "cache+peer+repl",
            "fifty-fifty", "full-fetch", "locality"} <= set(list_conditions())
    assert {"partition", "locality"} <= set(list_samplers())
    spec = condition("cache+peer+repl", w, cache_items=64)
    assert spec.peer_cache and spec.replication_aware_eviction
    assert spec.cache_items == 64
    with pytest.raises(ValueError):
        condition("no-such-condition", w)


def test_spec_runtime_rejects_disk_source():
    spec = condition("disk", MNIST.scaled(0.02))
    with pytest.raises(ValueError):
        spec.build_runtime()


# ---------------------------------------------------------------------------
# Sim/runtime parity (acceptance criterion).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "name,kw",
    [
        ("cache", dict(cache_items=300)),
        ("cache", dict(cache_items=-1)),
        ("gcp-direct", {}),
        ("cache+peer", dict(cache_items=300)),
        ("cache+peer+repl", dict(cache_items=250)),
    ],
)
def test_sim_runtime_parity_exact(name, kw):
    """The same DataPlaneSpec, built via build_sim() and build_runtime() on
    a deterministic clock with the same seed, yields identical per-tier hit
    counts and Class B totals for a 2-epoch MNIST-scale run."""
    spec = condition(name, MNIST.scaled(0.02), **kw)  # 1200 samples, 3 nodes
    report = assert_parity(spec, epochs=2)
    assert report.sim_samples == report.runtime_samples
    assert sum(n for _, _, n in report.sim_samples) == 2 * 1200


def test_parity_peer_tier_counts_nonzero():
    spec = condition("cache+peer", MNIST.scaled(0.02), cache_items=-1)
    report = assert_parity(spec, epochs=2)
    assert report.sim_tiers.get("peer", 0) > 0
    assert report.runtime_tiers.get("peer", 0) > 0


def test_parity_rejects_prefetch_specs():
    spec = condition("fifty-fifty", MNIST.scaled(0.02), cache_items=128)
    with pytest.raises(ValueError):
        run_parity(spec)


def test_runtime_cluster_prefetch_smoke():
    """Prefetch-enabled runtime built from a spec runs end-to-end and
    attributes reads per tier (exact parity is prefetch-free by design;
    statistical agreement is covered in test_core_sim_and_cost)."""
    spec = dataclasses.replace(
        condition("fifty-fifty", MNIST.scaled(0.02), cache_items=128),
        list_every_fetch=False,
    )
    with spec.build_runtime(clock=RealClock(scale=2e-4)) as cluster:
        stats, store = cluster.run(epochs=2)
    tiers = aggregate_tier_hits(stats)
    assert sum(s.samples for s in stats) == 2 * 1200
    assert tiers.get("ram", 0) > 0  # prefetched rounds produced cache hits
    assert store.class_b_requests > 0
    for s in stats:
        assert s.hits + s.misses == s.samples


def test_spec_payload_factory_overrides_runtime_payloads():
    w = dataclasses.replace(MNIST.scaled(0.02), n_nodes=1)
    marker = {i: bytes([i % 251]) * 8 for i in range(w.n_samples)}
    spec = DataPlaneSpec(workload=w, cache_items=-1, payload_factory=lambda s: marker)
    with spec.build_runtime() as cluster:
        loader = cluster.loaders[0]
        loader.set_epoch(0)
        batch = next(iter(loader))
    assert batch.payloads[0] == marker[batch.indices[0]]
