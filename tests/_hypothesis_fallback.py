"""Deterministic offline stand-in for the ``hypothesis`` API surface the
test suite uses.

This container has no network and no ``hypothesis`` wheel; rather than lose
the five property-test modules, each ``@given`` test degrades to a fixed
seed sweep: every strategy draws from a ``random.Random`` seeded by the
test's qualified name, so runs are reproducible and failures are
re-runnable.  Only the strategies the suite actually uses are implemented
(``integers``, ``lists``, ``binary``, ``sampled_from``); anything else
raises immediately rather than silently passing.

Usage (in test modules):

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:  # offline container
        from _hypothesis_fallback import given, settings
        from _hypothesis_fallback import strategies as st
"""
from __future__ import annotations


import random
import zlib
from typing import Any, Callable, List, Optional, Sequence

# Cap the sweep well below hypothesis' max_examples defaults: the fallback
# has no shrinking or coverage guidance, so extra examples buy little.
MAX_FALLBACK_EXAMPLES = 25


class SearchStrategy:
    """A strategy is just a deterministic sampler: rng -> value."""

    def __init__(self, draw: Callable[[random.Random], Any], label: str):
        self._draw = draw
        self.label = label

    def example_from(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # aid failure messages
        return f"st.{self.label}"


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (subset)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 16) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: rng.randint(min_value, max_value),
            f"integers({min_value}, {max_value})",
        )

    @staticmethod
    def binary(min_size: int = 0, max_size: int = 64) -> SearchStrategy:
        def draw(rng: random.Random) -> bytes:
            n = rng.randint(min_size, max_size)
            return bytes(rng.getrandbits(8) for _ in range(n))

        return SearchStrategy(draw, f"binary({min_size}, {max_size})")

    @staticmethod
    def lists(
        elements: SearchStrategy, min_size: int = 0, max_size: int = 16
    ) -> SearchStrategy:
        def draw(rng: random.Random) -> List[Any]:
            n = rng.randint(min_size, max_size)
            return [elements.example_from(rng) for _ in range(n)]

        return SearchStrategy(draw, f"lists({elements.label})")

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> SearchStrategy:
        options = list(options)
        if not options:
            raise ValueError("sampled_from needs a non-empty sequence")
        return SearchStrategy(lambda rng: rng.choice(options), "sampled_from")


st = strategies


def settings(max_examples: Optional[int] = None, deadline: Any = None, **_: Any):
    """Records the example budget; chainable in either decorator order."""

    def apply(fn: Callable) -> Callable:
        if max_examples is not None:
            budget = min(max_examples, MAX_FALLBACK_EXAMPLES)
            setattr(fn, "_fallback_max_examples", budget)
        return fn

    return apply


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Expand a property test into a fixed, seeded example sweep."""

    def decorate(fn: Callable) -> Callable:
        # NOT functools.wraps: copying __wrapped__ would make pytest read the
        # original signature and treat the drawn parameters as fixtures.
        def sweep(*fixture_args: Any, **fixture_kwargs: Any) -> None:
            n = getattr(sweep, "_fallback_max_examples", MAX_FALLBACK_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            for example in range(n):
                args = [s.example_from(rng) for s in arg_strategies]
                kwargs = {k: s.example_from(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*fixture_args, *args, **fixture_kwargs, **kwargs)
                except Exception as e:  # annotate with the failing example
                    raise AssertionError(
                        f"falsifying example #{example} (seed {seed}): "
                        f"args={args!r} kwargs={kwargs!r}: {e}"
                    ) from e

        sweep.__name__ = fn.__name__
        sweep.__qualname__ = fn.__qualname__
        sweep.__doc__ = fn.__doc__
        sweep.__module__ = fn.__module__
        # A later @settings may sit above or below @given; copy any budget
        # the wrapped fn already carries.
        if hasattr(fn, "_fallback_max_examples"):
            sweep._fallback_max_examples = fn._fallback_max_examples
        return sweep

    return decorate
