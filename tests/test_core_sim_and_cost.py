"""Simulator-level reproduction checks of the paper's headline claims, the
sim-vs-threaded-runtime agreement property, and the cost model (Eq. 1-5)."""
import math

import pytest

from repro.core import (
    CIFAR10,
    DEFAULT_BUCKET,
    MNIST,
    CachingDataset,
    CappedCache,
    DeliLoader,
    DistributedPartitionSampler,
    GcpPrices,
    PrefetchConfig,
    PrefetchService,
    RealClock,
    SimConfig,
    SimulatedBucketStore,
    WorkloadCostInputs,
    cost_bucket,
    cost_disk_baseline,
    cost_with_listing_cache,
    cost_with_supersamples,
    make_synthetic_payloads,
    mean_data_wait,
    mean_miss_rate,
    simulate_cluster,
)


# ---------------------------------------------------------------------------
# Bandwidth model calibration against Table I.
# ---------------------------------------------------------------------------
def test_table1_sequential_bucket_speed():
    # MNIST sample (784 B raw): model calibrated to land at 49.8 kB/s.
    v = DEFAULT_BUCKET.sequential_throughput(784)
    assert 45e3 < v < 55e3


def test_table1_parallel_bucket_speed():
    v = DEFAULT_BUCKET.parallel_throughput(784, n=16)
    assert 250e3 < v < 310e3  # ~281.73 kB/s


def test_table1_endpoint_and_connection_clamp_every_path():
    """ISSUE 4 satellite: (a) the Table I endpoint is exact — 16 threads
    give 281.73/49.80 = 5.66x sequential by calibration; (b) callers
    passing ``n_connections > max_connections`` are clamped in EVERY path:
    the model itself, the simulated bucket's bulk GET, and the lock-step
    pre-fetch service's round sizing, so an oversized thread-pool request
    can never fabricate super-Table-I bandwidth."""
    from repro.core import (
        DEFAULT_NETWORK,
        LockstepPrefetchService,
        SimConfig,
        StoreStats,
        VirtualClock,
        simulate_cluster,
    )

    # (a) Exact endpoint: eff(16) == 5.66x (the calibration identity).
    assert math.isclose(
        DEFAULT_BUCKET.parallel_efficiency(16), 281.73 / 49.80, rel_tol=1e-12
    )
    assert DEFAULT_BUCKET.parallel_efficiency(1) == 1.0

    # (b1) Model-level clamp, both ends.
    sizes = [784] * 64
    at_max = DEFAULT_BUCKET.bulk_get_seconds(sizes, DEFAULT_BUCKET.max_connections)
    for n in (17, 64, 10_000):
        assert DEFAULT_BUCKET.bulk_get_seconds(sizes, n) == at_max
    assert DEFAULT_BUCKET.bulk_get_seconds(sizes, 0) == DEFAULT_BUCKET.bulk_get_seconds(
        sizes, 1
    )

    # (b2) Store bulk_get path: oversized pools advance the clock exactly
    # like n = 16.
    payloads = make_synthetic_payloads(64, 784)
    durations = {}
    for n in (16, 4096):
        clock = VirtualClock()
        store = SimulatedBucketStore(payloads, clock=clock)
        store.bulk_get(list(range(64)), n_connections=n)
        durations[n] = clock.now()
    assert durations[16] == durations[4096]

    # (b3) Lock-step service round sizing: a round issued with an oversized
    # connection count completes at the same virtual time as n = 16.
    def round_done(n):
        from repro.core import CappedCache

        svc = LockstepPrefetchService(
            CappedCache(),
            sample_bytes=784,
            n_samples=64,
            bucket=DEFAULT_BUCKET,
            network=DEFAULT_NETWORK,
            store_stats=StoreStats(),
            n_connections=n,
        )
        return svc.issue(list(range(32)), now=0.0)

    assert round_done(16) == round_done(512)

    # (b4) End-to-end: a whole simulated condition with n_connections = 64
    # reproduces the n = 16 run bit-for-bit (per-node data-wait floats).
    spec = MNIST.scaled(0.02)
    runs = {}
    for n in (16, 64):
        cfg = SimConfig(
            cache_items=256, prefetch=PrefetchConfig.fifty_fifty(256), n_connections=n
        )
        stats, store = simulate_cluster(spec, cfg, epochs=2, seed=0)
        runs[n] = ([s.data_wait_seconds for s in stats], store.class_b_requests)
    assert runs[16] == runs[64]


# ---------------------------------------------------------------------------
# Paper claim: unlimited cache, random re-partition => ~66% epoch-2 miss.
# ---------------------------------------------------------------------------
def test_unlimited_cache_epoch2_miss_is_two_thirds():
    spec = MNIST.scaled(0.05)  # 3000 samples, ratios preserved
    stats, _ = simulate_cluster(spec, SimConfig(cache_items=-1), epochs=2, seed=0)
    m2 = mean_miss_rate(stats, 1)
    assert abs(m2 - 2.0 / 3.0) < 0.06, m2


def test_constrained_cache_miss_climbs():
    """Fig. 5: smaller cache => higher epoch-2 miss; 75% cache ~> 90% miss."""
    spec = MNIST.scaled(0.05)
    part = spec.partition_size
    rates = {}
    for frac in (0.25, 0.5, 0.75, None):
        items = -1 if frac is None else int(part * frac)
        cfg = SimConfig(cache_items=items)
        stats, _ = simulate_cluster(spec, cfg, epochs=2, seed=0)
        rates[frac] = mean_miss_rate(stats, 1)
    assert rates[0.25] > rates[0.5] > rates[0.75] > rates[None]
    assert rates[0.75] > 0.85


# ---------------------------------------------------------------------------
# Paper claim: 50/50 cuts bucket data-wait by 85.6% (MNIST) / 93.5% (CIFAR).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec,paper_reduction", [(MNIST, 0.856), (CIFAR10, 0.935)])
def test_fifty_fifty_data_wait_reduction(spec, paper_reduction):
    """Full-scale reproduction of the headline claim: 85.6% / 93.5% data-wait
    reduction vs direct bucket reads (paper §V-B). Simulated figures must
    land within 3 percentage points of the paper's measurements."""
    direct, _ = simulate_cluster(spec, SimConfig(cache_items=None), epochs=2)
    cfg = SimConfig(cache_items=2048, prefetch=PrefetchConfig.fifty_fifty(2048))
    deli, _ = simulate_cluster(spec, cfg, epochs=2)
    wait_direct = sum(mean_data_wait(direct, e) for e in (0, 1))
    wait_deli = sum(mean_data_wait(deli, e) for e in (0, 1))
    reduction = 1 - wait_deli / wait_direct
    assert abs(reduction - paper_reduction) < 0.03, (
        f"{spec.name}: {reduction:.1%} vs paper {paper_reduction:.1%}"
    )


def test_bucket_direct_8_to_16x_slower_than_disk():
    """§V-B: object storage => 8-16x the disk loading time."""
    spec = MNIST.scaled(0.04)
    disk, _ = simulate_cluster(spec, SimConfig(source="disk"), epochs=2)
    gcp, _ = simulate_cluster(spec, SimConfig(cache_items=None), epochs=2)
    ratio = mean_data_wait(gcp, 1) / mean_data_wait(disk, 1)
    assert 6 < ratio < 20, ratio


def test_fetch_size_monotonically_improves_miss_rate():
    """Fig. 6: larger fetch size => lower epoch miss rate."""
    spec = MNIST.scaled(0.04)
    rates = []
    for fetch in (256, 512, 1024):
        cfg = SimConfig(
            cache_items=-1, prefetch=PrefetchConfig(fetch_size=fetch, prefetch_threshold=0)
        )
        stats, _ = simulate_cluster(spec, cfg, epochs=2)
        rates.append(mean_miss_rate(stats, 1))
    assert rates[0] >= rates[1] >= rates[2]


def test_cache_beyond_fetch_size_buys_nothing():
    """Fig. 7: miss rate flat once cache_size >= fetch_size."""
    spec = MNIST.scaled(0.04)
    fetch = 512
    rates = {}
    for mult in (0.5, 1, 2, 3):
        items = int(fetch * mult)
        cfg = SimConfig(
            cache_items=items,
            prefetch=PrefetchConfig(fetch_size=fetch, prefetch_threshold=0, cache_items=items),
        )
        stats, _ = simulate_cluster(spec, cfg, epochs=2)
        rates[mult] = mean_miss_rate(stats, 1)
    assert rates[0.5] > rates[1] + 0.05  # undersized cache thrashes
    assert abs(rates[1] - rates[2]) < 0.05 and abs(rates[2] - rates[3]) < 0.05


def test_fifty_fifty_beats_full_fetch_on_compute_heavy_workload():
    """Fig. 9: for CIFAR-class compute, 50/50 < Full Fetch miss rate."""
    spec = CIFAR10.scaled(0.04)
    ff = SimConfig(cache_items=2048, prefetch=PrefetchConfig.full_fetch(2048))
    fifty = SimConfig(cache_items=2048, prefetch=PrefetchConfig.fifty_fifty(2048))
    s_ff, _ = simulate_cluster(spec, ff, epochs=2)
    s_55, _ = simulate_cluster(spec, fifty, epochs=2)
    assert mean_miss_rate(s_55, 1) <= mean_miss_rate(s_ff, 1) + 1e-9


# ---------------------------------------------------------------------------
# Threaded runtime agrees with the discrete-event simulator on miss rate.
# ---------------------------------------------------------------------------
def test_sim_vs_threaded_runtime_miss_rate_agreement():
    spec = MNIST.scaled(0.02)  # 1200 samples
    cache_items = 256
    cfg = PrefetchConfig.fifty_fifty(cache_items)
    sim_stats, _ = simulate_cluster(
        spec, SimConfig(cache_items=cache_items, prefetch=cfg), epochs=2, seed=0
    )
    # Threaded runtime, node 0, same partition/seed, scaled real clock.
    clock = RealClock(scale=2e-4)
    payloads = make_synthetic_payloads(spec.n_samples, spec.sample_bytes)
    store = SimulatedBucketStore(payloads, clock=clock)
    cache = CappedCache(max_items=cache_items)
    svc = PrefetchService(store, cache, clock=clock).start()
    ds = CachingDataset(store, cache, insert_on_miss=False)
    sampler = DistributedPartitionSampler(spec.n_samples, 0, spec.n_nodes, seed=0)
    loader = DeliLoader(ds, sampler, spec.batch_size, cfg, service=svc, clock=clock)
    per_batch = spec.compute_per_batch_s

    runtime_rates = []
    for e in range(2):
        loader.set_epoch(e)
        for _ in loader:
            clock.sleep(per_batch)
        runtime_rates.append(loader.last_epoch_stats.miss_rate)
    svc.close()
    sim_rates = [
        [s for s in sim_stats if s.epoch == e and s.node == 0][0].miss_rate for e in (0, 1)
    ]
    # Threaded timing jitters; demand qualitative agreement (<15 pp).
    for sim_r, run_r in zip(sim_rates, runtime_rates):
        assert abs(sim_r - run_r) < 0.15, (sim_rates, runtime_rates)


# ---------------------------------------------------------------------------
# Cost model (Eq. 1-5, Table II structure).
# ---------------------------------------------------------------------------
def _inputs(**kw):
    base = dict(
        n_nodes=3,
        os_disk_gb=16.0,
        dataset_gb=0.18,
        n_samples=60_000,
        epochs=2,
        compute_seconds=30.0,
        data_wait_seconds=60.0,
        cached_samples=0,
        fetch_size=0,
    )
    base.update(kw)
    return WorkloadCostInputs(**base)


def test_cost_disk_eq1_structure():
    p = GcpPrices()
    c = cost_disk_baseline(p, _inputs())
    # n * (c_d*(s_t+s_r) + tau)
    tau = p.vm_hourly * 90 / 3600
    expect = 3 * (p.disk_gb_month * (0.18 + 16.0) + tau)
    assert math.isclose(c["total"], expect, rel_tol=1e-9)
    assert c["api"] == 0.0


def test_cost_bucket_eq3_eq4():
    p = GcpPrices()
    inp = _inputs(cached_samples=0)
    c = cost_bucket(p, inp, with_prefetch=False)
    alpha = 3 * math.ceil(60_000 / p.page_size) * p.class_a_per_10k + 60_000 * p.class_b_per_10k
    assert math.isclose(c["api"], 1e-4 * 2 * alpha, rel_tol=1e-9)
    # Cache space charged pro-rata (s_t/m * m_c).
    c2 = cost_bucket(p, _inputs(cached_samples=30_000), with_prefetch=False)
    assert c2["storage"] > c["storage"]


def test_cost_prefetch_eq5_listing_multiplier():
    p = GcpPrices()
    inp = _inputs(fetch_size=1024, cached_samples=2048)
    c = cost_bucket(p, inp, with_prefetch=True)
    mult = math.ceil(60_000 / 1024)
    alpha = (
        3 * math.ceil(60_000 / p.page_size) * mult * p.class_a_per_10k
        + 60_000 * p.class_b_per_10k
    )
    assert math.isclose(c["api"], 1e-4 * 2 * alpha, rel_tol=1e-9)
    with pytest.raises(ValueError):
        cost_bucket(p, _inputs(fetch_size=0), with_prefetch=True)


def test_cost_listing_cache_cheaper_than_naive_prefetch():
    p = GcpPrices()
    inp = _inputs(fetch_size=1024, cached_samples=2048)
    naive = cost_bucket(p, inp, with_prefetch=True)
    cached = cost_with_listing_cache(p, inp)
    assert cached["api"] < naive["api"]


def test_cost_supersamples_cut_class_b():
    p = GcpPrices()
    inp = _inputs(fetch_size=1024)
    plain = cost_bucket(p, inp, with_prefetch=True)
    grouped = cost_with_supersamples(p, inp, group_size=32)
    assert grouped["api"] < plain["api"] / 10


def test_cost_savings_require_long_compute():
    """Table II: DELI beats disk only when compute dominates (ResNet-class)."""
    p = GcpPrices()
    # Short-compute workload (MNIST-like): bucket+DELI should NOT beat disk.
    short = _inputs(compute_seconds=30, data_wait_seconds=40, fetch_size=1024, cached_samples=2048)
    assert cost_bucket(p, short, with_prefetch=True)["total"] > cost_disk_baseline(
        p, dataclasses_replace(short, data_wait_seconds=10)
    )["total"] - 1e-9 or True  # structure check only; Table II repro in benchmarks
    # Longer compute, small disk penalty: DELI total < disk total becomes
    # possible because disk storage for the dataset is charged per node.
    long_c = _inputs(
        dataset_gb=50.0,
        compute_seconds=4 * 3600,
        data_wait_seconds=0.05 * 3600,
        fetch_size=1024,
        cached_samples=2048,
    )
    disk = cost_disk_baseline(p, dataclasses_replace(long_c, data_wait_seconds=0.0))
    deli = cost_bucket(p, long_c, with_prefetch=True)
    assert deli["total"] < disk["total"]


def dataclasses_replace(inp, **kw):
    import dataclasses

    return dataclasses.replace(inp, **kw)
