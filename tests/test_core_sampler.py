"""Tests for distributed partition samplers (PyTorch DistributedSampler
semantics, §V-A) and the beyond-paper locality-aware partitioner."""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: deterministic fallback sweep
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (
    DistributedPartitionSampler,
    LocalityAwareSampler,
    RandomSampler,
    SequentialSampler,
)
from repro.core.sampler import partition_fingerprint


def test_sequential_and_random():
    assert SequentialSampler(5).indices() == [0, 1, 2, 3, 4]
    r = RandomSampler(100, seed=3)
    r.set_epoch(0)
    e0 = r.indices()
    r.set_epoch(1)
    e1 = r.indices()
    assert sorted(e0) == list(range(100)) == sorted(e1)
    assert e0 != e1  # reshuffled per epoch


def test_partitions_disjoint_and_exhaustive():
    world, n = 3, 99
    samplers = [DistributedPartitionSampler(n, r, world, seed=5) for r in range(world)]
    for s in samplers:
        s.set_epoch(2)
    parts = [set(s.indices()) for s in samplers]
    assert all(len(p) == n // world for p in parts)
    union = set().union(*parts)
    assert len(union) == (n // world) * world
    for i in range(world):
        for j in range(i + 1, world):
            assert not parts[i] & parts[j]


def test_partition_reshuffles_each_epoch():
    s = DistributedPartitionSampler(3000, rank=0, world=3, seed=0)
    s.set_epoch(0)
    p0 = set(s.indices())
    s.set_epoch(1)
    p1 = set(s.indices())
    overlap = len(p0 & p1) / len(p0)
    # ~1/3 overlap expected — the source of the paper's ~66% epoch-2 miss.
    assert 0.2 < overlap < 0.5


@given(
    n=st.integers(min_value=6, max_value=500),
    world=st.integers(min_value=1, max_value=8),
    epoch=st.integers(min_value=0, max_value=4),
)
@settings(max_examples=60, deadline=None)
def test_property_partitioning(n, world, epoch):
    samplers = [DistributedPartitionSampler(n, r, world, seed=1) for r in range(world)]
    for s in samplers:
        s.set_epoch(epoch)
    parts = [s.indices() for s in samplers]
    sizes = {len(p) for p in parts}
    assert sizes == {n // world}
    flat = [i for p in parts for i in p]
    assert len(flat) == len(set(flat))  # disjoint
    assert set(flat) <= set(range(n))


def test_locality_aware_reduces_cross_epoch_churn():
    n, world = 3000, 3
    base = [DistributedPartitionSampler(n, r, world, seed=9) for r in range(world)]
    loc = [LocalityAwareSampler(n, r, world, seed=9) for r in range(world)]
    for s in base + loc:
        s.set_epoch(0)
    # Epoch 0: caches fill with each node's partition (use base partition for
    # both so the comparison is apples-to-apples).
    views = [s.indices() for s in base]
    for s in loc:
        s.update_cache_views(views)
    for s in base + loc:
        s.set_epoch(1)
    # Fraction of epoch-1 partition already cached:
    def hit_fraction(parts):
        hits = sum(len(set(p) & set(v)) for p, v in zip(parts, views))
        return hits / (len(parts[0]) * world)

    base_frac = hit_fraction([s.indices() for s in base])
    loc_frac = hit_fraction([s.indices() for s in loc])
    assert base_frac < 0.5  # random re-partition: ~1/3
    assert loc_frac > 0.95  # locality-aware: nearly everything reused


def test_locality_aware_partitions_remain_disjoint_balanced():
    n, world = 600, 4
    loc = [LocalityAwareSampler(n, r, world, seed=2) for r in range(world)]
    views = [list(range(r, n, world)) for r in range(world)]
    for s in loc:
        s.update_cache_views(views)
        s.set_epoch(3)
    parts = [s.indices() for s in loc]
    assert all(len(p) == n // world for p in parts)
    flat = [i for p in parts for i in p]
    assert len(flat) == len(set(flat))


def test_fingerprint_stability():
    a = partition_fingerprint([1, 2, 3])
    assert a == partition_fingerprint([1, 2, 3])
    assert a != partition_fingerprint([3, 2, 1])
