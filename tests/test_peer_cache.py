"""Cooperative peer-cache tier: registry, PeerStore, simulator knob,
threaded-runtime wiring, locality-aware tiering and the cost hook."""
import pytest

from repro.core import (
    MNIST,
    CachingDataset,
    CappedCache,
    DeliLoader,
    DistributedPartitionSampler,
    GcpPrices,
    LocalityAwareSampler,
    PrefetchConfig,
    PrefetchService,
    SimConfig,
    SimulatedBucketStore,
    VirtualClock,
    WorkloadCostInputs,
    cost_bucket,
    cost_with_peer_cache,
    mean_data_wait,
    simulate_cluster,
)
from repro.distributed import PeerCacheRegistry, PeerStore


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------
def test_registry_register_and_lookup():
    reg = PeerCacheRegistry()
    c0, c1 = CappedCache(), CappedCache()
    reg.register(0, c0)
    reg.register(1, c1)
    c1.put(7, b"x")
    assert reg.nodes() == [0, 1]
    assert reg.lookup(7, requester=0) == 1
    assert reg.lookup(7, requester=1) is None  # never your own cache
    assert reg.lookup(8, requester=0) is None
    assert reg.cache_views() == [[], [7]]
    # Lookups are candidates only; hits are confirmed by the reader.
    assert reg.lookups == 3 and reg.peer_hits == 0
    reg.record_hit()
    assert reg.peer_hits == 1


def test_registry_rejects_double_registration():
    reg = PeerCacheRegistry()
    reg.register(0, CappedCache())
    reg.register(0, reg.cache_of(0))  # same cache: idempotent
    with pytest.raises(ValueError):
        reg.register(0, CappedCache())


def test_registry_prefers_lowest_holder_deterministically():
    reg = PeerCacheRegistry()
    caches = [CappedCache() for _ in range(3)]
    for n, c in enumerate(caches):
        reg.register(n, c)
    caches[1].put(5, b"a")
    caches[2].put(5, b"a")
    assert reg.lookup(5, requester=0) == 1


# ---------------------------------------------------------------------------
# PeerStore.
# ---------------------------------------------------------------------------
def _peer_setup(payloads, clock):
    bucket = SimulatedBucketStore(payloads, clock=clock)
    reg = PeerCacheRegistry()
    mine, theirs = CappedCache(), CappedCache()
    reg.register(0, mine)
    reg.register(1, theirs)
    store = PeerStore(bucket, reg, node=0, clock=clock)
    return store, bucket, mine, theirs


def test_peer_store_serves_from_peer_without_class_b(payloads_1k):
    clock = VirtualClock()
    store, bucket, _, theirs = _peer_setup(payloads_1k, clock)
    theirs.put(3, payloads_1k[3])
    t0 = clock.now()
    assert store.get(3) == payloads_1k[3]
    peer_dt = clock.now() - t0
    assert store.peer_hits == 1
    assert bucket.stats.class_b_requests == 0
    # A peer transfer must be far cheaper than the modelled bucket GET.
    assert peer_dt < bucket.model.get_seconds(1024) / 10


def test_peer_store_falls_back_to_bucket(payloads_1k):
    clock = VirtualClock()
    store, bucket, _, _ = _peer_setup(payloads_1k, clock)
    assert store.get(5) == payloads_1k[5]
    assert store.peer_hits == 0
    assert bucket.stats.class_b_requests == 1


def test_peer_store_eviction_race_degrades_to_fallback(payloads_1k):
    """Holder lists the key, but the entry is gone by the peer read."""
    clock = VirtualClock()
    store, bucket, _, theirs = _peer_setup(payloads_1k, clock)

    class VanishingCache(CappedCache):
        def peek(self, index):
            return None  # evicted between lookup and read

    vanishing = VanishingCache()
    vanishing.put(4, payloads_1k[4])
    store.registry._caches[1] = vanishing  # swap in behind the directory
    assert store.get(4) == payloads_1k[4]
    assert store.peer_hits == 0
    assert bucket.stats.class_b_requests == 1


def test_peer_store_stats_route_to_inner(payloads_1k):
    clock = VirtualClock()
    store, bucket, _, _ = _peer_setup(payloads_1k, clock)
    store.get(1)
    assert store.stats is bucket.stats
    assert store.size_of(1) == 1024
    assert store.list_objects() == sorted(payloads_1k)


# ---------------------------------------------------------------------------
# Simulator integration.
# ---------------------------------------------------------------------------
def test_sim_peer_cache_reduces_class_b_and_wait():
    """Acceptance: 4-node cluster, equal per-node cache — peer mode strictly
    cuts aggregate Class B and mean data-wait, with non-zero peer hits."""
    import dataclasses

    spec = dataclasses.replace(MNIST.scaled(0.05), n_nodes=4)
    cache = spec.partition_size
    runs = {}
    for peer in (False, True):
        cfg = SimConfig(cache_items=cache, peer_cache=peer)
        stats, store = simulate_cluster(spec, cfg, epochs=2, seed=0)
        runs[peer] = (stats, store)
    local_stats, local_store = runs[False]
    peer_stats, peer_store = runs[True]
    assert peer_store.class_b_requests < local_store.class_b_requests
    wait_local = sum(mean_data_wait(local_stats, e) for e in (0, 1))
    wait_peer = sum(mean_data_wait(peer_stats, e) for e in (0, 1))
    assert wait_peer < wait_local
    assert sum(s.peer_hits for s in peer_stats) > 0
    assert all(s.peer_hits == 0 for s in local_stats)
    for s in peer_stats:
        assert s.peer_hits <= s.misses
        assert s.hits + s.misses == s.samples


def test_sim_peer_cache_with_prefetch_cuts_class_b():
    cfg_base = dict(cache_items=1024, prefetch=PrefetchConfig.fifty_fifty(1024))
    spec = MNIST.scaled(0.05)
    _, local = simulate_cluster(spec, SimConfig(**cfg_base), epochs=2, seed=0)
    stats, peer = simulate_cluster(
        spec, SimConfig(**cfg_base, peer_cache=True), epochs=2, seed=0
    )
    assert peer.class_b_requests < local.class_b_requests
    assert sum(s.peer_hits for s in stats) > 0


def test_sim_peer_cache_requires_local_cache():
    with pytest.raises(ValueError):
        simulate_cluster(MNIST.scaled(0.05), SimConfig(cache_items=None, peer_cache=True))


def test_sim_config_label_mentions_peer():
    assert "+peer" in SimConfig(cache_items=64, peer_cache=True).label()
    assert "+peer" not in SimConfig(cache_items=64).label()


# ---------------------------------------------------------------------------
# Threaded runtime integration (loader + prefetch service over PeerStore).
# ---------------------------------------------------------------------------
def test_threaded_loader_counts_peer_hits(payloads_1k):
    clock = VirtualClock()
    bucket = SimulatedBucketStore(payloads_1k, clock=clock)
    reg = PeerCacheRegistry()
    world = 2
    loaders, stores = [], []
    for rank in range(world):
        cache = CappedCache()
        reg.register(rank, cache)
        store = PeerStore(bucket, reg, node=rank, clock=clock)
        ds = CachingDataset(store, cache, insert_on_miss=True)
        sampler = DistributedPartitionSampler(len(payloads_1k), rank, world, seed=0)
        loaders.append(
            DeliLoader(ds, sampler, 16, PrefetchConfig.disabled(), clock=clock, node=rank)
        )
        stores.append(store)
    for epoch in range(2):
        for loader in loaders:
            loader.set_epoch(epoch)
            for _ in loader:
                pass
    e2 = [l.epoch_history[1] for l in loaders]
    assert sum(s.peer_hits for s in e2) > 0
    for s in e2:
        assert s.peer_hits <= s.misses
    # Every sample fetched from the bucket at most once across the cluster.
    assert bucket.stats.class_b_requests == len(payloads_1k)


def test_prefetch_service_over_peer_store_skips_bucket(payloads_1k):
    clock = VirtualClock()
    bucket = SimulatedBucketStore(payloads_1k, clock=clock)
    reg = PeerCacheRegistry()
    peer_cache = CappedCache()
    reg.register(1, peer_cache)
    for i in range(8):
        peer_cache.put(i, payloads_1k[i])
    my_cache = CappedCache()
    reg.register(0, my_cache)
    store = PeerStore(bucket, reg, node=0, clock=clock)
    with PrefetchService(store, my_cache, clock=clock, list_every_fetch=False) as svc:
        svc.request(list(range(16)))
        assert svc.drain(timeout=30)
    assert all(my_cache.contains(i) for i in range(16))
    assert store.peer_hits == 8
    assert svc.peer_fetches == 8  # service-side attribution of peer pulls
    assert bucket.stats.class_b_requests == 8  # only the non-resident half
    # Serving peers must not pollute the holder's own hit/miss accounting.
    assert peer_cache.stats.hits == 0 and peer_cache.stats.misses == 0


# ---------------------------------------------------------------------------
# Replication-aware eviction (Hoard-style: keep the last cluster copy).
# ---------------------------------------------------------------------------
def test_registry_tracks_resident_copies():
    reg = PeerCacheRegistry()
    c0, c1 = CappedCache(), CappedCache()
    c0.put(7, b"x")  # pre-registration resident: folded in at register()
    reg.register(0, c0)
    reg.register(1, c1)
    assert reg.resident_copies(7) == 1
    c1.put(7, b"x")
    assert reg.resident_copies(7) == 2
    c0.clear()  # evictions decrement
    assert reg.resident_copies(7) == 1
    c1.clear()
    assert reg.resident_copies(7) == 0


def test_replication_aware_cache_skips_last_copy_victim():
    """FIFO would evict the oldest entry; when it is the last
    cluster-resident copy, the next-oldest *replicated* entry goes instead."""
    reg = PeerCacheRegistry(replication_aware=True)
    c0 = CappedCache(max_items=2)
    c1 = CappedCache(max_items=2)
    reg.register(0, c0)
    reg.register(1, c1)
    c0.put(1, b"a")  # last copy of 1 (FIFO-oldest in c0)
    c0.put(2, b"b")
    c1.put(2, b"b")  # 2 now has two cluster copies
    c0.put(3, b"c")  # over capacity: FIFO victim would be 1
    assert c0.contains(1)  # protected: last cluster-resident copy
    assert not c0.contains(2)  # the replicated entry was evicted instead
    assert c0.contains(3)
    assert reg.resident_copies(2) == 1  # c1 still holds it
    assert c0.stats.guard_skips == 1  # exactly one protection changed an outcome


def test_replication_aware_cache_falls_back_when_all_protected():
    """Capacity always wins: if every entry is a last copy, plain FIFO."""
    reg = PeerCacheRegistry(replication_aware=True)
    c0 = CappedCache(max_items=2)
    reg.register(0, c0)
    c0.put(1, b"a")
    c0.put(2, b"b")
    c0.put(3, b"c")  # all entries are last copies -> evict oldest (1)
    assert len(c0) == 2
    assert not c0.contains(1)
    assert c0.contains(2) and c0.contains(3)
    assert c0.stats.guard_skips == 0  # capacity fallback declined nothing


def test_replication_aware_eviction_cuts_bucket_refetches():
    """ISSUE 2 satellite: at equal per-node capacity, declining to evict
    the last cluster-resident copy keeps more of the dataset peer-servable,
    so the cluster re-issues strictly fewer Class B bucket GETs."""
    import dataclasses

    spec = dataclasses.replace(MNIST.scaled(0.05), n_nodes=4)
    cache = max(1, int(spec.partition_size * 0.75))
    results = {}
    for repl in (False, True):
        cfg = SimConfig(
            cache_items=cache, peer_cache=True, replication_aware_eviction=repl
        )
        stats, store = simulate_cluster(spec, cfg, epochs=2, seed=0)
        results[repl] = (store.class_b_requests, sum(s.peer_hits for s in stats))
    assert results[True][0] < results[False][0]
    assert results[True][1] >= results[False][1]  # more peer-served reads


def test_sim_config_label_mentions_repl():
    cfg = SimConfig(cache_items=64, peer_cache=True, replication_aware_eviction=True)
    assert "+peer+repl" in cfg.label()


# ---------------------------------------------------------------------------
# Locality-aware tiering + cost hook.
# ---------------------------------------------------------------------------
def test_locality_sampler_peer_aware_balances_bucket_only():
    """Node 0 over-caches (quota fills with its own hits); the leftover fill
    must spread the expensive bucket-only samples evenly over the nodes with
    remaining quota (on-node > on-peer > bucket-only tiering)."""
    n, world = 36, 3
    cached = [list(range(18)), [], []]  # node 0 holds half the dataset
    samplers = [
        LocalityAwareSampler(n, r, world, seed=1, peer_aware=True) for r in range(world)
    ]
    for s in samplers:
        s.update_cache_views(cached)
        s.set_epoch(1)
    parts = [s.indices() for s in samplers]
    # Deterministic, disjoint, exhaustive and balanced.
    assert sorted(i for p in parts for i in p) == list(range(n))
    assert all(len(p) == n // world for p in parts)
    # Node 0's 12 slots all came from its own cache (on-node tier).
    assert all(i < 18 for i in parts[0])
    # The 18 bucket-only samples split evenly across the two cold nodes,
    # and the 6 on-peer leftovers (cached on full node 0) fill the rest.
    for p in parts[1:]:
        assert len([i for i in p if i >= 18]) == 9
        assert len([i for i in p if i < 18]) == 3


def test_cost_with_peer_cache_cuts_class_b_line():
    p = GcpPrices()
    inp = WorkloadCostInputs(
        n_nodes=4,
        os_disk_gb=16.0,
        dataset_gb=0.18,
        n_samples=60_000,
        epochs=2,
        compute_seconds=30.0,
        data_wait_seconds=60.0,
    )
    base = cost_bucket(p, inp)
    peered = cost_with_peer_cache(p, inp, peer_hits_per_epoch=40_000)
    assert peered["api"] < base["api"]
    assert peered["total"] < base["total"]
    # Avoided GETs cannot push the Class B term negative.
    floor = cost_with_peer_cache(p, inp, peer_hits_per_epoch=10**9)
    assert floor["api"] >= 0.0
