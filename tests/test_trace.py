"""ISSUE 10 tentpole: the virtual-time flight recorder.

Three exact (``==``, no tolerances) acceptance properties, swept across
the condition x sync x granularity x engine matrix:

1. **Trace parity** — both projections of one ``DataPlaneSpec`` emit
   bit-identical canonical event streams (``repro.obs.parity``), and the
   scalar and vector engines synthesize the same streams from entirely
   different execution shapes.
2. **Ledger reconciliation** — summing the per-request cost ledger built
   from the trace reproduces ``StoreStats.class_a_requests`` /
   ``class_b_requests`` exactly (every charge has an emitting event).
3. **Observer purity** — ``trace=None`` and ``trace=TraceRecorder()``
   produce byte-identical stats, tiers and store counters (the recorder
   observes the schedule, never perturbs it).

Plus the exporters: Chrome trace-event JSON validates and round-trips
losslessly, and the wall-time decomposition sums spans back to
``EpochStats.wall_seconds``.
"""
import dataclasses
import json

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import MNIST, EpochStats, straggler_profiles
from repro.obs.events import TraceRecorder, canonical_stream
from repro.obs.export import (
    chrome_trace,
    decomposition,
    decomposition_table,
    events_from_chrome,
    text_timeline,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.obs.ledger import assert_reconciles, build_ledger, per_node_totals
from repro.obs.parity import assert_trace_parity, run_trace_parity
from repro.pipeline import condition

#: The tentpole matrix: demand-only, paper prefetch, single-node-horizon
#: oracle, cross-rank clairvoyant planner, and gradient-bucket overlap —
#: each exercising a different set of emitting components.
CONDITIONS = (
    ("cache", {"cache_items": 64}),
    ("fifty-fifty", {"cache_items": 64}),
    ("oracle", {"cache_items": 64}),
    ("cluster-oracle", {"cache_items": 64}),
    ("overlap", {"cache_items": 64}),
)
CONDITION_NAMES = tuple(name for name, _ in CONDITIONS)
_KW = dict(CONDITIONS)

_W = MNIST.scaled(0.01)  # 600 samples, 3 nodes, batch 64 — fast but real


def _spec(name, sync, granularity, engine, seed):
    spec = condition(name, _W, seed=seed, **_KW[name])
    if name == "overlap":
        sync = "batch"  # overlap="buckets" requires per-batch barriers
    return dataclasses.replace(
        spec, sync=sync, granularity=granularity, engine=engine
    )


def _traced_sim_run(spec, epochs=2):
    rec = TraceRecorder()
    stats, store = dataclasses.replace(spec, trace=rec).build_sim().run(
        epochs=epochs
    )
    return rec, stats, store


# ---------------------------------------------------------------------------
# 1. Event-level parity, sim vs runtime AND scalar vs vector.
# ---------------------------------------------------------------------------
@settings(max_examples=20)
@given(
    name=st.sampled_from(CONDITION_NAMES),
    sync=st.sampled_from(["epoch", "batch"]),
    granularity=st.sampled_from(["step", "substep"]),
    engine=st.sampled_from(["scalar", "vector"]),
    seed=st.integers(min_value=0, max_value=4),
)
def test_trace_parity_matrix(name, sync, granularity, engine, seed):
    """The two projections emit identical canonical streams — compared
    with ``==`` on every event's (node, t, kind, dur, attrs)."""
    assert_trace_parity(_spec(name, sync, granularity, engine, seed), epochs=2)


@settings(max_examples=12)
@given(
    name=st.sampled_from(CONDITION_NAMES),
    sync=st.sampled_from(["epoch", "batch"]),
    seed=st.integers(min_value=0, max_value=3),
)
def test_trace_engine_equivalence(name, sync, seed):
    """Scalar stepping and vector segment-commit synthesis produce the
    same event multiset: the vector engine reconstructs per-sample demand
    spans, compute boundaries and cache inserts from its cumsum arrays."""
    scalar, _, _ = _traced_sim_run(_spec(name, sync, "step", "scalar", seed))
    vector, _, _ = _traced_sim_run(_spec(name, sync, "step", "vector", seed))
    assert canonical_stream(scalar.events) == canonical_stream(vector.events)


def test_trace_parity_under_stragglers():
    """Heterogeneous profiles skew every per-node float; the streams must
    still match event for event."""
    profs = straggler_profiles(_W.n_nodes, (0,), 2.0, 2.0)
    spec = dataclasses.replace(
        condition("fifty-fifty", _W, cache_items=64), nodes=profs, sync="batch"
    )
    assert_trace_parity(spec, epochs=2)


def test_trace_parity_report_diverged_renders():
    """A manufactured divergence is reported with the first differing
    event pair (not just a bare AssertionError)."""
    a, b = TraceRecorder(), TraceRecorder()
    a.emit("demand", 0, 1.0, 0.5, idx=3, tier="ram", class_b=0)
    b.emit("demand", 0, 1.0, 0.5, idx=4, tier="ram", class_b=0)
    from repro.obs.parity import TraceParityReport

    report = TraceParityReport(
        spec_label="manufactured",
        epochs=1,
        sim_stream=canonical_stream(a.events),
        runtime_stream=canonical_stream(b.events),
    )
    assert not report.exact
    pair = report.first_divergence()
    assert pair is not None and pair[0] != pair[1]
    assert "DIVERGED" in report.describe()


# ---------------------------------------------------------------------------
# 2. Ledger reconciliation: sum-of-ledger == counters, exactly.
# ---------------------------------------------------------------------------
@settings(max_examples=15)
@given(
    name=st.sampled_from(CONDITION_NAMES),
    sync=st.sampled_from(["epoch", "batch"]),
    engine=st.sampled_from(["scalar", "vector"]),
    seed=st.integers(min_value=0, max_value=3),
)
def test_ledger_reconciles_counters(name, sync, engine, seed):
    spec = _spec(name, sync, "step", engine, seed)
    rec, stats, store = _traced_sim_run(spec)
    report = assert_reconciles(rec.events, store)
    assert report.n_lines > 0
    # The runtime projection's trace reconciles against ITS counters too.
    run_rec = TraceRecorder()
    with dataclasses.replace(spec, trace=run_rec).build_runtime() as cluster:
        _, run_store = cluster.run(epochs=2)
    assert_reconciles(run_rec.events, run_store)


def test_ledger_lines_attribute_every_charge():
    """Ledger lines split demand GETs from round issues and carry node +
    virtual-time provenance; per-node totals sum to the cluster total."""
    spec = condition("fifty-fifty", _W, cache_items=64)
    rec, _, store = _traced_sim_run(spec)
    lines = build_ledger(rec.events)
    assert {ln.kind for ln in lines} == {"issue", "demand"}
    assert all(ln.class_a >= 0 and ln.class_b >= 0 for ln in lines)
    per_node = per_node_totals(rec.events)
    assert sum(a for a, _ in per_node.values()) == store.class_a_requests
    assert sum(b for _, b in per_node.values()) == store.class_b_requests


# ---------------------------------------------------------------------------
# 3. Observer purity: tracing-off == tracing-on, byte for byte.
# ---------------------------------------------------------------------------
@settings(max_examples=12)
@given(
    name=st.sampled_from(CONDITION_NAMES),
    engine=st.sampled_from(["scalar", "vector"]),
    seed=st.integers(min_value=0, max_value=3),
)
def test_tracing_off_equals_tracing_on(name, engine, seed):
    spec = _spec(name, "batch", "step", engine, seed)
    plain_stats, plain_store = spec.build_sim().run(epochs=2)
    _, traced_stats, traced_store = _traced_sim_run(spec)
    assert [s.asdict() for s in traced_stats] == [s.asdict() for s in plain_stats]
    assert (traced_store.class_a_requests, traced_store.class_b_requests,
            traced_store.bytes_read, traced_store.read_seconds) == (
        plain_store.class_a_requests, plain_store.class_b_requests,
        plain_store.bytes_read, plain_store.read_seconds)


def test_untraced_runtime_rejects_free_running_only():
    """trace= is a lock-step-only knob: the free-running threaded runtime
    has no virtual timeline to record and must refuse loudly."""
    from repro.core import RealClock

    spec = dataclasses.replace(
        condition("cache", _W, cache_items=64), trace=TraceRecorder()
    )
    with pytest.raises(ValueError, match="lock-step"):
        spec.build_runtime(clock=RealClock(scale=1e-4))


# ---------------------------------------------------------------------------
# EpochStats: wall_seconds + asdict round-trip (satellite 1).
# ---------------------------------------------------------------------------
def test_epoch_stats_wall_seconds_and_asdict_round_trip():
    s = EpochStats(
        epoch=1, node=2, samples=10,
        data_wait_seconds=0.5, compute_seconds=0.25,
        allreduce_wait_seconds=0.125, allreduce_comm_seconds=0.0625,
        evictions=3, tier_hits={"ram": 7, "bucket": 3},
    )
    assert s.wall_seconds == 0.5 + 0.25 + 0.125 + 0.0625
    assert s.wall_clock_seconds == s.wall_seconds  # legacy alias
    d = s.asdict()
    assert EpochStats(**d) == s
    d["tier_hits"]["ram"] = 0  # copied, never aliased
    assert s.tier_hits["ram"] == 7
    json.dumps(d)  # stable plain-dict form is JSON-serializable


def test_epoch_stats_asdict_round_trips_from_real_run():
    stats, _ = condition("cache", _W, cache_items=64).build_sim().run(epochs=2)
    for s in stats:
        assert EpochStats(**s.asdict()) == s


# ---------------------------------------------------------------------------
# Exporters: Chrome trace-event JSON + text views.
# ---------------------------------------------------------------------------
def test_chrome_export_validates_and_round_trips(tmp_path):
    spec = dataclasses.replace(
        condition("overlap", _W, cache_items=64), sync="batch"
    )
    rec, stats, _ = _traced_sim_run(spec)
    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), rec.events)
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == []
    assert canonical_stream(events_from_chrome(doc)) == canonical_stream(rec.events)
    # One track per rank with the fixed lanes, metadata included.
    pids = {r["pid"] for r in doc["traceEvents"]}
    assert pids >= {1, 2, 3}  # one process per rank (pid = node + 1)
    names = {r["args"]["name"] for r in doc["traceEvents"] if r["ph"] == "M"
             and r["name"] == "thread_name"}
    assert names == {"data-wait", "compute", "allreduce", "events"}


def test_chrome_validation_catches_breakage():
    assert validate_chrome_trace({"nope": 1})
    doc = {"traceEvents": [{"name": "demand", "ph": "X", "ts": 1.0,
                            "pid": 1, "tid": 1}]}
    assert any("dur" in p for p in validate_chrome_trace(doc))
    doc = {"traceEvents": [
        {"name": "a", "ph": "i", "ts": 2.0, "pid": 1, "tid": 1},
        {"name": "b", "ph": "i", "ts": 1.0, "pid": 1, "tid": 1},
    ]}
    assert any("monotone" in p for p in validate_chrome_trace(doc))


def test_decomposition_sums_back_to_wall_seconds():
    """Each traced span's duration is the very float the schedule added to
    the matching EpochStats field, so for a one-epoch run the linear fold
    over emission-ordered events reproduces every stats field with ==
    (overlap-exposed tails count as comm, mirroring the accounting)."""
    for name in ("fifty-fifty", "overlap"):
        spec = _spec(name, "batch", "step", "scalar", seed=0)
        rec, stats, _ = _traced_sim_run(spec, epochs=1)
        dec = decomposition(rec.events)
        for s in stats:
            d = dec[s.node]
            assert d["data_wait"] == s.data_wait_seconds
            assert d["compute"] == s.compute_seconds
            assert d["allreduce_wait"] == s.allreduce_wait_seconds
            assert d["allreduce_comm"] == s.allreduce_comm_seconds
            assert (d["data_wait"] + d["compute"] + d["allreduce_wait"]
                    + d["allreduce_comm"]) == s.wall_seconds


def test_text_views_render(tmp_path, capsys):
    spec = condition("fifty-fifty", _W, cache_items=64)
    rec, _, _ = _traced_sim_run(spec, epochs=1)
    table = decomposition_table(rec.events)
    assert "data_wait" in table and "rank" in table
    timeline = text_timeline(rec.events, limit=5)
    assert len(timeline.splitlines()) == 5
    # CLI end-to-end: render + validate.
    path = tmp_path / "t.json"
    write_chrome_trace(str(path), rec.events)
    from repro.obs.__main__ import main

    assert main([str(path), "--limit", "3"]) == 0
    out = capsys.readouterr().out
    assert "wall-time decomposition" in out and "timeline" in out
    assert main([str(path), "--validate"]) == 0
    assert "valid Chrome trace" in capsys.readouterr().out


def test_run_trace_parity_report_describes_exact():
    report = run_trace_parity(condition("cache", _W, cache_items=64), epochs=1)
    assert report.exact
    assert "EXACT" in report.describe()
    assert report.first_divergence() is None
