"""Per-architecture smoke tests: reduced same-family config, one real
forward/train/decode step on CPU; asserts shapes + finiteness.  The FULL
configs are exercised only via the dry-run (no allocation) — see
tests/test_dryrun_lowering.py and launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # XLA-compile-heavy; excluded from the smoke lane

from repro import configs
from repro.models import model as M
from repro.models.config import applicable_shapes


def _smoke_batch(cfg, key, batch=2, seq=64):
    ks = jax.random.split(key, 3)
    out = {}
    if cfg.frontend == "frame":
        out["frame_embeds"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
    out["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    if cfg.frontend == "patch":
        out["patch_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
        )
    return out


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_is_published_shape(arch):
    cfg = configs.get(arch)
    # param_count must land within 12% of the id's nominal size when the id
    # carries one (sanity net for config transcription errors).
    nominal = {
        "jamba-1.5-large-398b": 398e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "dbrx-132b": 132e9,
        "internlm2-20b": 20e9,
        "h2o-danube-3-4b": 4e9,
        "deepseek-coder-33b": 33e9,
        "command-r-35b": 35e9,
        "mamba2-130m": 130e6,
    }
    if arch in nominal:
        n = cfg.param_count()
        assert abs(n - nominal[arch]) / nominal[arch] < 0.12, (arch, n)
    assert applicable_shapes(cfg), arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = configs.reduce_for_smoke(configs.get(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _smoke_batch(cfg, key)
    loss = jax.jit(lambda p, b: M.train_loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    hidden, _ = M.forward(params, cfg, batch, remat=False)
    assert hidden.shape == (2, 64, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step_grads(arch):
    cfg = configs.reduce_for_smoke(configs.get(arch))
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batch = _smoke_batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: M.train_loss(p, cfg, batch)))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat and all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize(
    "arch", [a for a in configs.ARCH_IDS if configs.get(a).causal]
)
def test_smoke_prefill_then_decode(arch):
    cfg = configs.reduce_for_smoke(configs.get(arch))
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    B, S = 2, 32
    batch = _smoke_batch(cfg, key, batch=B, seq=S)
    logits, state = jax.jit(lambda p, b: M.prefill(p, cfg, b))(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    # decode 4 tokens from a fresh max-length state (mirrors decode_32k cells)
    caches, kv_len = M.init_decode_state(cfg, B, S + 8)
    step = jax.jit(lambda p, t, st, pos: M.decode_step(p, cfg, t, st, pos))
    st = (caches, kv_len)
    tok = batch.get("tokens", jnp.zeros((B, S), jnp.int32))[:, :1]
    for pos in range(4):
        logits, st = step(params, tok, st, jnp.int32(pos))
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), (arch, pos)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)


def test_decode_matches_prefill_hybrid():
    """Prefill(t0..t3) and 4 decode steps must produce the same final logits
    — exercises KV-cache write paths and SSM carry handoff end to end.

    f32 + no-drop capacity: capacity-based MoE legitimately drops tokens in
    prefill when an expert overflows, which single-token decode never does,
    so equivalence is only exact when capacity covers all assignments.
    """
    import dataclasses

    cfg = configs.reduce_for_smoke(configs.get("jamba-1.5-large-398b"))
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=16.0)
    key = jax.random.PRNGKey(3)
    params = M.init_params(key, cfg)
    B, S = 1, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits_pre, _ = M.prefill(params, cfg, {"tokens": tokens})

    caches, kv_len = M.init_decode_state(cfg, B, S)
    st = (caches, kv_len)
    for pos in range(S):
        logits_dec, st = M.decode_step(params, cfg, tokens[:, pos : pos + 1], st, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(logits_dec, np.float32),
        rtol=2e-3, atol=2e-3,
    )
