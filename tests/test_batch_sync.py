"""ISSUE 4 tentpole: per-batch allreduce barriers (``sync="batch"``),
sub-step event granularity (``granularity="substep"``) and heterogeneous
node profiles (stragglers) — schedule semantics, exact sim/runtime parity,
and seed-sweep invariants."""
import dataclasses
import math

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (
    MNIST,
    CollectiveModel,
    NodeProfile,
    PrefetchConfig,
    SimConfig,
    mnist_cnn_gradient_bytes,
    simulate_cluster,
    straggler_profiles,
)
from repro.core.lockstep import drive_interleaved_epoch
from repro.core.simulator import NodeSimulator
from repro.core.types import aggregate_tier_hits
from repro.core.workloads import WorkloadSpec
from repro.pipeline import DataPlaneSpec, assert_parity, condition


def _workload(n_samples=600, batch=25, n_nodes=3, compute_s=0.2):
    """Batch-divisible shape: partition % batch == 0, so every node runs
    the same number of gradient batches (the data-parallel regime)."""
    assert (n_samples // n_nodes) % batch == 0
    return WorkloadSpec(
        name="bsync",
        n_samples=n_samples,
        sample_bytes=784,
        batch_size=batch,
        compute_per_epoch_s=compute_s,
        n_nodes=n_nodes,
    )


# ---------------------------------------------------------------------------
# NodeProfile scaling.
# ---------------------------------------------------------------------------
def test_node_profile_identity_is_bitwise_noop():
    """profile(1.0, 1.0) must rebuild bit-identical models — that is what
    keeps homogeneous (default) timelines exactly at their PR 3 values."""
    from repro.core import DEFAULT_BUCKET, DEFAULT_DISK, DEFAULT_NETWORK, DEFAULT_PIPELINE

    p = NodeProfile()
    assert p.scale_bucket(DEFAULT_BUCKET) == DEFAULT_BUCKET
    assert p.scale_disk(DEFAULT_DISK) == DEFAULT_DISK
    assert p.scale_network(DEFAULT_NETWORK) == DEFAULT_NETWORK
    assert p.scale_pipeline(DEFAULT_PIPELINE) == DEFAULT_PIPELINE
    assert p.batch_compute_s(0.123) == 0.123


def test_node_profile_validation_and_helper():
    with pytest.raises(ValueError):
        NodeProfile(compute=0.0)
    with pytest.raises(ValueError):
        NodeProfile(bandwidth=-1.0)
    profs = straggler_profiles(4, slow_ranks=(1, 3), compute=3.0, bandwidth=2.0)
    assert [p.compute for p in profs] == [1.0, 3.0, 1.0, 3.0]
    assert [p.bandwidth for p in profs] == [1.0, 2.0, 1.0, 2.0]


def test_straggler_bandwidth_slows_io_and_compute_slows_loop():
    from repro.core import DEFAULT_BUCKET, DEFAULT_PIPELINE

    p = NodeProfile(compute=2.0, bandwidth=3.0)
    assert p.scale_bucket(DEFAULT_BUCKET).get_seconds(784) == pytest.approx(
        3.0 * DEFAULT_BUCKET.get_seconds(784)
    )
    assert p.scale_pipeline(DEFAULT_PIPELINE).cpu_overhead_s == pytest.approx(
        2.0 * DEFAULT_PIPELINE.cpu_overhead_s
    )


# ---------------------------------------------------------------------------
# The per-batch barrier schedule.
# ---------------------------------------------------------------------------
def test_batch_barrier_fires_once_per_batch_with_all_running_ranks():
    """Direct drive: with equal shards, the allreduce barrier fires exactly
    batches-per-epoch times and every barrier includes every rank."""
    w = _workload()
    cfg = SimConfig(cache_items=-1, sync="batch")
    nodes = [
        NodeSimulator(w, cfg, node_id=r, profile=p)
        for r, p in enumerate(straggler_profiles(w.n_nodes))
    ]
    for rank, node in enumerate(nodes):
        node.begin_epoch(0, list(range(rank, w.n_samples, w.n_nodes)), node=rank)
    barriers = []

    drive_interleaved_epoch(
        len(nodes),
        now=lambda r: nodes[r].t,
        fold_all=lambda t: None,
        step=lambda r: nodes[r].step(),
        barrier=lambda t: [n.sync_to(t) for n in nodes],
        sync="batch",
        batch_barrier=lambda t, ranks: barriers.append((t, tuple(sorted(ranks)))),
    )
    assert len(barriers) == w.partition_size // w.batch_size
    assert all(ranks == (0, 1, 2) for _, ranks in barriers)
    assert [t for t, _ in barriers] == sorted(t for t, _ in barriers)
    for n in nodes:
        n.finish_epoch()


def test_batch_sync_accounts_allreduce_wait_on_fast_nodes_only():
    """A straggler cluster under per-batch sync: the fast nodes block at
    every allreduce (wait > 0), the slowest node essentially never does,
    and per-node wall times equalize (everyone leaves the last barrier
    together)."""
    w = _workload()
    spec = DataPlaneSpec(
        workload=w,
        cache_items=-1,
        sync="batch",
        nodes=straggler_profiles(w.n_nodes, slow_ranks=(2,), compute=2.0, bandwidth=2.0),
    )
    stats, _ = spec.build_sim().run(epochs=1)
    by_node = {s.node: s for s in stats}
    assert by_node[0].allreduce_wait_seconds > 0
    assert by_node[1].allreduce_wait_seconds > 0
    assert by_node[2].allreduce_wait_seconds < by_node[0].allreduce_wait_seconds
    walls = [s.wall_clock_seconds for s in stats]
    assert max(walls) == pytest.approx(min(walls), rel=1e-9)


def test_epoch_sync_default_leaves_allreduce_wait_zero():
    spec = condition("cache", MNIST.scaled(0.02), cache_items=300)
    stats, _ = spec.build_sim().run(epochs=2)
    assert all(s.allreduce_wait_seconds == 0.0 for s in stats)


def test_batch_sync_requires_interleaved_schedule():
    w = _workload()
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=-1, sync="batch", interleaved=False)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=-1, granularity="substep", interleaved=False)
    with pytest.raises(ValueError):
        simulate_cluster(w, SimConfig(cache_items=-1, sync="batch"), interleaved=False)
    with pytest.raises(ValueError):
        SimConfig(sync="sometimes")
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=-1, nodes=(NodeProfile(),))  # wrong arity
    # The free-running threaded runtime cannot implement either knob: it
    # must refuse loudly (docs/PARITY.md: restrict the domain, never
    # silently ignore), not report allreduce_wait == 0 for a schedule the
    # caller asked for.
    from repro.core import RealClock

    for bad in (
        DataPlaneSpec(workload=w, cache_items=-1, sync="batch"),
        DataPlaneSpec(workload=w, cache_items=-1, granularity="substep"),
    ):
        with pytest.raises(ValueError):
            bad.build_runtime(clock=RealClock(scale=1e-4))


def test_batch_sync_bounds_runahead_through_peer_visibility():
    """Observable schedule difference: two nodes stream the shared dataset,
    one 4x slower.  Under epoch sync the fast node finishes long before
    the slow node populates its cache; under batch sync the fast node is
    held to one-batch lockstep, so it sees strictly more of the slow
    node's same-epoch fills (peer hits go up)."""
    w = WorkloadSpec(
        name="shared", n_samples=400, sample_bytes=784, batch_size=20,
        compute_per_epoch_s=0.1, n_nodes=2,
    )
    base = DataPlaneSpec(
        workload=w,
        cache_items=-1,
        peer_cache=True,
        sampler="shared-shuffle",
        nodes=(NodeProfile(), NodeProfile(compute=4.0, bandwidth=4.0)),
    )
    e_stats, _ = base.build_sim().run(epochs=1)
    b_stats, _ = dataclasses.replace(base, sync="batch").build_sim().run(epochs=1)
    fast_epoch = [s for s in e_stats if s.node == 0][0]
    fast_batch = [s for s in b_stats if s.node == 0][0]
    assert fast_batch.peer_hits > fast_epoch.peer_hits
    assert fast_batch.allreduce_wait_seconds > 0


# ---------------------------------------------------------------------------
# Sub-step granularity.
# ---------------------------------------------------------------------------
def test_substep_changes_capped_peer_outcomes():
    """Demand inserts land at their true arrival time under sub-step
    events, so a code-later-but-time-earlier peer probe no longer sees
    them: capped-cache shared-shuffle outcomes shift (deterministically)
    versus the step schedule."""
    w = WorkloadSpec(
        name="shared", n_samples=900, sample_bytes=784, batch_size=32,
        compute_per_epoch_s=0.2, n_nodes=3,
    )
    base = DataPlaneSpec(
        workload=w, cache_items=300, peer_cache=True, sampler="shared-shuffle"
    )
    step_stats, step_store = base.build_sim().run(epochs=2)
    sub_stats, sub_store = (
        dataclasses.replace(base, granularity="substep").build_sim().run(epochs=2)
    )
    step_peer = aggregate_tier_hits(step_stats).get("peer", 0)
    sub_peer = aggregate_tier_hits(sub_stats).get("peer", 0)
    assert (step_peer, step_store.class_b_requests) != (
        sub_peer,
        sub_store.class_b_requests,
    )
    # Conservation: every read is still served by exactly one tier.
    assert sum(s.samples for s in sub_stats) == 2 * w.n_samples * w.n_nodes


def test_substep_equals_step_for_non_interacting_nodes_outcomes():
    """Without a peer tier nothing can observe mid-access state: sub-step
    decomposition must not change tier outcomes or Class B totals (the
    event *boundaries* move; the decisions and charges do not)."""
    w = MNIST.scaled(0.02)
    cfg = condition("cache", w, cache_items=300)
    a_stats, a_store = cfg.build_sim().run(epochs=2)
    b_stats, b_store = (
        dataclasses.replace(cfg, granularity="substep").build_sim().run(epochs=2)
    )
    assert aggregate_tier_hits(a_stats) == aggregate_tier_hits(b_stats)
    assert a_store.class_b_requests == b_store.class_b_requests
    assert [s.data_wait_seconds for s in a_stats] == [
        s.data_wait_seconds for s in b_stats
    ]


# ---------------------------------------------------------------------------
# Exact parity (acceptance criterion): batch sync, stragglers, sub-step —
# prefetch on and off.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "tag,overrides,prefetch",
    [
        ("batch-cache", dict(sync="batch"), False),
        ("batch-peer", dict(sync="batch", peer_cache=True), False),
        ("batch-peer-pf", dict(sync="batch", peer_cache=True), True),
        ("straggler", dict(sync="batch", peer_cache=True, straggler=True), False),
        ("straggler-pf", dict(sync="batch", peer_cache=True, straggler=True), True),
        ("substep-peer-pf", dict(granularity="substep", peer_cache=True), True),
        (
            "substep-batch-straggler-pf",
            dict(sync="batch", granularity="substep", peer_cache=True, straggler=True),
            True,
        ),
        # ISSUE 8 knobs folded into the same sweep: collective cost,
        # bucket overlap, and mitigation ride the identical parity bar.
        (
            "batch-comm-straggler-pf",
            dict(sync="batch", comm=True, peer_cache=True, straggler=True),
            True,
        ),
        (
            "substep-comm-ovl-pf",
            dict(sync="batch", granularity="substep", comm=True, overlap="buckets", peer_cache=True),
            True,
        ),
        (
            "batch-comm-backup-straggler",
            dict(sync="batch", comm=True, backup_workers=1, straggler=True),
            False,
        ),
    ],
)
def test_sim_runtime_parity_exact_batch_and_straggler(tag, overrides, prefetch):
    """ISSUE 4 acceptance: assert_parity (exact ==; per-tier hits, Class
    A+B, data-wait AND allreduce-wait floats; no tolerances) covers
    sync="batch", granularity="substep" and straggler specs, prefetch on
    and off."""
    w = MNIST.scaled(0.02)
    overrides = dict(overrides)
    if overrides.pop("straggler", False):
        overrides["nodes"] = straggler_profiles(
            w.n_nodes, slow_ranks=(0,), compute=2.0, bandwidth=2.0
        )
    if overrides.pop("comm", False):
        overrides["collective"] = CollectiveModel(
            gradient_bytes=mnist_cnn_gradient_bytes()
        )
    spec = DataPlaneSpec(
        workload=w,
        cache_items=300,
        prefetch=PrefetchConfig.fifty_fifty(300) if prefetch else None,
        **overrides,
    )
    report = assert_parity(spec, epochs=2)
    if spec.sync == "batch":
        assert sum(row[4] for row in report.sim_samples) > 0  # allreduce seen
    if spec.collective is not None and spec.backup_workers == 0:
        assert sum(row[5] for row in report.sim_samples) > 0  # comm charged
    if prefetch:
        assert report.sim_tiers.get("ram", 0) > 0


# ---------------------------------------------------------------------------
# Straggler invariants (seed sweeps through the hypothesis fallback).
# ---------------------------------------------------------------------------
@settings(max_examples=8)
@given(
    seed=st.integers(0, 10_000),
    slow=st.integers(0, 2),
    comp=st.sampled_from([1.0, 1.5, 2.0, 4.0]),
    bw=st.sampled_from([1.0, 2.0, 3.0]),
)
def test_straggler_invariants_batch_vs_epoch_sync(seed, slow, comp, bw):
    """For cache-only (non-interacting) straggler clusters:

    1. the schedules agree exactly on tier outcomes and Class A/B totals
       and (up to barrier-induced float re-basing: durations are measured
       as ``t_after - t_before`` against differently-jumped clocks) on
       data-wait — barriers move clocks, not cache behaviour;
    2. per-node wall time under batch sync >= epoch sync (allreduce waits
       only add);
    3. the slowest-node bound: every node's batch-sync wall time >= the
       busiest node's own busy time (sum of per-batch maxima >= any node's
       own sum);
    4. the batch-sync interleaved schedule is deterministic across runs.
    """
    w = _workload()
    profiles = straggler_profiles(w.n_nodes, slow_ranks=(slow,), compute=comp, bandwidth=bw)
    base = DataPlaneSpec(
        workload=w,
        cache_items=w.partition_size // 2,
        nodes=profiles,
        seed=seed % 7,  # samplers reshuffle per seed; keep a few distinct
    )
    e_stats, e_store = base.build_sim().run(epochs=2)
    b_stats, b_store = dataclasses.replace(base, sync="batch").build_sim().run(epochs=2)
    assert [(s.epoch, s.node, s.samples, s.tier_hits) for s in e_stats] == [
        (s.epoch, s.node, s.samples, s.tier_hits) for s in b_stats
    ]
    for e_row, b_row in zip(e_stats, b_stats):
        assert math.isclose(
            e_row.data_wait_seconds, b_row.data_wait_seconds, rel_tol=1e-9
        )
    assert (e_store.class_a_requests, e_store.class_b_requests) == (
        b_store.class_a_requests,
        b_store.class_b_requests,
    )
    for e_row, b_row in zip(e_stats, b_stats):
        assert b_row.wall_clock_seconds >= e_row.wall_clock_seconds * (1 - 1e-12)
    for epoch in (0, 1):
        rows = [s for s in b_stats if s.epoch == epoch]
        busiest = max(r.data_wait_seconds + r.compute_seconds for r in rows)
        for r in rows:
            assert r.wall_clock_seconds >= busiest * (1 - 1e-9)
    b2_stats, b2_store = dataclasses.replace(base, sync="batch").build_sim().run(epochs=2)
    assert [dataclasses.asdict(s) for s in b_stats] == [
        dataclasses.asdict(s) for s in b2_stats
    ]
    assert b_store == b2_store


def test_straggler_condition_registered():
    w = MNIST.scaled(0.02)
    spec = condition("straggler", w, cache_items=300)
    assert spec.sync == "batch" and spec.peer_cache
    assert spec.nodes is not None and spec.nodes[0].compute == 2.0
    assert "straggler" in spec.label() and "+bsync" in spec.label()
    bspec = condition("batch-sync", w)
    assert bspec.sync == "batch" and bspec.nodes is None
