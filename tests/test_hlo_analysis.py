"""Unit tests for the trip-count-aware HLO analyzer on a hand-written
module: loop multiplication, dot FLOPs, window-based HBM traffic, and
collective operand accounting."""
from repro.launch.hlo_analysis import HloAnalyzer

HLO = """
HloModule jit_step, is_scheduled=true

%loop_cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%ew_only (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  ROOT %t = f32[8,16] tanh(%a)
}

%loop_body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] parameter(1)
  %d = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%ew_only
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[8,16]) tuple(%ni, %ar)
}

ENTRY %main (x: f32[8,16], w: f32[16,16], big: f32[100,8,16]) -> f32[8,16] {
  %x = f32[8,16] parameter(0)
  %w = f32[16,16] parameter(1)
  %big = f32[100,8,16] parameter(2)
  %zero = s32[] constant(0)
  %sl = f32[1,8,16] dynamic-slice(%big, %zero, %zero, %zero), dynamic_slice_sizes={1,8,16}
  %ew = f32[8,16] fusion(%x), kind=kLoop, calls=%ew_only
  %init = (s32[], f32[8,16]) tuple(%zero, %ew)
  %loop = (s32[], f32[8,16]) while(%init), condition=%loop_cond, body=%loop_body
  ROOT %r = f32[8,16] get-tuple-element(%loop), index=1
}
"""


def test_trip_count_from_condition_constant():
    a = HloAnalyzer(HLO, n_devices=256)
    a.collective_bytes()
    assert a.loop_trips == {"loop": 24}


def test_dot_flops_multiplied_by_trips():
    a = HloAnalyzer(HLO, n_devices=256)
    # dot: 2*M*N*K = 2*8*16*16 = 4096 per iter, x24 iters; plus tanh 128/iter
    # elementwise + entry fusion tanh 128
    f = a.flops()
    assert f >= 24 * 4096
    assert f <= 24 * 4096 + 24 * 200 + 200


def test_collectives_counted_per_iteration():
    a = HloAnalyzer(HLO, n_devices=256)
    a.collective_bytes()
    summary = a.collective_summary()
    assert summary["all-reduce"]["count"] == 24
    assert summary["all-reduce"]["operand_bytes"] == 24 * 8 * 16 * 4


def test_window_traffic_not_buffer_traffic():
    a = HloAnalyzer(HLO, n_devices=256)
    b = a.hbm_bytes()
    # dynamic-slice must charge 2x window (2*1*8*16*4 = 1024 B), NOT the
    # 100x larger source buffer; pure-elementwise fusion charges nothing.
    window = 2 * 8 * 16 * 4
    dot_per_iter = (8 * 16 + 16 * 16 + 8 * 16) * 4
    ar_per_iter = 2 * 8 * 16 * 4
    expected_max = window + 24 * (dot_per_iter + ar_per_iter) + 4096
    assert b <= expected_max, b
    assert b >= 24 * dot_per_iter
