"""ISSUE 5 tentpole: the oracle data plane — clairvoyant access views,
Belady (farthest-future-use) eviction as a pluggable policy, the
OraclePrefetchPlanner, and exact sim/runtime parity for oracle specs."""
import dataclasses
import warnings

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (
    MNIST,
    CappedCache,
    DistributedPartitionSampler,
    FifoEviction,
    PrefetchConfig,
    RealClock,
    SimConfig,
    straggler_profiles,
)
from repro.distributed import PeerCacheRegistry
from repro.oracle import (
    NEVER,
    AccessOracle,
    BeladyEviction,
    NodeAccessView,
    OraclePrefetchPlanner,
    planner_for,
    replayable,
)
from repro.pipeline import DataPlaneSpec, assert_parity, condition
from repro.pipeline.spec import DataPlaneConfigWarning


# ---------------------------------------------------------------------------
# NodeAccessView / AccessOracle.
# ---------------------------------------------------------------------------
def test_view_next_use_follows_cursor():
    view = NodeAccessView()
    view.begin_epoch(0, [3, 1, 4, 1, 5])
    assert view.next_use(3) == 0
    assert view.next_use(1) == 1
    assert view.next_use(9) == NEVER
    view.on_consume(3)
    view.on_consume(1)
    assert view.next_use(3) == NEVER  # consumed, never reused this horizon
    assert view.next_use(1) == 3  # the second occurrence
    view.on_consume(4)
    view.on_consume(1)
    assert view.next_use(1) == NEVER
    assert view.next_use(5) == 4


def test_access_oracle_replays_future_epochs():
    """The partition sampler is a pure function of its epoch, so the view
    sees the NEXT epoch's exact order too: a key consumed this epoch has a
    finite next_use at (this-epoch length + its epoch-1 position)."""
    sampler = DistributedPartitionSampler(60, rank=0, world=3, seed=5)
    assert replayable(sampler)
    oracle = AccessOracle([sampler], horizon=1)
    view = oracle.view(0)
    sampler.set_epoch(0)
    order0 = sampler.indices()
    view.begin_epoch(0, order0)
    assert view.lookahead_epochs == 1
    assert sampler.epoch == 0  # replay restored the sampler's epoch
    sampler.set_epoch(1)
    order1 = sampler.indices()
    sampler.set_epoch(0)
    for idx in order0:
        view.on_consume(idx)
    for idx in order0:
        if idx in order1:
            assert view.next_use(idx) == len(order0) + order1.index(idx)
        else:
            assert view.next_use(idx) == NEVER


def test_locality_sampler_is_not_replayed():
    """Locality orders depend on future cache views that do not exist yet;
    the oracle must refuse to replay a wrong future (current-epoch horizon
    only — still exact, the driver feeds the realized order)."""
    from repro.core import LocalityAwareSampler

    sampler = LocalityAwareSampler(60, rank=0, world=3, seed=0)
    assert not replayable(sampler)
    oracle = AccessOracle([sampler])
    view = oracle.view(0)
    view.begin_epoch(0, [1, 2, 3])
    assert view.lookahead_epochs == 0
    assert view.next_use(1) == 0 and view.next_use(7) == NEVER


# ---------------------------------------------------------------------------
# OraclePrefetchPlanner invariants (seed-swept).
# ---------------------------------------------------------------------------
@settings(max_examples=20)
@given(
    n=st.integers(min_value=1, max_value=200),
    capacity=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_oracle_planner_invariants(n, capacity, seed):
    """Every index yields exactly once in order; announced-but-unconsumed
    never exceeds the window (no fetch can evict a still-needed sample);
    rounds are deadline-ordered prefixes of the future sequence."""
    import random

    order = list(range(n))
    random.Random(seed).shuffle(order)
    planner = OraclePrefetchPlanner(order, capacity=capacity)
    window = min(capacity, n)
    consumed, announced_keys = [], []
    pending_high = 0
    for idx, round_ in planner:
        if round_ is not None:
            announced_keys += round_
        consumed.append(idx)
        pending_high = max(pending_high, len(announced_keys) - len(consumed) + 1)
    assert consumed == order
    assert announced_keys == order  # every key fetched once, in deadline order
    assert pending_high <= window
    assert planner.rounds_issued >= 1


@settings(max_examples=15)
@given(
    resident_mask=st.integers(min_value=0, max_value=(1 << 16) - 1),
    capacity=st.integers(min_value=2, max_value=20),
)
def test_oracle_planner_filters_resident_keys(resident_mask, capacity):
    """Keys already cached at announce time are skipped (no re-fetched
    Class B); everything else is announced exactly once."""
    n = 16
    resident = {k for k in range(n) if resident_mask >> k & 1}
    planner = OraclePrefetchPlanner(
        list(range(n)), capacity=capacity, resident=resident.__contains__
    )
    announced = [k for _, r in planner if r is not None for k in r]
    assert set(announced) == set(range(n)) - resident
    assert planner.resident_skips == len(resident)


def test_planner_for_is_the_shared_construction():
    p = planner_for([1, 2, 3], policy="oracle", config=None, capacity=2)
    assert isinstance(p, OraclePrefetchPlanner)
    from repro.core import PrefetchPlanner

    p = planner_for([1, 2, 3], policy="paper", config=PrefetchConfig(fetch_size=2))
    assert isinstance(p, PrefetchPlanner)
    with pytest.raises(ValueError):
        planner_for([1], policy="psychic", config=None)


# ---------------------------------------------------------------------------
# Belady eviction invariants (seed-swept, ISSUE 5 satellite).
# ---------------------------------------------------------------------------
class _RecordingBelady(BeladyEviction):
    """Instrument victim selection: snapshot (victim, kept) next-uses."""

    def __init__(self, view):
        super().__init__(view)
        self.decisions = []

    def select_victim(self, entries, guard):
        uses = {key.index: self.view.next_use(key.index) for key in entries}
        victim, skips = super().select_victim(entries, guard)
        self.decisions.append((victim.index, uses, guard))
        return victim, skips


@settings(max_examples=20)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    capacity=st.integers(min_value=1, max_value=12),
)
def test_belady_never_evicts_a_key_reused_sooner_than_a_kept_key(seed, capacity):
    """THE Belady invariant: at every eviction, the victim's next use is
    >= every kept (unguarded) entry's next use — over synthetic sequences
    WITH within-epoch reuse, driven through a real CappedCache."""
    import random

    rng = random.Random(seed)
    order = [rng.randrange(24) for _ in range(120)]
    view = NodeAccessView()
    view.begin_epoch(0, order)
    policy = _RecordingBelady(view)
    cache = CappedCache(max_items=capacity, eviction_policy=policy)
    for idx in order:
        view.on_consume(idx)
        if cache.get(idx) is None:
            cache.put(idx, b"x")
    assert policy.decisions, "capacity pressure must have evicted something"
    for victim, uses, _ in policy.decisions:
        assert all(uses[victim] >= use for use in uses.values()), (
            f"victim {victim} (next_use {uses[victim]}) evicted before "
            f"a farther-future key: {uses}"
        )


@settings(max_examples=10)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_fifo_and_belady_agree_when_capacity_covers_working_set(seed):
    """With capacity >= the whole working set nothing is ever evicted, so
    the two policies must produce byte-identical outcomes."""
    w = dataclasses.replace(MNIST.scaled(0.02), n_nodes=3)
    results = {}
    for eviction in ("fifo", "belady"):
        spec = DataPlaneSpec(
            workload=w, cache_items=w.n_samples, eviction=eviction, seed=seed % 7
        )
        stats, store = spec.build_sim().run(epochs=2)
        results[eviction] = (
            [(s.epoch, s.node, s.samples, s.tier_hits, s.data_wait_seconds) for s in stats],
            store.class_b_requests,
        )
    assert results["fifo"] == results["belady"]


def test_replication_guard_declines_last_copy_under_belady():
    """ISSUE 5 satellite: the Hoard-style guard composes with Belady — the
    farthest-future victim is skipped when it is the last cluster-resident
    copy, and ``guard_skips`` counts the redirect."""
    reg = PeerCacheRegistry(replication_aware=True)
    view = NodeAccessView()
    # Future: 1 is needed soon, 2 later, 3 soonest; 9 is never needed.
    view.begin_epoch(0, [3, 1, 2])
    c0 = CappedCache(max_items=3, eviction_policy=BeladyEviction(view))
    c1 = CappedCache(max_items=3)
    reg.register(0, c0)
    reg.register(1, c1)
    c0.put(9, b"x")  # Belady victim (never used again) — but last copy
    c0.put(1, b"x")
    c1.put(1, b"x")  # 1 is replicated: evictable without cluster data loss
    c0.put(2, b"x")
    c0.put(3, b"x")  # over capacity: Belady says 9, guard redirects
    assert c0.contains(9)  # last cluster copy survived
    assert not c0.contains(1)  # farthest-future *replicated* entry went
    assert c0.contains(2) and c0.contains(3)
    # Two protections outranked the victim in Belady order (9: never
    # reused; 2: reused later than 1) — both redirects are counted.
    assert c0.stats.guard_skips == 2


def test_belady_all_guarded_falls_back_to_unrestricted_choice():
    view = NodeAccessView()
    view.begin_epoch(0, [1, 2])
    cache = CappedCache(max_items=2, eviction_policy=BeladyEviction(view))
    cache.eviction_guard = lambda idx: True  # everything is a last copy
    cache.put(1, b"a")
    cache.put(2, b"b")
    cache.put(3, b"c")  # 3 unneeded: it IS the unrestricted Belady victim
    assert not cache.contains(3)
    assert cache.contains(1) and cache.contains(2)
    assert cache.stats.guard_skips == 0  # capacity fallback, no redirect


def test_belady_without_view_raises():
    cache = CappedCache(max_items=1, eviction_policy=BeladyEviction())
    cache.put(1, b"a")
    with pytest.raises(RuntimeError):
        cache.put(2, b"b")


def test_fifo_eviction_policy_is_the_default():
    cache = CappedCache(max_items=2)
    assert isinstance(cache.eviction_policy, FifoEviction)
    cache.put(1, b"a")
    cache.put(2, b"b")
    cache.put(3, b"c")
    assert not cache.contains(1)  # oldest insert went first


# ---------------------------------------------------------------------------
# Spec surface: validation, labels, warnings (ISSUE 5 satellite).
# ---------------------------------------------------------------------------
def test_oracle_spec_validation():
    w = MNIST.scaled(0.02)
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, eviction="belady")  # needs a cache
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, prefetch_policy="oracle")  # needs a cache
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, source="disk", cache_items=8, eviction="belady")
    with pytest.raises(ValueError):  # the oracle has no knobs
        DataPlaneSpec(
            workload=w,
            cache_items=64,
            prefetch_policy="oracle",
            prefetch=PrefetchConfig.fifty_fifty(64),
        )
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=64, eviction="lru")
    with pytest.raises(ValueError):
        DataPlaneSpec(workload=w, cache_items=64, prefetch_policy="psychic")
    # The free-running threaded runtime has no deterministic cursor.
    spec = condition("oracle", w, cache_items=64)
    with pytest.raises(ValueError):
        spec.build_runtime(clock=RealClock(scale=1e-4))


def test_oracle_labels_and_sim_config_round_trip():
    w = MNIST.scaled(0.02)
    spec = condition("oracle+peer", w, cache_items=64)
    assert "+belady" in spec.label() and "+pf(oracle)" in spec.label()
    cfg = spec.to_sim_config()
    assert cfg.eviction == "belady" and cfg.prefetch_policy == "oracle"
    assert DataPlaneSpec.from_sim_config(w, cfg).to_sim_config() == cfg
    with pytest.raises(ValueError):
        SimConfig(cache_items=64, prefetch_policy="oracle",
                  prefetch=PrefetchConfig.fifty_fifty(64))


def test_spec_construction_surfaces_policy_warnings():
    """ISSUE 5 satellite: the pure-logic config lint (core/policy.py) now
    fires at DataPlaneSpec construction — cache smaller than fetch size is
    the paper's Fig. 7 churn regime and warns; the 50/50 point does not."""
    w = MNIST.scaled(0.02)
    with pytest.warns(DataPlaneConfigWarning, match="fetch"):
        DataPlaneSpec(
            workload=w, cache_items=32, prefetch=PrefetchConfig(fetch_size=64)
        )
    with warnings.catch_warnings():
        warnings.simplefilter("error", DataPlaneConfigWarning)
        DataPlaneSpec(
            workload=w, cache_items=128, prefetch=PrefetchConfig.fifty_fifty(128)
        )
        condition("oracle", w, cache_items=128)  # the oracle has no knobs


# ---------------------------------------------------------------------------
# Exact sim/runtime parity for oracle specs (acceptance criterion).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["belady-only", "oracle", "oracle+peer"])
@pytest.mark.parametrize(
    "schedule",
    [
        {},
        dict(sync="batch"),
        dict(granularity="substep"),
        dict(
            sync="batch",
            granularity="substep",
            nodes=straggler_profiles(3, (0,), 2.0, 2.0),
        ),
    ],
    ids=["epoch-step", "batch", "substep", "batch+substep+straggler"],
)
def test_oracle_parity_exact(name, schedule):
    """assert_parity passes with exact == (per-tier hits, Class A+B,
    data-wait, allreduce waits) for Belady-eviction and oracle-prefetch
    specs under every cluster schedule — extended by sharing the
    implementation (repro.oracle built by both projections), never by
    tolerances."""
    spec = condition(name, MNIST.scaled(0.02), cache_items=200, **schedule)
    report = assert_parity(spec, epochs=2)
    assert report.sim_samples == report.runtime_samples
    if name != "belady-only":
        assert report.sim_tiers.get("ram", 0) > 0  # clairvoyant rounds hit


def test_oracle_parity_with_shared_shuffle_and_locality():
    """The oracle derives exact orders from ANY registry sampler: the
    Hoard-style shared-shuffle regime (full dataset per node, replayable)
    and the locality-aware order (not replayable — current-epoch horizon)
    both stay parity-exact."""
    w = MNIST.scaled(0.02)
    for sampler in ("shared-shuffle", "locality"):
        spec = condition("oracle", w, cache_items=200, sampler=sampler)
        assert_parity(spec, epochs=2)


def test_oracle_beats_heuristics_at_equal_capacity():
    """Pin fig12's claims at test scale: clairvoyant prefetch data-wait <=
    demand and <= the paper's best heuristic (50/50), and Belady Class B <=
    FIFO Class B under cache pressure, at equal capacity."""
    w = MNIST.scaled(0.02)
    C = w.partition_size // 2  # real cache pressure

    def run(name, **kw):
        stats, store = condition(name, w, cache_items=C, **kw).build_sim().run(epochs=2)
        return sum(s.data_wait_seconds for s in stats), store.class_b_requests

    demand_wait, demand_b = run("cache")
    belady_wait, belady_b = run("belady-only")
    fifty_wait, _ = run("fifty-fifty")
    oracle_wait, oracle_b = run("oracle")
    assert belady_b <= demand_b
    assert belady_wait <= demand_wait
    assert oracle_wait <= fifty_wait
    assert oracle_wait <= demand_wait


def test_oracle_loader_mid_epoch_resume_no_rebilling(payloads_1k):
    """Mid-epoch checkpoint/restore with the clairvoyant planner: the
    resumed loader replays announced rounds (``replay=True`` filters
    still-cached keys — no re-billed Class B), the oracle cursor re-syncs
    through the replay branch, and the remainder is consumed exactly
    once."""
    from repro.core import (
        CachingDataset,
        DeliLoader,
        LockstepPrefetchService,
        SimulatedBucketStore,
        VirtualClock,
    )
    from repro.oracle import make_planner_factory

    n = len(payloads_1k)
    clock = VirtualClock()
    store = SimulatedBucketStore(payloads_1k, clock=clock)
    sampler = DistributedPartitionSampler(n, 0, 1, seed=0)
    view = AccessOracle([sampler]).view(0)
    cache = CappedCache(eviction_policy=BeladyEviction(view))  # unlimited
    from repro.core import DEFAULT_BUCKET, DEFAULT_NETWORK

    svc = LockstepPrefetchService(
        cache,
        sample_bytes=1024,
        n_samples=n,
        bucket=DEFAULT_BUCKET,
        network=DEFAULT_NETWORK,
        store_stats=store.stats,
        payload_for=payloads_1k.__getitem__,
        clock=clock,
        list_every_fetch=False,
    )
    ds = CachingDataset(store, cache, insert_on_miss=False)
    factory = make_planner_factory(policy="oracle", config=None, resident=cache.contains)

    def fresh_loader():
        return DeliLoader(
            ds,
            sampler,
            16,
            PrefetchConfig.disabled(),
            service=svc,
            clock=clock,
            planner_factory=factory,
            oracle_view=view,
        )

    loader = fresh_loader()
    loader.set_epoch(0)
    it = iter(loader)
    first = [next(it) for _ in range(4)]
    svc.advance_to(float("inf"))  # in-flight rounds land before the crash
    state = loader.state_dict()
    it.close()  # simulated crash mid-epoch
    loader2 = fresh_loader()
    loader2.load_state_dict(state)
    rest = list(loader2)
    svc.advance_to(float("inf"))  # land the epoch's trailing rounds
    consumed = [i for b in first + rest for i in b.indices]
    assert sorted(consumed) == sorted(payloads_1k)
    assert len(consumed) == len(set(consumed))
    # Replayed rounds were fully resident (unlimited cache, drained before
    # the crash): the service round-fetched every key exactly once despite
    # the restart, and every Class B GET is accounted — one round GET per
    # key plus the demand GETs that raced in-flight rounds.
    assert svc.samples_fetched == n
    demand_gets = sum(b.misses for b in first) + (
        loader2.last_epoch_stats.tier("bucket")
    )
    assert store.stats.class_b_requests == n + demand_gets


def test_oracle_peer_rounds_never_bill_class_b_for_cluster_resident_keys():
    """The planner composes with the shared service's peer partition: with
    an unlimited cache and the shared-shuffle regime, epoch-2 rounds pull
    cluster-resident keys from peers — strictly fewer Class B than the
    peer-less oracle at equal capacity."""
    w = MNIST.scaled(0.02)
    _, solo = (
        condition("oracle", w, cache_items=300).build_sim().run(epochs=2)
    )
    stats, peer = (
        condition("oracle+peer", w, cache_items=300).build_sim().run(epochs=2)
    )
    assert peer.class_b_requests < solo.class_b_requests
    from repro.core import aggregate_tier_hits

    assert aggregate_tier_hits(stats).get("peer", 0) > 0
