"""Property tests for the pre-fetch planner — the paper's §III-B semantics."""
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: deterministic fallback sweep
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import PrefetchConfig, PrefetchPlanner, validate_config_against_cache
from repro.core.policy import expected_rounds


def test_fifty_fifty_construction():
    cfg = PrefetchConfig.fifty_fifty(2048)
    assert cfg.fetch_size == 1024 and cfg.prefetch_threshold == 1024
    assert cfg.cache_items == 2048


def test_full_fetch_construction():
    cfg = PrefetchConfig.full_fetch(1024)
    assert cfg.fetch_size == 1024 and cfg.prefetch_threshold == 0
    assert cfg.cache_items == 1024


def test_invalid_configs():
    with pytest.raises(ValueError):
        PrefetchConfig(fetch_size=0)
    with pytest.raises(ValueError):
        PrefetchConfig(fetch_size=4, prefetch_threshold=-1)
    with pytest.raises(ValueError):
        PrefetchConfig.fifty_fifty(1)


def test_threshold_zero_fetches_only_on_depletion():
    """Paper default: a new round only when the queue is depleted."""
    order = list(range(10))
    planner = PrefetchPlanner(order, PrefetchConfig(fetch_size=4, prefetch_threshold=0))
    rounds_at = [i for i, (_, r) in enumerate(planner) if r is not None]
    # Rounds at consumption steps 0, 4, 8 (exactly when pending hits 0).
    assert rounds_at == [0, 4, 8]


def test_threshold_prefetches_early():
    order = list(range(12))
    planner = PrefetchPlanner(order, PrefetchConfig(fetch_size=4, prefetch_threshold=2))
    events = list(planner)
    rounds_at = [i for i, (_, r) in enumerate(events) if r is not None]
    # First round at 0; pending drops to 2 after consuming 2 of 4 -> round at
    # step 2 (announced before consuming the trigger sample), then every 4.
    assert rounds_at[0] == 0
    assert all(b - a == 4 for a, b in zip(rounds_at[1:], rounds_at[2:]))


def test_disabled_planner_announces_nothing():
    planner = PrefetchPlanner(list(range(5)), PrefetchConfig.disabled())
    events = list(planner)
    assert [i for i, _ in events] == list(range(5))
    assert all(r is None for _, r in events)


@given(
    n=st.integers(min_value=0, max_value=400),
    fetch=st.integers(min_value=1, max_value=64),
    threshold=st.integers(min_value=0, max_value=64),
)
@settings(max_examples=120, deadline=None)
def test_property_planner_invariants(n, fetch, threshold):
    order = list(range(n))
    cfg = PrefetchConfig(fetch_size=fetch, prefetch_threshold=threshold)
    planner = PrefetchPlanner(order, cfg)
    consumed = []
    announced = []
    announced_set = set()
    for idx, round_ in planner:
        if round_ is not None:
            assert 1 <= len(round_) <= fetch
            announced.extend(round_)
            announced_set.update(round_)
        # An index must be announced before (or at) its consumption step.
        assert idx in announced_set
        consumed.append(idx)
    # Every index consumed exactly once, in order.
    assert consumed == order
    # Every index announced exactly once, in order, no over-announcement.
    assert announced == order
    if n:
        assert planner.rounds_issued == expected_rounds(n, cfg)


@given(n=st.integers(min_value=1, max_value=300), fetch=st.integers(min_value=1, max_value=50))
@settings(max_examples=60, deadline=None)
def test_property_expected_rounds_matches_ceil(n, fetch):
    cfg = PrefetchConfig(fetch_size=fetch)
    assert expected_rounds(n, cfg) == -(-n // fetch)


def test_config_lints():
    # cache smaller than fetch: the Fig. 7 pathological regime.
    w = validate_config_against_cache(
        PrefetchConfig(fetch_size=100, prefetch_threshold=0, cache_items=10)
    )
    assert any("evict each other" in x for x in w)
    # 50/50 is clean.
    assert validate_config_against_cache(PrefetchConfig.fifty_fifty(2048)) == []
    # oversized cache wastes space.
    w = validate_config_against_cache(
        PrefetchConfig(fetch_size=10, prefetch_threshold=5, cache_items=1000)
    )
    assert any("does not reduce miss rate" in x for x in w)
