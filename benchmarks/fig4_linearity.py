"""Fig. 4: data loading time is linear in miss rate (both workloads).
Collects (miss, wait) from the caching+pre-fetching trials across
configurations and fits a line; validates R^2."""
from __future__ import annotations

import numpy as np

from benchmarks.common import check, fmt_table, run_condition, workloads
from repro.core import PrefetchConfig, SimConfig


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    for spec in workloads(fast):
        pts = []
        for fetch in (256, 512, 1024, 2048, 4096):
            for cache_mult in (1, 2):
                cache = fetch * cache_mult
                cfg = SimConfig(
                    source="bucket", cache_items=cache,
                    prefetch=PrefetchConfig(fetch_size=fetch,
                                            prefetch_threshold=cache // 2,
                                            cache_items=cache),
                )
                for seed in range(1 if fast else 2):
                    r = run_condition(spec, cfg, epochs=2, seed=seed)
                    for e in ("1", "2"):
                        pts.append((r[f"miss_e{e}"], r[f"wait_e{e}"]))
        x = np.array([p[0] for p in pts])
        y = np.array([p[1] for p in pts])
        A = np.vstack([x, np.ones_like(x)]).T
        coef, res, *_ = np.linalg.lstsq(A, y, rcond=None)
        ss_tot = float(((y - y.mean()) ** 2).sum())
        r2 = 1.0 - float(res[0]) / ss_tot if len(res) and ss_tot else 1.0
        rows.append([spec.name, len(pts), f"{coef[0]:.1f}", f"{coef[1]:.2f}", f"{r2:.4f}"])
        checks.append(
            check(f"fig4/{spec.name}/linear", r2 > 0.98, f"R^2 = {r2:.4f} over {len(pts)} points")
        )
    return {
        "name": "Fig. 4 — wait time ~ linear in miss rate",
        "table": fmt_table(["workload", "points", "slope s/miss", "intercept", "R^2"], rows),
        "rows": rows,
        "checks": checks,
    }
