"""Fig. 7: with fetch size fixed at 1024, growing the cache past 1x the
fetch size buys (almost) nothing; below 1x the miss rate spikes."""
from __future__ import annotations

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import PrefetchConfig, SimConfig

FETCH = 1024


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    for spec in workloads(fast):
        series = {}
        for mult in (0.5, 1.0, 2.0, 3.0):
            cache = int(FETCH * mult)
            cfg = SimConfig(
                source="bucket", cache_items=cache,
                prefetch=PrefetchConfig(fetch_size=FETCH, prefetch_threshold=0,
                                        cache_items=cache),
            )
            ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
            m = mean(mean((t["miss_e1"], t["miss_e2"])) for t in ts)
            series[mult] = m
            rows.append([spec.name, f"{mult:g}x", f"{m:.3f}"])
        checks += [
            check(
                f"fig7/{spec.name}/under-1x-hurts",
                series[0.5] > series[1.0] + 0.05,
                f"0.5x miss {series[0.5]:.2f} vs 1x {series[1.0]:.2f}",
            ),
            check(
                f"fig7/{spec.name}/flat-past-1x",
                abs(series[3.0] - series[1.0]) < 0.05,
                f"1x {series[1.0]:.3f} vs 3x {series[3.0]:.3f} (negligible)",
            ),
        ]
    return {
        "name": "Fig. 7 — cache size at constant fetch size (1024)",
        "table": fmt_table(["workload", "cache/fetch", "miss (mean ep1/2)"], rows),
        "rows": rows,
        "checks": checks,
    }
