"""Shared benchmark plumbing: run data-plane conditions, format tables,
collect checks.  Every benchmark module exposes ``run(fast=False) -> dict``
with keys {"name", "rows", "checks", "notes"}; checks are (label, ok, detail).

Conditions are ``repro.pipeline.DataPlaneSpec`` objects — built directly,
lifted from a legacy ``SimConfig`` (``run_condition``), or declared by name
through the component registry (``run_named``).  All three funnel into
``run_spec``, so one spec description drives the simulator here and the
threaded runtime in the parity tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple, Union

from repro.core import (
    CIFAR10,
    MNIST,
    SimConfig,
    aggregate_tier_hits,
    mean_data_wait,
    mean_miss_rate,
)
from repro.core.workloads import WorkloadSpec
from repro.pipeline import DataPlaneSpec, condition

FAST_FACTOR = 0.1  # --fast: 10% datasets, ratios preserved

TIER_ORDER = ("ram", "disk", "peer", "bucket")


def workloads(fast: bool) -> List[WorkloadSpec]:
    if fast:
        return [MNIST.scaled(FAST_FACTOR), CIFAR10.scaled(FAST_FACTOR)]
    return [MNIST, CIFAR10]


def tier_breakdown(stats) -> str:
    """'ram/disk/peer/bucket' counter column from EpochStats tier maps."""
    agg = aggregate_tier_hits(stats)
    return "/".join(str(agg.get(t, 0)) for t in TIER_ORDER)


def run_spec(plane: DataPlaneSpec, epochs: int = 2) -> Dict:
    """Run one declarative condition through the simulator projection."""
    stats, store = plane.build_sim().run(epochs=epochs)
    return {
        "workload": plane.workload.name,
        "condition": plane.label(),
        "miss_e1": mean_miss_rate(stats, 0),
        "miss_e2": mean_miss_rate(stats, 1) if epochs > 1 else None,
        "wait_e1": mean_data_wait(stats, 0),
        "wait_e2": mean_data_wait(stats, 1) if epochs > 1 else None,
        "store": store,
        "stats": stats,
        "tiers": aggregate_tier_hits(stats),
    }


def run_condition(
    spec: WorkloadSpec, cfg: Union[SimConfig, DataPlaneSpec], epochs: int = 2, seed: int = 0
) -> Dict:
    """Legacy entry point: lift a ``SimConfig`` into a spec and run it.

    A ``DataPlaneSpec`` is accepted too; the ``spec``/``seed`` arguments
    still apply (so ``trials`` seed-variation works for either form).
    """
    if isinstance(cfg, DataPlaneSpec):
        plane = dataclasses.replace(cfg, workload=spec, seed=seed)
    else:
        plane = DataPlaneSpec.from_sim_config(spec, cfg, seed=seed)
    return run_spec(plane, epochs=epochs)


def run_named(
    name: str, spec: WorkloadSpec, epochs: int = 2, seed: int = 0, **overrides
) -> Dict:
    """Run a registry-named condition (benchmarks declare by name)."""
    return run_spec(condition(name, spec, seed=seed, **overrides), epochs=epochs)


def trials(
    spec: WorkloadSpec, cfg: SimConfig, epochs: int = 2, n: int = 3
) -> List[Dict]:
    """The paper averages over three trials; seeds give us the trials."""
    return [run_condition(spec, cfg, epochs, seed=s) for s in range(n)]


def mean(xs) -> float:
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def check(label: str, ok: bool, detail: str) -> Tuple[str, bool, str]:
    return (label, bool(ok), detail)


def dump_trace(plane: DataPlaneSpec, untraced_stats, path, epochs: int = 2):
    """Re-run one condition with the flight recorder on and export the
    Chrome trace (``--trace-dir``).

    Returns ``(identical, n_events)`` where ``identical`` is the ISSUE 10
    observer claim, checked with ``==``: the traced rerun's EpochStats are
    byte-identical to the untraced run the benchmark already measured —
    tracing observes the schedule, never perturbs it.
    """
    from repro.obs.events import TraceRecorder
    from repro.obs.export import write_chrome_trace

    rec = TraceRecorder()
    traced_stats, _ = dataclasses.replace(plane, trace=rec).build_sim().run(
        epochs=epochs
    )
    write_chrome_trace(str(path), rec.events)
    identical = [s.asdict() for s in traced_stats] == [
        s.asdict() for s in untraced_stats
    ]
    return identical, len(rec.events)


def fmt_table(headers: List[str], rows: List[List]) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    out = ["  ".join(str(h).ljust(w) for h, w in zip(headers, widths))]
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
