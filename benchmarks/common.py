"""Shared benchmark plumbing: run simulator conditions, format tables,
collect checks.  Every benchmark module exposes ``run(fast=False) -> dict``
with keys {"name", "rows", "checks", "notes"}; checks are (label, ok, detail).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core import (
    CIFAR10,
    MNIST,
    PrefetchConfig,
    SimConfig,
    mean_data_wait,
    mean_miss_rate,
    simulate_cluster,
)
from repro.core.workloads import WorkloadSpec

FAST_FACTOR = 0.1  # --fast: 10% datasets, ratios preserved


def workloads(fast: bool) -> List[WorkloadSpec]:
    if fast:
        return [MNIST.scaled(FAST_FACTOR), CIFAR10.scaled(FAST_FACTOR)]
    return [MNIST, CIFAR10]


def run_condition(
    spec: WorkloadSpec, cfg: SimConfig, epochs: int = 2, seed: int = 0
) -> Dict:
    stats, store = simulate_cluster(spec, cfg, epochs=epochs, seed=seed)
    return {
        "workload": spec.name,
        "condition": cfg.label(),
        "miss_e1": mean_miss_rate(stats, 0),
        "miss_e2": mean_miss_rate(stats, 1) if epochs > 1 else None,
        "wait_e1": mean_data_wait(stats, 0),
        "wait_e2": mean_data_wait(stats, 1) if epochs > 1 else None,
        "store": store,
        "stats": stats,
    }


def trials(
    spec: WorkloadSpec, cfg: SimConfig, epochs: int = 2, n: int = 3
) -> List[Dict]:
    """The paper averages over three trials; seeds give us the trials."""
    return [run_condition(spec, cfg, epochs, seed=s) for s in range(n)]


def mean(xs) -> float:
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def check(label: str, ok: bool, detail: str) -> Tuple[str, bool, str]:
    return (label, bool(ok), detail)


def fmt_table(headers: List[str], rows: List[List]) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    out = ["  ".join(str(h).ljust(w) for h, w in zip(headers, widths))]
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
