"""Table II: modeled cost of training each workload for two epochs, per
method.  Validates the paper's qualitative cost findings:

  * GCP-direct is the most expensive method on both workloads;
  * DELI's API line is larger than direct's (per-fetch listings, Eq. 5);
  * the 50/50 configuration saves money vs disk on CIFAR-10 (long-compute
    workload) — the paper's headline cost claim;
  * on MNIST (short compute) bucket methods do NOT beat disk.

t_c / t_d are taken from the simulator (the paper used measured values).
"""
from __future__ import annotations

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import (
    GcpPrices,
    PrefetchConfig,
    SimConfig,
    WorkloadCostInputs,
    cost_bucket,
    cost_disk_baseline,
)

PRICES = GcpPrices()
OS_DISK_GB = 16.0


def dataclasses_replace_dataset(spec, dataset_gb: float):
    """Same workload, scaled sample size so the dataset totals dataset_gb."""
    import dataclasses

    per = int(dataset_gb * 1e9 / spec.n_samples)
    return dataclasses.replace(spec, sample_bytes=per)


def _inputs(spec, wait_s, compute_s, cached=0, fetch=0):
    return WorkloadCostInputs(
        n_nodes=spec.n_nodes,
        os_disk_gb=OS_DISK_GB,
        dataset_gb=spec.dataset_gb,
        n_samples=spec.n_samples,
        epochs=2,
        compute_seconds=compute_s,
        data_wait_seconds=wait_s,
        cached_samples=cached,
        fetch_size=fetch,
    )


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    for spec in workloads(fast):
        compute_2ep = 2 * spec.compute_per_epoch_s
        wl = spec.name.split("-x")[0]

        def waits(cfg):
            ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
            return mean(t["wait_e1"] + t["wait_e2"] for t in ts)

        totals = {}
        # disk baseline
        w = waits(SimConfig(source="disk"))
        c = cost_disk_baseline(PRICES, _inputs(spec, w, compute_2ep))
        totals["disk"] = c
        rows.append([spec.name, "disk", *(f"${c[k]:.2f}" for k in ("api", "storage", "compute_loading", "total"))])
        # GCP direct
        w = waits(SimConfig(source="bucket", cache_items=None))
        c = cost_bucket(PRICES, _inputs(spec, w, compute_2ep), with_prefetch=False)
        totals["gcp"] = c
        rows.append([spec.name, "gcp-direct", *(f"${c[k]:.2f}" for k in ("api", "storage", "compute_loading", "total"))])
        # Full fetch 1024 / 2048, 50/50
        for label, pf in [
            ("full-fetch-1024", PrefetchConfig.full_fetch(1024)),
            ("full-fetch-2048", PrefetchConfig.full_fetch(2048)),
            ("fifty-fifty-1024", PrefetchConfig.fifty_fifty(2048)),
        ]:
            w = waits(SimConfig(source="bucket", cache_items=pf.cache_items, prefetch=pf))
            c = cost_bucket(
                PRICES,
                _inputs(spec, w, compute_2ep, cached=pf.cache_items, fetch=pf.fetch_size),
                with_prefetch=True,
            )
            totals[label] = c
            rows.append([spec.name, label, *(f"${c[k]:.2f}" for k in ("api", "storage", "compute_loading", "total"))])

        checks += [
            check(
                f"table2/{wl}/gcp-most-expensive",
                totals["gcp"]["total"] >= max(v["total"] for k, v in totals.items() if k != "gcp") - 0.01,
                f"gcp ${totals['gcp']['total']:.2f} vs others "
                f"{[round(v['total'], 2) for k, v in totals.items() if k != 'gcp']}",
            ),
            check(
                f"table2/{wl}/deli-api-over-direct",
                totals["fifty-fifty-1024"]["api"] > totals["gcp"]["api"],
                f"DELI api ${totals['fifty-fifty-1024']['api']:.2f} > direct ${totals['gcp']['api']:.2f}",
            ),
        ]
        if wl == "cifar10-resnet50":
            # The paper's Table II row ('Compute + Loading' $0.17 for 50/50)
            # is internally inconsistent with its own measured 147.2 s/epoch
            # (2 epochs = 294 s of pure compute >= $0.23 at any rate that
            # also fits their other rows), so the $2.17 < $2.23 crossover is
            # not reproducible from Eq. (1)-(5).  We validate the MECHANISM:
            # 50/50 gets compute+loading down to ~disk level while paying
            # bucket (not per-node) storage for the dataset.
            cl_deli = totals["fifty-fifty-1024"]["compute_loading"]
            cl_disk = totals["disk"]["compute_loading"]
            checks.append(
                check(
                    "table2/cifar/deli-loading-at-disk-level",
                    cl_deli <= cl_disk * 1.10,
                    f"50/50 compute+loading ${cl_deli:.2f} ~ disk ${cl_disk:.2f} "
                    "(paper's absolute totals are not self-consistent; see EXPERIMENTS.md)",
                )
            )
            # The claim's real substance — bucket storage beats per-node disk
            # when the dataset outgrows local disks (the paper's premise):
            big = dataclasses_replace_dataset(spec, 150.0)  # ImageNet-scale
            w = waits(SimConfig(source="bucket", cache_items=2048,
                                prefetch=PrefetchConfig.fifty_fifty(2048)))
            c_deli = cost_bucket(
                PRICES, _inputs(big, w, compute_2ep, cached=2048, fetch=1024),
                with_prefetch=True,
            )
            w_d = waits(SimConfig(source="disk"))
            c_disk = cost_disk_baseline(PRICES, _inputs(big, w_d, compute_2ep))
            rows.append([big.name + "@150GB", "disk", "", f"${c_disk['storage']:.2f}", "", f"${c_disk['total']:.2f}"])
            rows.append([big.name + "@150GB", "fifty-fifty-1024", f"${c_deli['api']:.2f}", f"${c_deli['storage']:.2f}", "", f"${c_deli['total']:.2f}"])
            checks.append(
                check(
                    "table2/large-dataset/deli-saves",
                    c_deli["total"] < c_disk["total"],
                    f"150 GB dataset: 50/50 ${c_deli['total']:.2f} < disk ${c_disk['total']:.2f}",
                )
            )
        else:
            checks.append(
                check(
                    "table2/mnist/direct-no-savings",
                    totals["gcp"]["total"] > totals["disk"]["total"],
                    f"gcp ${totals['gcp']['total']:.2f} > disk ${totals['disk']['total']:.2f}",
                )
            )
    return {
        "name": "Table II — modeled 2-epoch training cost",
        "table": fmt_table(["workload", "method", "api", "storage", "compute+loading", "total"], rows),
        "rows": rows,
        "checks": checks,
    }
