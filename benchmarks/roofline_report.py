"""Roofline report: renders the dry-run sweep (results/dryrun/*.json) as
the EXPERIMENTS.md §Roofline table and sanity-checks coverage (every
applicable (arch x shape) cell present on both meshes, all ok)."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import check, fmt_table
from repro import configs
from repro.models.config import applicable_shapes

RESULTS = pathlib.Path("results/dryrun")


def expected_cells():
    out = []
    for arch in configs.ARCH_IDS:
        for s in applicable_shapes(configs.get(arch)):
            for mesh in ("pod16x16", "pod2x16x16"):
                out.append((arch, s.name, mesh))
    return out


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    found = {}
    for f in sorted(RESULTS.glob("*.json")) if RESULTS.exists() else []:
        d = json.loads(f.read_text())
        found[(d["arch"], d["shape"], d["mesh"])] = d
    missing = [c for c in expected_cells() if c not in found]
    failed = [k for k, d in found.items() if d["status"] != "ok"]
    checks.append(
        check(
            "dryrun/coverage",
            not missing and not failed,
            f"{len(found)} cells; missing={len(missing)} failed={len(failed)}",
        )
    )
    for (arch, shape, mesh), d in sorted(found.items()):
        if d["status"] != "ok":
            rows.append([arch, shape, mesh, "FAIL", "", "", "", "", ""])
            continue
        r = d["roofline"]
        rows.append([
            arch, shape, mesh,
            f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}", f"{r['collective_s']:.3f}",
            r["dominant"], f"{r['useful_ratio']:.2f}", f"{r['roofline_fraction']*100:.1f}%",
        ])
    return {
        "name": "Roofline — dry-run terms per (arch x shape x mesh)",
        "table": fmt_table(
            ["arch", "shape", "mesh", "comp_s", "mem_s", "coll_s", "dominant", "useful", "roof%"],
            rows,
        ),
        "rows": rows,
        "checks": checks,
    }
