"""Fig. 12 (beyond-paper): the optimality gap — how far the paper's
heuristic knobs sit from the clairvoyant data plane (ISSUE 5).

The paper tunes two knobs (fetch size, prefetch threshold) and lands on
the 50/50 rule; figs. 3-11 measure *heuristic* policies but never say how
far they are from optimal.  Because DL samplers are seeded PRNG
permutations, the exact future access order is known ahead of time (NoPFS:
"Clairvoyant Prefetching", Dryden et al.) — so the optimum is
*implementable*: Belady (farthest-future-use) eviction and the
OraclePrefetchPlanner (deadline-ordered, capacity-windowed,
residency-filtered rounds; per-round re-listing subsumed by clairvoyance).
This benchmark runs, at equal cache capacity across three cache-pressure
points and under both cluster schedules (the default epoch barrier and the
straggler/batch-sync schedule of fig. 11):

  * demand        — capped cache only, FIFO (paper §IV-B);
  * belady-only   — same, with Belady eviction: what clairvoyant
    *eviction* alone buys;
  * 50/50         — the paper's best heuristic (f = T = cache/2);
  * full-fetch    — the fig. 9 baseline (cache == fetch, T = 0);
  * oracle        — clairvoyant prefetch + Belady eviction;
  * oracle+peer   — plus the cooperative peer tier (cluster-resident keys
    pulled from peers at round issue, never billed to Class B).

Reported per condition: total data-wait, Class A/B requests, tier hits,
and the oracle-vs-50/50 gap (how much of the heuristic's data-wait the
oracle removes — the price of tuning knobs instead of knowing the future).

Claim checks:

  * oracle data-wait <= every heuristic condition (demand, 50/50,
    full-fetch) at equal capacity, on both schedules;
  * Belady Class B <= FIFO Class B at equal capacity (clairvoyant eviction
    never re-fetches more);
  * oracle Class B <= 50/50 Class B (the residency filter + Belady keep
    fetched bytes useful);
  * the oracle-vs-50/50 gap is reported (finite) for every condition row.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import check, fmt_table, run_spec
from repro.core import MNIST, straggler_profiles
from repro.pipeline import condition

#: Cache capacity as a fraction of the per-node partition (pressure sweep).
PRESSURES = (0.25, 0.5, 1.0)
HEURISTICS = ("demand", "50/50", "full-fetch")


def _conditions(w, cache_items):
    return [
        ("demand", condition("cache", w, cache_items=cache_items)),
        ("belady-only", condition("belady-only", w, cache_items=cache_items)),
        ("50/50", condition("fifty-fifty", w, cache_items=cache_items)),
        ("full-fetch", condition("full-fetch", w, fetch_size=cache_items)),
        ("oracle", condition("oracle", w, cache_items=cache_items)),
        ("oracle+peer", condition("oracle+peer", w, cache_items=cache_items)),
    ]


def _schedules(w):
    """The default epoch-barrier schedule and fig. 11's straggler/batch-sync
    schedule (rank 0 slowed 2x, per-batch allreduce barriers)."""
    return [
        ("epoch", {}),
        (
            "bsync+straggler",
            dict(sync="batch", nodes=straggler_profiles(w.n_nodes, (0,), 2.0, 2.0)),
        ),
    ]


def _measure(spec):
    # Vector engine (ISSUE 6): bit-identical results (exact == per
    # docs/PARITY.md and tests/test_engine_equivalence.py), a fraction of
    # the wall-clock; peer conditions fall back to scalar stepping per node.
    r = run_spec(dataclasses.replace(spec, engine="vector"), epochs=2)
    return {
        "wait": sum(s.data_wait_seconds for s in r["stats"]),
        "class_a": r["store"].class_a_requests,
        "class_b": r["store"].class_b_requests,
        "ram": r["tiers"].get("ram", 0),
        "peer": r["tiers"].get("peer", 0),
    }


def run(fast: bool = False) -> dict:
    w = MNIST.scaled(0.05 if fast else 0.1)
    rows, checks, gaps = [], [], []
    for sched_tag, sched_kw in _schedules(w):
        for frac in PRESSURES:
            cache_items = max(2, int(w.partition_size * frac))
            results = {}
            for tag, base in _conditions(w, cache_items):
                spec = dataclasses.replace(base, **sched_kw) if sched_kw else base
                results[tag] = _measure(spec)
            fifty = results["50/50"]["wait"]
            for tag, m in results.items():
                gap = (fifty - m["wait"]) / fifty if fifty else float("nan")
                gaps.append((sched_tag, frac, tag, gap))
                rows.append(
                    [
                        sched_tag,
                        f"{frac:.0%}",
                        tag,
                        f"{m['wait']:.2f}s",
                        f"{m['class_b']}",
                        f"{m['class_a']}",
                        f"{m['ram']}/{m['peer']}",
                        f"{gap:+.1%}",
                    ]
                )
            oracle = results["oracle"]
            for heur in HEURISTICS:
                checks.append(
                    check(
                        f"fig12/{sched_tag}/C={cache_items}/oracle-wait<=-{heur}",
                        oracle["wait"] <= results[heur]["wait"] * (1 + 1e-9),
                        f"oracle {oracle['wait']:.2f}s <= {heur} "
                        f"{results[heur]['wait']:.2f}s",
                    )
                )
            checks.append(
                check(
                    f"fig12/{sched_tag}/C={cache_items}/belady-classB<=fifo",
                    results["belady-only"]["class_b"] <= results["demand"]["class_b"],
                    f"belady B={results['belady-only']['class_b']} <= "
                    f"fifo B={results['demand']['class_b']}",
                )
            )
            checks.append(
                check(
                    f"fig12/{sched_tag}/C={cache_items}/oracle-classB<=50/50",
                    oracle["class_b"] <= results["50/50"]["class_b"],
                    f"oracle B={oracle['class_b']} <= "
                    f"50/50 B={results['50/50']['class_b']}",
                )
            )
    checks.append(
        check(
            "fig12/gap-reported-per-condition",
            all(g == g for _, _, _, g in gaps),  # finite, no NaNs
            f"{len(gaps)} condition rows carry an oracle-vs-50/50 gap "
            "(see the 'vs 50/50' column)",
        )
    )
    return {
        "name": "Fig. 12 — optimality gap: heuristic knobs vs the clairvoyant "
        "data plane (beyond-paper)",
        "engine": "vector",
        "table": fmt_table(
            [
                "schedule",
                "cache/partition",
                "condition",
                "data-wait",
                "class B",
                "class A",
                "ram/peer hits",
                "vs 50/50",
            ],
            rows,
        ),
        "rows": rows,
        "checks": checks,
        "notes": (
            "3-node MNIST-scale cluster, 2 epochs, equal cache capacity per "
            "row block. 'vs 50/50' = fraction of the 50/50 heuristic's "
            "data-wait each condition removes (negative = worse). The "
            "oracle conditions derive fetch rounds from the seeded "
            "sampler's exact future order (NoPFS-style clairvoyance): "
            "deadline-ordered ramped rounds kill the 50/50 cold-start "
            "stall, the residency filter stops re-fetching cached keys, "
            "Belady eviction keeps the soonest-needed bytes, and (peer "
            "condition) cluster-resident keys stream from peers without "
            "Class B billing. Per-round re-listing is subsumed by "
            "clairvoyance (one initial listing billed). The gap persists "
            "under the fig. 11 straggler/batch-sync schedule."
        ),
    }
