"""Fig. 3: data loading time by method (disk / GCP-direct / cache-only /
DELI 50-50).  Headline claims validated:

  * bucket-direct loading is 8-16x disk;
  * DELI 50/50 cuts data-wait 85.6% (MNIST) / 93.5% (CIFAR-10) vs direct;
  * 50/50 lands near (or below) the disk baseline.
"""
from __future__ import annotations

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import PrefetchConfig, SimConfig

PAPER_REDUCTION = {"mnist-cnn": 0.856, "cifar10-resnet50": 0.935}
CACHE = 2048


def conditions():
    return [
        SimConfig(source="disk"),
        SimConfig(source="bucket", cache_items=None),
        SimConfig(source="bucket", cache_items=-1),
        SimConfig(source="bucket", cache_items=CACHE,
                  prefetch=PrefetchConfig.fifty_fifty(CACHE)),
    ]


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    for spec in workloads(fast):
        waits = {}
        for cfg in conditions():
            ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
            w = mean(mean((t["wait_e1"], t["wait_e2"])) for t in ts)
            waits[cfg.label()] = w
            rows.append([spec.name, cfg.label(), f"{w:.1f}s"])
        disk, direct = waits["disk"], waits["gcp-direct"]
        deli = waits[f"cache[{CACHE}]+pf(f={CACHE//2},T={CACHE//2})"]
        penalty = direct / disk
        reduction = 1 - deli / direct
        key = spec.name.split("-x")[0]
        expect = PAPER_REDUCTION[key]
        checks += [
            check(
                f"fig3/{key}/bucket-penalty-8-16x",
                6 <= penalty <= 20,
                f"direct/disk = {penalty:.1f}x (paper: 8-16x)",
            ),
            check(
                f"fig3/{key}/deli-reduction",
                reduction >= expect - 0.08,
                f"50/50 cuts wait {reduction:.1%} vs direct (paper: {expect:.1%})",
            ),
            check(
                f"fig3/{key}/near-disk",
                deli <= 2.5 * disk,
                f"50/50 {deli:.1f}s vs disk {disk:.1f}s",
            ),
        ]
    return {
        "name": "Fig. 3 — data loading time by method",
        "table": fmt_table(["workload", "condition", "wait (mean ep1/ep2)"], rows),
        "rows": rows,
        "checks": checks,
    }
