"""Fig. 6: increasing the fetch size decreases the miss rate (unlimited
cache + pre-fetching, fetch size swept in 256-sample increments)."""
from __future__ import annotations

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import PrefetchConfig, SimConfig


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    sizes = (256, 512, 1024, 2048, 4096)
    for spec in workloads(fast):
        series = []
        for f in sizes:
            cfg = SimConfig(
                source="bucket", cache_items=-1,
                prefetch=PrefetchConfig(fetch_size=f, prefetch_threshold=0),
            )
            ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
            m = mean(mean((t["miss_e1"], t["miss_e2"])) for t in ts)
            series.append(m)
            rows.append([spec.name, f, f"{m:.3f}"])
        drops = sum(1 for a, b in zip(series, series[1:]) if b <= a + 1e-9)
        checks.append(
            check(
                f"fig6/{spec.name}/decreasing",
                drops >= len(series) - 2 and series[-1] < series[0],
                f"miss {series[0]:.2f} -> {series[-1]:.2f} over fetch {sizes[0]}->{sizes[-1]}",
            )
        )
    return {
        "name": "Fig. 6 — fetch size vs miss rate",
        "table": fmt_table(["workload", "fetch size", "miss (mean ep1/2)"], rows),
        "rows": rows,
        "checks": checks,
    }
