"""Benchmark harness: one module per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--fast] [--only fig3,table2]``.

Prints each benchmark's table, then a PASS/FAIL line per claim check; exits
nonzero if any check fails.  Results also land in results/benchmarks.json.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import pathlib
import sys
import time

MODULES = [
    "table1_transfer",
    "fig3_loading_time",
    "fig4_linearity",
    "fig5_cache_size",
    "fig6_fetch_size",
    "fig7_cache_vs_fetch",
    "fig8_thresholds",
    "fig9_best_settings",
    "fig10_peer_cache",
    "fig11_stragglers",
    "fig12_oracle_gap",
    "fig13_scaling",
    "fig14_cluster_placement",
    "fig15_comm_overlap",
    "table2_cost",
    "beyond_paper",
    "roofline_report",
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="10%% datasets, 1 trial")
    ap.add_argument("--only", default="", help="comma list of module names")
    ap.add_argument(
        "--trace-dir",
        default="",
        metavar="DIR",
        help="dump a Chrome trace (flight recorder, repro.obs) of each "
        "figure's headline condition into DIR; modules without trace "
        "support run untraced",
    )
    args = ap.parse_args(argv)
    trace_dir = pathlib.Path(args.trace_dir) if args.trace_dir else None
    if trace_dir is not None:
        trace_dir.mkdir(parents=True, exist_ok=True)

    names = [m for m in MODULES if not args.only or m in args.only.split(",")]
    all_checks, summary = [], {}
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        kwargs = {}
        if trace_dir is not None and "trace_dir" in inspect.signature(
            mod.run
        ).parameters:
            kwargs["trace_dir"] = trace_dir
        t0 = time.time()
        res = mod.run(fast=args.fast, **kwargs)
        dt = time.time() - t0
        print(f"\n=== {res['name']}  [{name}, {dt:.1f}s] ===")
        print(res["table"])
        for label, ok, detail in res["checks"]:
            print(f"  {'PASS' if ok else 'FAIL'}  {label}: {detail}")
        all_checks += res["checks"]
        summary[name] = {
            "name": res["name"],
            "seconds": round(dt, 1),
            "engine": res.get("engine", "scalar"),
            "traced": bool(kwargs),
            "traces": [str(p) for p in res.get("traces", [])],
            "checks": [
                {"label": l, "ok": o, "detail": d} for l, o, d in res["checks"]
            ],
        }
    n_ok = sum(1 for _, ok, _ in all_checks if ok)
    print(f"\n==== {n_ok}/{len(all_checks)} claim checks passed ====")
    out = pathlib.Path("results")
    out.mkdir(exist_ok=True)
    (out / "benchmarks.json").write_text(json.dumps(summary, indent=1))
    if n_ok != len(all_checks):
        sys.exit(1)


if __name__ == "__main__":
    main()
