"""Fig. 5: cache size vs miss rate, NO pre-fetching.  Validates:

  * unlimited cache epoch-2 miss ~= 66% (random 3-way re-partitioning:
    only 1/3 of a node's epoch-1 partition returns to it);
  * miss climbs rapidly as the cache shrinks (~90% at 75% of partition).
"""
from __future__ import annotations

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import SimConfig


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    for spec in workloads(fast):
        part = spec.partition_size
        miss2 = {}
        for frac, cache in [("unlimited", -1)] + [
            (f"{int(f*100)}%", int(part * f)) for f in (0.75, 0.5, 0.25)
        ]:
            cfg = SimConfig(source="bucket", cache_items=cache)
            ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
            m1 = mean(t["miss_e1"] for t in ts)
            m2 = mean(t["miss_e2"] for t in ts)
            miss2[frac] = m2
            rows.append([spec.name, frac, f"{m1:.3f}", f"{m2:.3f}"])
        checks += [
            check(
                f"fig5/{spec.name}/unlimited-66pct",
                0.60 <= miss2["unlimited"] <= 0.72,
                f"epoch-2 miss {miss2['unlimited']:.1%} (paper ~66%)",
            ),
            check(
                f"fig5/{spec.name}/75pct-cache-90pct-miss",
                miss2["75%"] >= 0.85,
                f"epoch-2 miss at 75% cache {miss2['75%']:.1%} (paper ~90%)",
            ),
            check(
                f"fig5/{spec.name}/monotone",
                miss2["25%"] >= miss2["50%"] >= miss2["75%"] >= miss2["unlimited"],
                "miss rises as cache shrinks",
            ),
        ]
    return {
        "name": "Fig. 5 — cache size vs miss rate (caching alone)",
        "table": fmt_table(["workload", "cache", "miss ep1", "miss ep2"], rows),
        "rows": rows,
        "checks": checks,
    }
