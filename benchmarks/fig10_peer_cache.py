"""Fig. 10 (beyond-paper): cooperative peer-cache tier, nodes x cache size.

For each cluster size and per-node cache size, run node-local caching vs
the peer-cache tier (same per-node cache budget) and compare:

  * aggregate Class B requests (the bucket bill the tier exists to cut);
  * mean data-wait (a peer RTT is ~2 orders cheaper than a bucket GET);
  * ``EpochStats.peer_hits`` (how much of the win came from peers).

Checks assert the headline property for a 4-node cluster: peer-cache mode
strictly reduces both aggregate Class B traffic and mean data-wait versus
node-local caching at equal per-node cache size, with non-zero peer hits.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import check, fmt_table, mean
from repro.core import MNIST, SimConfig, mean_data_wait, simulate_cluster


def run(fast: bool = False) -> dict:
    spec0 = MNIST.scaled(0.05 if fast else 0.1)
    rows, checks = [], []
    headline = {}
    node_counts = (2, 4) if fast else (2, 4, 8)
    for n_nodes in node_counts:
        spec = dataclasses.replace(spec0, n_nodes=n_nodes)
        part = spec.partition_size
        for frac in (0.5, 1.0):
            cache_items = max(1, int(part * frac))
            results = {}
            for peer in (False, True):
                cfg = SimConfig(cache_items=cache_items, peer_cache=peer)
                stats, store = simulate_cluster(spec, cfg, epochs=2, seed=0)
                results[peer] = {
                    "class_b": store.class_b_requests,
                    "wait": mean(mean_data_wait(stats, e) for e in (0, 1)),
                    "peer_hits": sum(s.peer_hits for s in stats),
                }
                rows.append(
                    [
                        f"{n_nodes} nodes",
                        f"cache {int(frac * 100)}% of part",
                        "peer" if peer else "local",
                        results[peer]["class_b"],
                        f"{results[peer]['wait']:.2f}s",
                        results[peer]["peer_hits"],
                    ]
                )
            if n_nodes == 4 and frac == 1.0:
                headline = results
            checks.append(
                check(
                    f"fig10/{n_nodes}n/cache{int(frac*100)}pct/strict-reduction",
                    results[True]["class_b"] < results[False]["class_b"]
                    and results[True]["wait"] < results[False]["wait"],
                    f"classB {results[False]['class_b']} -> {results[True]['class_b']}, "
                    f"wait {results[False]['wait']:.2f}s -> {results[True]['wait']:.2f}s",
                )
            )
    checks.append(
        check(
            "fig10/4n/peer-hits-nonzero",
            bool(headline) and headline[True]["peer_hits"] > 0,
            f"4-node peer hits: {headline.get(True, {}).get('peer_hits')}",
        )
    )
    return {
        "name": "Fig. 10 — cooperative peer-cache tier (beyond-paper)",
        "table": fmt_table(
            ["cluster", "cache", "mode", "class B", "mean wait", "peer hits"], rows
        ),
        "rows": rows,
        "checks": checks,
        "notes": (
            "Peer tier: on a local miss, ask peers' caches over a ~0.2 ms RTT "
            "intra-zone network before paying a ~15.7 ms bucket GET (Class B)."
        ),
    }
