"""Fig. 10 (beyond-paper): cooperative peer-cache tier, nodes x cache size.

Conditions are declared by name through the ``repro.pipeline`` registry
("cache", "cache+peer", "cache+peer+repl") and run through one
``DataPlaneSpec`` each — under the **event-interleaved** cluster schedule
(ISSUE 3), so peer lookups observe mid-epoch cache state.  For every
cluster size and per-node cache size we compare, at equal per-node cache
budget:

  * aggregate Class B requests (the bucket bill the tier exists to cut);
  * mean data-wait (a peer RTT is ~2 orders cheaper than a bucket GET);
  * the per-tier read breakdown (ram/disk/peer/bucket) from the
    ``EpochStats`` tier counters.

Checks assert the headline property for a 4-node cluster: peer-cache mode
strictly reduces both aggregate Class B traffic and mean data-wait versus
node-local caching at equal per-node cache size, with non-zero peer hits —
and Hoard-style replication-aware eviction cuts Class B further at capped
capacity.

A final section quantifies the *schedule fidelity delta*: the same peer
conditions re-run with ``interleaved=False`` (the legacy sequential node
loop).  For capped caches without prefetch the sequential schedule
OVERSTATED the peer tier (late ranks read early ranks' complete-epoch
snapshots; mid-epoch evictions were invisible), so honest interleaving
reports more Class B; with the pre-fetch service on, rounds probing peers
mid-epoch find more same-epoch fills, so interleaving reports FEWER
Class B.  Both directions are asserted.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import check, fmt_table, mean, run_condition, run_named, tier_breakdown
from repro.core import MNIST, PrefetchConfig
from repro.pipeline import condition

MODES = ("cache", "cache+peer", "cache+peer+repl")
MODE_LABEL = {"cache": "local", "cache+peer": "peer", "cache+peer+repl": "peer+repl"}


def run(fast: bool = False) -> dict:
    spec0 = MNIST.scaled(0.05 if fast else 0.1)
    rows, checks = [], []
    headline = {}
    node_counts = (2, 4) if fast else (2, 4, 8)
    for n_nodes in node_counts:
        spec = dataclasses.replace(spec0, n_nodes=n_nodes)
        part = spec.partition_size
        for frac in (0.5, 1.0):
            cache_items = max(1, int(part * frac))
            results = {}
            for mode in MODES:
                # Vector engine (ISSUE 6): exact == results; these peer
                # conditions fall back to scalar stepping per node, but the
                # spec-level switch keeps figs. 10-12 on one engine setting.
                r = run_named(
                    mode, spec, epochs=2, seed=0,
                    cache_items=cache_items, engine="vector",
                )
                results[mode] = {
                    "class_b": r["store"].class_b_requests,
                    "wait": mean((r["wait_e1"], r["wait_e2"])),
                    "peer_hits": r["tiers"].get("peer", 0),
                    "tiers": tier_breakdown(r["stats"]),
                }
                rows.append(
                    [
                        f"{n_nodes} nodes",
                        f"cache {int(frac * 100)}% of part",
                        MODE_LABEL[mode],
                        results[mode]["class_b"],
                        f"{results[mode]['wait']:.2f}s",
                        results[mode]["peer_hits"],
                        results[mode]["tiers"],
                    ]
                )
            if n_nodes == 4 and frac == 1.0:
                headline = results
            checks.append(
                check(
                    f"fig10/{n_nodes}n/cache{int(frac*100)}pct/strict-reduction",
                    results["cache+peer"]["class_b"] < results["cache"]["class_b"]
                    and results["cache+peer"]["wait"] < results["cache"]["wait"],
                    f"classB {results['cache']['class_b']} -> "
                    f"{results['cache+peer']['class_b']}, "
                    f"wait {results['cache']['wait']:.2f}s -> "
                    f"{results['cache+peer']['wait']:.2f}s",
                )
            )
            if frac < 1.0:
                # Replication-aware eviction only matters under eviction
                # pressure (capped caches); at 100% nothing is ever evicted.
                checks.append(
                    check(
                        f"fig10/{n_nodes}n/cache{int(frac*100)}pct/repl-aware-no-worse",
                        results["cache+peer+repl"]["class_b"]
                        <= results["cache+peer"]["class_b"],
                        f"classB peer {results['cache+peer']['class_b']} -> "
                        f"repl {results['cache+peer+repl']['class_b']}",
                    )
                )
    checks.append(
        check(
            "fig10/4n/peer-hits-nonzero",
            bool(headline) and headline["cache+peer"]["peer_hits"] > 0,
            f"4-node peer hits: {headline.get('cache+peer', {}).get('peer_hits')}",
        )
    )
    # -- schedule fidelity: event-interleaved vs legacy sequential ----------
    spec4 = dataclasses.replace(spec0, n_nodes=4)
    half = max(1, spec4.partition_size // 2)
    delta_rows = []
    for tag, plane in (
        ("peer (no pf)", condition("cache+peer", spec4, cache_items=half, engine="vector")),
        (
            "peer + 50/50 pf",
            condition(
                "cache+peer",
                spec4,
                cache_items=half,
                prefetch=PrefetchConfig.fifty_fifty(half),
                engine="vector",
            ),
        ),
    ):
        by_sched = {}
        for interleaved in (True, False):
            r = run_condition(
                spec4, dataclasses.replace(plane, interleaved=interleaved), epochs=2
            )
            by_sched[interleaved] = {
                "class_b": r["store"].class_b_requests,
                "peer_hits": r["tiers"].get("peer", 0),
            }
        delta_rows.append(
            [
                "4 nodes",
                "cache 50% of part",
                f"{tag} / interleaved",
                by_sched[True]["class_b"],
                "-",
                by_sched[True]["peer_hits"],
                "-",
            ]
        )
        delta_rows.append(
            [
                "4 nodes",
                "cache 50% of part",
                f"{tag} / sequential",
                by_sched[False]["class_b"],
                "-",
                by_sched[False]["peer_hits"],
                "-",
            ]
        )
        if "pf" in tag and "no pf" not in tag:
            # Prefetch rounds probing peers mid-epoch find same-epoch fills.
            ok = by_sched[True]["class_b"] <= by_sched[False]["class_b"]
            direction = "interleaved <= sequential (rounds see mid-epoch fills)"
        else:
            # Sequential epoch-boundary snapshots overstated the peer tier.
            ok = by_sched[True]["class_b"] >= by_sched[False]["class_b"]
            direction = "interleaved >= sequential (snapshot bias removed)"
        checks.append(
            check(
                f"fig10/4n/interleaved-delta/{'pf' if 'no pf' not in tag else 'nopf'}",
                ok,
                f"classB interleaved {by_sched[True]['class_b']} vs sequential "
                f"{by_sched[False]['class_b']}; {direction}; peer hits "
                f"{by_sched[True]['peer_hits']} vs {by_sched[False]['peer_hits']}",
            )
        )
    rows.extend(delta_rows)
    return {
        "name": "Fig. 10 — cooperative peer-cache tier (beyond-paper)",
        "engine": "vector",
        "table": fmt_table(
            [
                "cluster",
                "cache",
                "mode",
                "class B",
                "mean wait",
                "peer hits",
                "ram/disk/peer/bucket",
            ],
            rows,
        ),
        "rows": rows,
        "checks": checks,
        "notes": (
            "Peer tier: on a local miss, ask peers' caches over a ~0.2 ms RTT "
            "intra-zone network before paying a ~15.7 ms bucket GET (Class B). "
            "peer+repl additionally declines to evict the last cluster-resident "
            "copy (Hoard-style). Conditions declared via pipeline.registry and "
            "run event-interleaved (ISSUE 3); the trailing rows quantify the "
            "delta vs the legacy sequential schedule, whose epoch-boundary "
            "snapshots overstated the peer tier for capped caches."
        ),
    }
