"""Fig. 9: Full-Fetch (fetch=cache, T=0; sizes 1024/2048) vs the 50/50
approach (fetch=T=1024, cache=2048).  Validates: 50/50 >= Full-Fetch, with
the large win on the compute-heavy workload (paper: 83% CIFAR miss drop
vs Full-Fetch-1024)."""
from __future__ import annotations

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import PrefetchConfig, SimConfig


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    conds = {
        "full-fetch-1024": PrefetchConfig.full_fetch(1024),
        "full-fetch-2048": PrefetchConfig.full_fetch(2048),
        "fifty-fifty-2048": PrefetchConfig.fifty_fifty(2048),
    }
    for spec in workloads(fast):
        miss = {}
        for label, pf in conds.items():
            cfg = SimConfig(source="bucket", cache_items=pf.cache_items, prefetch=pf)
            ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
            miss[label] = mean(mean((t["miss_e1"], t["miss_e2"])) for t in ts)
            rows.append([spec.name, label, f"{miss[label]:.3f}"])
        wl = spec.name.split("-x")[0]
        drop = 1 - miss["fifty-fifty-2048"] / miss["full-fetch-1024"] \
            if miss["full-fetch-1024"] else 0.0
        checks.append(
            check(
                f"fig9/{wl}/fifty-fifty-wins",
                miss["fifty-fifty-2048"] <= miss["full-fetch-1024"] + 0.02,
                f"50/50 {miss['fifty-fifty-2048']:.3f} vs full-fetch-1024 "
                f"{miss['full-fetch-1024']:.3f} (drop {drop:.0%})",
            )
        )
        if wl == "cifar10-resnet50":
            checks.append(
                check(
                    "fig9/cifar/large-win",
                    drop >= 0.5,
                    f"50/50 cuts CIFAR miss {drop:.0%} vs Full-Fetch-1024 (paper 83%)",
                )
            )
    return {
        "name": "Fig. 9 — Full-Fetch vs 50/50",
        "table": fmt_table(["workload", "condition", "miss (mean ep1/2)"], rows),
        "rows": rows,
        "checks": checks,
    }
