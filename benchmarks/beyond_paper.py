"""Beyond-paper optimizations (paper §VI futures + our additions), each
benchmarked against the paper-faithful DELI configuration:

  1. locality-aware partitioning — nodes prefer samples already in their
     cache when the epoch re-partitions (kills the 66% epoch-2 miss floor);
  2. streaming cache inserts — samples become visible as they arrive
     instead of at fetch completion;
  3. listing cache — one Class A listing per session (paper §VI idea);
  4. super-samples — grouped objects divide Class B request count.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import (
    GcpPrices,
    PrefetchConfig,
    SimConfig,
    WorkloadCostInputs,
    cost_bucket,
    cost_with_listing_cache,
    cost_with_supersamples,
)

PRICES = GcpPrices()
CACHE = 2048


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    base_pf = PrefetchConfig.fifty_fifty(CACHE)
    for spec in workloads(fast):
        wl = spec.name.split("-x")[0]
        base_cfg = SimConfig(source="bucket", cache_items=CACHE, prefetch=base_pf)

        def stats(cfg):
            ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
            return (
                mean(t["miss_e2"] for t in ts),
                mean(t["wait_e1"] + t["wait_e2"] for t in ts),
            )

        miss_b, wait_b = stats(base_cfg)
        rows.append([spec.name, "50/50 baseline", f"{miss_b:.3f}", f"{wait_b:.1f}s"])

        # 1. locality-aware partitioning — attacks the paper's 66% epoch-2
        # miss floor (Fig. 5), which exists because the random re-partition
        # hands 2/3 of a node's cached samples to other nodes.  Measured in
        # the cache-only regime where that floor lives (under pre-fetching
        # the miss rate is already ~1%, so there is nothing to cut).
        cache_only = SimConfig(source="bucket", cache_items=-1)
        miss_r, _ = stats(cache_only)
        miss_l, _ = stats(dataclasses.replace(cache_only, locality_aware=True))
        rows.append([spec.name, "cache-only random part.", f"{miss_r:.3f}", ""])
        rows.append([spec.name, "cache-only +locality", f"{miss_l:.3f}", ""])
        checks.append(
            check(
                f"beyond/{wl}/locality-breaks-66pct-floor",
                miss_l < miss_r - 0.3,
                f"epoch-2 miss {miss_r:.1%} -> {miss_l:.1%} (floor ~66% -> ~0)",
            )
        )

        # 2. streaming inserts
        miss_s, wait_s = stats(dataclasses.replace(base_cfg, streaming_insert=True))
        rows.append([spec.name, "+streaming-insert", f"{miss_s:.3f}", f"{wait_s:.1f}s"])
        checks.append(
            check(
                f"beyond/{wl}/streaming-no-worse",
                wait_s <= wait_b * 1.05,
                f"wait {wait_b:.1f}s -> {wait_s:.1f}s",
            )
        )

        # 3+4. cost-side optimizations (paper §VI)
        inp = WorkloadCostInputs(
            n_nodes=spec.n_nodes, os_disk_gb=16.0, dataset_gb=spec.dataset_gb,
            n_samples=spec.n_samples, epochs=2,
            compute_seconds=2 * spec.compute_per_epoch_s,
            data_wait_seconds=wait_b, cached_samples=CACHE, fetch_size=1024,
        )
        api_base = cost_bucket(PRICES, inp, with_prefetch=True)["api"]
        api_lc = cost_with_listing_cache(PRICES, inp)["api"]
        api_ss = cost_with_supersamples(PRICES, inp, group_size=32)["api"]
        rows.append([spec.name, "api: per-fetch listing", f"${api_base:.3f}", ""])
        rows.append([spec.name, "api: +listing-cache", f"${api_lc:.3f}", ""])
        rows.append([spec.name, "api: +supersamples(32)", f"${api_ss:.3f}", ""])
        checks += [
            check(
                f"beyond/{wl}/listing-cache-cheaper",
                api_lc < api_base,
                f"${api_base:.3f} -> ${api_lc:.3f}",
            ),
            check(
                f"beyond/{wl}/supersamples-cheaper",
                api_ss < api_base,
                f"${api_base:.3f} -> ${api_ss:.3f}",
            ),
        ]
    return {
        "name": "Beyond-paper — locality, streaming, listing cache, super-samples",
        "table": fmt_table(["workload", "variant", "miss-ep2 / api$", "wait"], rows),
        "rows": rows,
        "checks": checks,
    }
