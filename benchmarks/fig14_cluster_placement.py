"""Fig. 14 (beyond-paper): cluster clairvoyant placement — one cross-rank
plan so every key is bucket-fetched (about) once (ISSUE 7).

Fig. 12's per-rank oracle removes every *local* inefficiency, but each
rank still plans alone: in the shared-shuffle regime (every rank streams
the full dataset in its own order) a key is bucket-fetched by every rank
that fails to catch it in a peer, multiplying cluster-wide Class B.  The
``ClusterPlacementPlanner`` closes that gap by partitioning the union of
epoch orders into ownership sets — each key's owner is the rank whose
first use is the cluster-wide earliest — so exactly one rank bucket-
fetches it and everyone else peer-pulls.  This benchmark sweeps per-node
cache capacity at equal aggregate capacity across three conditions:

  * hoard-static    — Hoard-style static placement: demand-filled caches
    with replication-aware eviction + the peer tier, no clairvoyance
    (``cache+peer+repl``);
  * oracle+peer     — fig. 12's best: per-rank clairvoyant prefetch +
    Belady + peer tier, no cross-rank plan;
  * cluster-oracle  — the ownership-partitioned plan (the tentpole).

Claim checks:

  * at AMPLE capacity the cluster plan's total Class B is within one
    listing round (``DEFAULT_BUCKET.page_size``) of the unique key count
    — near-zero duplicates, vs ~world x unique for per-rank planning;
  * cluster-oracle data-wait <= oracle+peer at EVERY capacity point (the
    plan never loses, even under eviction pressure where owners shed keys
    and consumers fall back to planned duplicate fetches);
  * cluster-oracle Class B <= oracle+peer at every point;
  * cluster-oracle data-wait <= hoard-static at every point (clairvoyant
    placement dominates static placement at equal aggregate capacity).

All conditions carry a peer registry, so the vector engine would fall
back to scalar stepping anyway (see ``repro/engine/vector.py``) — the
benchmark runs the scalar projection directly.
"""
from __future__ import annotations

from benchmarks.common import check, fmt_table, run_spec
from repro.core import MNIST
from repro.core.bandwidth import DEFAULT_BUCKET
from repro.pipeline import condition

#: Per-node cache capacities swept (-1 = unbounded = ample).
CAPACITIES = (64, 400, 600, 800, 1200, -1)
FAST_CAPACITIES = (64, 600, -1)

CONDITIONS = (
    ("hoard-static", "cache+peer+repl"),
    ("oracle+peer", "oracle+peer"),
    ("cluster-oracle", "cluster-oracle"),
)


def _measure(name, w, cache_items):
    spec = condition(name, w, cache_items=cache_items, sampler="shared-shuffle")
    r = run_spec(spec, epochs=2)
    return {
        "wait": sum(s.data_wait_seconds for s in r["stats"]),
        "class_b": r["store"].class_b_requests,
        "class_a": r["store"].class_a_requests,
        "ram": r["tiers"].get("ram", 0),
        "peer": r["tiers"].get("peer", 0),
        "bucket": r["tiers"].get("bucket", 0),
    }


def run(fast: bool = False) -> dict:
    w = MNIST.scaled(0.02)
    unique = w.n_samples
    slack = DEFAULT_BUCKET.page_size  # one listing round of duplicate races
    rows, checks = [], []
    for cap in FAST_CAPACITIES if fast else CAPACITIES:
        results = {}
        for tag, name in CONDITIONS:
            m = _measure(name, w, cap)
            results[tag] = m
            rows.append(
                [
                    "ample" if cap == -1 else str(cap),
                    tag,
                    f"{m['wait']:.2f}s",
                    f"{m['class_b']}",
                    f"{m['class_a']}",
                    f"{m['ram']}/{m['peer']}/{m['bucket']}",
                ]
            )
        cluster, per_rank = results["cluster-oracle"], results["oracle+peer"]
        cap_tag = "ample" if cap == -1 else f"C={cap}"
        checks.append(
            check(
                f"fig14/{cap_tag}/cluster-wait<=oracle+peer",
                cluster["wait"] <= per_rank["wait"] * (1 + 1e-9),
                f"cluster {cluster['wait']:.2f}s <= "
                f"oracle+peer {per_rank['wait']:.2f}s",
            )
        )
        checks.append(
            check(
                f"fig14/{cap_tag}/cluster-classB<=oracle+peer",
                cluster["class_b"] <= per_rank["class_b"],
                f"cluster B={cluster['class_b']} <= "
                f"oracle+peer B={per_rank['class_b']}",
            )
        )
        checks.append(
            check(
                f"fig14/{cap_tag}/cluster-wait<=hoard-static",
                cluster["wait"] <= results["hoard-static"]["wait"] * (1 + 1e-9),
                f"cluster {cluster['wait']:.2f}s <= "
                f"hoard-static {results['hoard-static']['wait']:.2f}s",
            )
        )
        if cap == -1:
            checks.append(
                check(
                    "fig14/ample/classB-within-one-listing-round-of-unique",
                    unique <= cluster["class_b"] <= unique + slack,
                    f"{unique} <= B={cluster['class_b']} <= {unique + slack} "
                    f"(unique + page_size; oracle+peer B={per_rank['class_b']})",
                )
            )
    return {
        "name": "Fig. 14 — cluster clairvoyant placement: one bucket fetch "
        "per key (beyond-paper)",
        "table": fmt_table(
            [
                "cache/node",
                "condition",
                "data-wait",
                "class B",
                "class A",
                "ram/peer/bucket",
            ],
            rows,
        ),
        "rows": rows,
        "checks": checks,
        "notes": (
            "3-node MNIST-scale cluster, shared-shuffle sampler (every rank "
            "streams all keys), 2 epochs, equal aggregate capacity per row "
            "block. cluster-oracle partitions each epoch's union of orders "
            "by cluster-wide earliest first use: the owner bucket-fetches, "
            "consumers peer-pull, and a consumer announcing a key whose "
            "owning fetch is still in flight defers it to its next announce "
            "point (the cluster-shared in-flight set is the signal). Under "
            "capacity pressure owners evict and consumers fall back to "
            "planned duplicate bulk fetches — never a duplicate bucket GET "
            "while a copy is resident or in flight — so data-wait degrades "
            "gracefully and still dominates per-rank planning everywhere. "
            "hoard-static shows static placement (demand-filled, "
            "replication-aware eviction) at the same aggregate capacity."
        ),
    }
