"""Fig. 8: pre-fetch thresholds (25/50/75% of cache) across cache sizes
(0.5x..3x of fetch=1024), both workloads.  Validates: 50% threshold at
cache 2048 gives the big reliable miss-rate drop vs threshold 0."""
from __future__ import annotations

from benchmarks.common import check, fmt_table, mean, trials, workloads
from repro.core import PrefetchConfig, SimConfig

FETCH = 1024


def run(fast: bool = False) -> dict:
    rows, checks = [], []
    for spec in workloads(fast):
        grid = {}
        for mult in (0.5, 1.0, 2.0, 3.0):
            cache = int(FETCH * mult)
            for tfrac in (0.0, 0.25, 0.5, 0.75):
                thr = int(cache * tfrac)
                cfg = SimConfig(
                    source="bucket", cache_items=cache,
                    prefetch=PrefetchConfig(fetch_size=FETCH, prefetch_threshold=thr,
                                            cache_items=cache),
                )
                ts = trials(spec, cfg, epochs=2, n=1 if fast else 3)
                m = mean(mean((t["miss_e1"], t["miss_e2"])) for t in ts)
                grid[(mult, tfrac)] = m
                rows.append([spec.name, cache, f"{int(tfrac*100)}%", f"{m:.3f}"])
        base = grid[(2.0, 0.0)]  # cache 2048, threshold 0
        fifty = grid[(2.0, 0.5)]  # the 50/50 point
        drop = 1 - fifty / base if base else 0.0
        wl = spec.name.split("-x")[0]
        expect = {"mnist-cnn": 0.31, "cifar10-resnet50": 0.80}[wl]
        checks += [
            check(
                f"fig8/{wl}/50pct-threshold-drop",
                drop >= expect - 0.15,
                f"cache=2048: T=50% cuts miss {drop:.0%} vs T=0 (paper ~{expect:.0%})",
            ),
            check(
                f"fig8/{wl}/50pct-best-or-close",
                fifty <= min(grid[(2.0, t)] for t in (0.0, 0.25, 0.75)) + 0.03,
                f"T=50% miss {fifty:.3f} vs others "
                f"{[round(grid[(2.0, t)], 3) for t in (0.0, 0.25, 0.75)]}",
            ),
        ]
    return {
        "name": "Fig. 8 — pre-fetch thresholds across cache sizes",
        "table": fmt_table(["workload", "cache", "threshold", "miss"], rows),
        "rows": rows,
        "checks": checks,
    }
