"""Fig. 13 (beyond-paper): simulator weak-scaling — the vectorized segment
engine vs the scalar event engine (ISSUE 6).

Figs. 3-12 measure the *modelled* system; this figure measures the
simulator itself.  The scalar engine pays one heap event (plus Python-level
cost arithmetic) per sample per node, so a modelled epoch costs O(samples x
log nodes) host work — fine at the paper's 3-node scale, painful at
hundreds of nodes.  The vector engine (``repro.engine.vector``) advances
each node's between-interaction *segment* — the run of demand reads between
prefetch-round completions, announce points, and batch/epoch barriers — as
batched numpy array ops, keeping the event heap only for cross-node
interactions.  Because both engines share the per-sample cost kernel
(``repro.engine.kernels``) and the vector engine accumulates with
sequential ``np.cumsum`` scans, results are bit-for-bit ``==`` identical
(docs/PARITY.md) — asserted here at every sweep point, not within a
tolerance.

The sweep holds per-node work fixed (weak scaling: 2 000 samples per node)
and grows the cluster, on two conditions bracketing the engine's win:

  * ``gcp-direct`` — no cache state at all: whole inter-barrier spans
    vectorize, the speedup is the pure event-loop overhead;
  * ``50/50`` — the paper's best prefetch configuration: segments end at
    announce points and round completions, and cache membership still
    evolves through the real ``CappedCache`` (exactness over speed), so
    the speedup is smaller but the condition is the paper's data plane.

Claim checks:

  * scalar and vector results are exactly ``==`` at every sweep point
    (tier hits, Class A/B, bytes, per-node stat tuples);
  * >= 10x speedup (>= 3x under ``--fast``'s smaller sweep, where the
    scalar baseline runs milliseconds and timing noise dominates) on the
    best condition at the largest node count — typically ``50/50``,
    where the scalar engine also pays planner/cache Python work per
    sample, with ``gcp-direct`` reported alongside;
  * a 100-node, 10^6-sample epoch on the 50/50 data plane completes in
    seconds (<= 60 s wall-clock) under the vector engine — the scale the
    scalar engine made impractical to sweep.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import check, fmt_table
from repro.core import MNIST, aggregate_tier_hits
from repro.pipeline import condition

#: Weak scaling: fixed per-node partition, growing cluster.
PER_NODE_SAMPLES = 2000
SWEEP_FULL = (2, 10, 50, 100)
SWEEP_FAST = (2, 10, 20)
#: The big-epoch claim point (full mode): 100 nodes, 10^6 samples.
BIG_FULL = (100, 1_000_000)
BIG_FAST = (20, 100_000)
SPEEDUP_FLOOR_FULL = 10.0
SPEEDUP_FLOOR_FAST = 3.0


def _workload(n_nodes: int, n_samples: int):
    """MNIST cost ratios (sample bytes, per-batch compute) at an arbitrary
    dataset/cluster shape; per-node compute stays MNIST's per-partition
    figure, so weak scaling holds the modelled per-node work fixed."""
    return dataclasses.replace(
        MNIST, name=f"mnist-{n_nodes}n", n_samples=n_samples, n_nodes=n_nodes
    )


def _conditions(w):
    return [
        ("gcp-direct", condition("gcp-direct", w)),
        ("50/50", condition("fifty-fifty", w, cache_items=512)),
    ]


def _fingerprint(stats, store):
    """Everything the equivalence claim compares, exactly (no rounding)."""
    return (
        aggregate_tier_hits(stats),
        store.class_a_requests,
        store.class_b_requests,
        store.bytes_read,
        [
            (s.epoch, s.node, s.samples, s.data_wait_seconds,
             s.compute_seconds, s.allreduce_wait_seconds, s.evictions)
            for s in stats
        ],
    )


def _timed_run(spec, engine: str, epochs: int = 1, repeats: int = 1):
    """Best-of-``repeats`` wall-clock (the standard noise-robust estimator;
    host jitter only ever inflates a measurement) + the result fingerprint."""
    plane = dataclasses.replace(spec, engine=engine)
    best = float("inf")
    fp = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        stats, store = plane.build_sim().run(epochs=epochs)
        best = min(best, time.perf_counter() - t0)
        fp = _fingerprint(stats, store)
    return best, fp


def run(fast: bool = False) -> dict:
    sweep = SWEEP_FAST if fast else SWEEP_FULL
    big_nodes, big_samples = BIG_FAST if fast else BIG_FULL
    floor = SPEEDUP_FLOOR_FAST if fast else SPEEDUP_FLOOR_FULL
    rows, checks = [], []
    all_exact = True
    top_speedups = {}
    for n_nodes in sweep:
        w = _workload(n_nodes, PER_NODE_SAMPLES * n_nodes)
        for tag, spec in _conditions(w):
            t_scalar, fp_scalar = _timed_run(spec, "scalar", repeats=2)
            t_vector, fp_vector = _timed_run(spec, "vector", repeats=3)
            exact = fp_scalar == fp_vector
            all_exact = all_exact and exact
            speedup = t_scalar / t_vector if t_vector > 0 else float("inf")
            if n_nodes == sweep[-1]:
                top_speedups[tag] = speedup
            rows.append(
                [
                    tag,
                    f"{n_nodes}",
                    f"{w.n_samples}",
                    f"{t_scalar:.3f}s",
                    f"{t_vector:.3f}s",
                    f"{speedup:.1f}x",
                    f"{1.0 / t_vector:.1f}" if t_vector > 0 else "inf",
                    "==" if exact else "MISMATCH",
                ]
            )
    checks.append(
        check(
            "fig13/scalar-vector-exact-at-every-point",
            all_exact,
            f"{len(rows)} sweep points compared field-for-field with == "
            "(tier hits, Class A/B, bytes, per-node stat tuples)",
        )
    )
    best = max(top_speedups.values()) if top_speedups else 0.0
    checks.append(
        check(
            f"fig13/speedup>={floor:.0f}x-at-{sweep[-1]}-nodes",
            best >= floor,
            f"best condition at {sweep[-1]} nodes: {best:.1f}x "
            + "("
            + ", ".join(f"{t} {s:.1f}x" for t, s in top_speedups.items())
            + f"; floor {floor:.0f}x{', fast sweep' if fast else ''})",
        )
    )
    # -- the big epoch: the scale the scalar engine made impractical --------
    w_big = _workload(big_nodes, big_samples)
    big_spec = condition("fifty-fifty", w_big, cache_items=512)
    t_big, _ = _timed_run(big_spec, "vector")
    rows.append(
        [
            "50/50",
            f"{big_nodes}",
            f"{big_samples}",
            "-",
            f"{t_big:.2f}s",
            "-",
            f"{1.0 / t_big:.2f}",
            "(vector only)",
        ]
    )
    checks.append(
        check(
            f"fig13/{big_nodes}-node-{big_samples}-sample-epoch-in-seconds",
            t_big <= 60.0,
            f"one epoch, {big_nodes} nodes x {big_samples // big_nodes} "
            f"samples/node, 50/50 prefetch: {t_big:.2f}s wall-clock "
            "(vector engine)",
        )
    )
    return {
        "name": "Fig. 13 — simulator weak-scaling: vectorized segment engine "
        "vs scalar event engine (beyond-paper)",
        "engine": "vector",
        "table": fmt_table(
            [
                "condition",
                "nodes",
                "samples",
                "scalar",
                "vector",
                "speedup",
                "epochs/sec (vec)",
                "equivalence",
            ],
            rows,
        ),
        "rows": rows,
        "checks": checks,
        "notes": (
            "Weak scaling: 2 000 samples per node, one modelled epoch per "
            "point, both engines on the same spec; 'equivalence' is exact "
            "== on tier hits, Class A/B, bytes and per-node (samples, "
            "data-wait, compute, allreduce, evictions) tuples — the vector "
            "engine shares the scalar engine's cost kernel and accumulates "
            "with sequential cumsum scans, so floats agree bit-for-bit. "
            "gcp-direct isolates the event-loop overhead (whole spans "
            "vectorize); 50/50 keeps the real CappedCache in the loop "
            "(exactness over speed) and still clears the big-epoch bar: "
            "the final row models a 10^6-sample epoch across 100 nodes in "
            "seconds under the vector engine."
        ),
    }
