"""Fig. 15 (beyond-paper): allreduce cost, gradient-bucket overlap, and
straggler mitigation on the fig11 cluster (ISSUE 8).

fig11 made the per-batch allreduce *schedule* first-class but kept the
collective itself free: blocked time was pure skew wait.  This benchmark
attaches a ``CollectiveModel`` — ring allreduce duration from the
calibrated ``NetworkModel`` and real gradient byte counts — to the same
4-node cluster (rank 0 slowed 2x in compute and I/O) and sweeps two
gradient regimes:

  * ``mnist-cnn`` — the paper's CNN (~1.8 MB of fp32 gradients):
    compute-bound, the transfer all but vanishes behind backprop;
  * ``lm-130m`` — a 130M-parameter LM config (~520 MB): comm-bound, the
    exposed transfer rivals compute.

Per regime, three conditions ride the identical data plane:

  * ``bsync+comm`` — barriers grow a transfer duration: blocked time now
    splits into allreduce *wait* (skew) + allreduce *comm* (transfer);
  * ``+overlap`` — the gradient decomposes into buckets whose allreduces
    issue as sub-step events interleaved with the remaining backprop
    (``BucketedBatchComm``), so only the last bucket's exposed tail is
    charged;
  * ``+backup-1`` — barriers release after n-1 ranks: the straggler's
    gradient is dropped (it pays no comm at all), the surviving
    collective runs at the fast ranks' unscaled pace, samples all
    accounted.

Claim checks:

  * bucket overlap hides >= 30% of the allreduce comm time versus
    ``overlap="none"`` at equal collective cost — in BOTH regimes;
  * overlap never increases any node's wall clock at equal cost;
  * ``backup_workers=1`` reduces the cluster's max epoch wall versus
    plain ``bsync+comm`` (the fig11 straggler tax shrinks measurably);
  * equal cost = equal data plane: tier outcomes and Class A/B identical
    across all three conditions (the communication schedule moves clocks,
    never cache behaviour);
  * sim/runtime parity stays exact (==) at every swept condition.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import check, dump_trace, fmt_table, run_spec
from repro.core import MNIST, CollectiveModel, mnist_cnn_gradient_bytes, straggler_profiles
from repro.pipeline import DataPlaneSpec, run_parity

SLOW_RANK = 0
SLOWDOWN = 2.0
LM_PARAMS = 130_000_000  # mamba2-130m scale (repro.configs), fp32


def _lm_gradient_bytes() -> int:
    try:  # exact count from the real config when jax is importable
        from repro.core import arch_gradient_bytes

        return arch_gradient_bytes("mamba2-130m")
    except Exception:
        return 4 * LM_PARAMS


def _conditions(fast: bool):
    w = dataclasses.replace(MNIST.scaled(0.05 if fast else 0.1), n_nodes=4)
    half = max(2, w.partition_size // 2)
    profs = straggler_profiles(w.n_nodes, (SLOW_RANK,), SLOWDOWN, SLOWDOWN)
    regimes = [
        ("mnist-cnn", mnist_cnn_gradient_bytes()),
        ("lm-130m", _lm_gradient_bytes()),
    ]
    out = []
    for tag, grad in regimes:
        cm = CollectiveModel(gradient_bytes=grad)
        base = dict(
            workload=w, cache_items=half, nodes=profs, sync="batch", collective=cm
        )
        out.append(
            (
                tag,
                grad,
                [
                    ("bsync+comm", DataPlaneSpec(**base)),
                    ("+overlap", DataPlaneSpec(overlap="buckets", **base)),
                    ("+backup-1", DataPlaneSpec(backup_workers=1, **base)),
                ],
            )
        )
    return w, out


def _totals(stats):
    comm = sum(s.allreduce_comm_seconds for s in stats)
    wait = sum(s.allreduce_wait_seconds for s in stats)
    wall = max(s.wall_seconds for s in stats)
    slow_comm = sum(s.allreduce_comm_seconds for s in stats if s.node == SLOW_RANK)
    return comm, wait, wall, slow_comm


def run(fast: bool = False, trace_dir=None) -> dict:
    rows, checks, traces = [], [], []
    w, regimes = _conditions(fast)
    for regime, grad, conditions in regimes:
        results = {}
        for tag, spec in conditions:
            r = run_spec(spec, epochs=2)
            if trace_dir is not None and regime == "lm-130m" and tag == "+overlap":
                # Headline condition (comm-bound regime with bucket
                # overlap): flight-recorder dump + the observer claim.
                path = trace_dir / "fig15.trace.json"
                same, n_events = dump_trace(spec, r["stats"], path)
                traces.append(path)
                checks.append(
                    check(
                        "fig15/trace-on-stats-identical",
                        same,
                        f"{n_events} events -> {path.name}; "
                        "traced EpochStats == untraced",
                    )
                )
            comm, wait, wall, slow_comm = _totals(r["stats"])
            results[tag] = dict(
                r=r, comm=comm, wait=wait, wall=wall, slow_comm=slow_comm, spec=spec
            )
            rows.append(
                [
                    f"{regime} / {tag}",
                    f"{grad / 1e6:.1f}MB",
                    f"{comm:.3f}s",
                    f"{wait:.2f}s",
                    f"{wall:.2f}s",
                    f"{r['store'].class_b_requests}",
                ]
            )
        none, ovl, bkp = results["bsync+comm"], results["+overlap"], results["+backup-1"]
        hidden = (none["comm"] - ovl["comm"]) / none["comm"]
        checks.append(
            check(
                f"fig15/{regime}/overlap-hides-30pct-of-comm",
                hidden >= 0.30,
                f"comm {none['comm']:.3f}s -> {ovl['comm']:.3f}s "
                f"({hidden:.1%} hidden behind backprop)",
            )
        )
        n_walls = sorted(s.wall_seconds for s in none["r"]["stats"])
        o_walls = sorted(s.wall_seconds for s in ovl["r"]["stats"])
        checks.append(
            check(
                f"fig15/{regime}/overlap-wall-never-worse",
                all(o <= n * (1 + 1e-9) for n, o in zip(n_walls, o_walls)),
                f"max wall {none['wall']:.3f}s -> {ovl['wall']:.3f}s",
            )
        )
        checks.append(
            check(
                f"fig15/{regime}/backup-shrinks-straggler-tax",
                bkp["wall"] < none["wall"] and bkp["slow_comm"] == 0.0,
                f"max wall {none['wall']:.3f}s -> {bkp['wall']:.3f}s "
                f"(-{(none['wall'] - bkp['wall']) / none['wall']:.1%}), "
                f"straggler comm {none['slow_comm']:.3f}s -> 0",
            )
        )
        checks.append(
            check(
                f"fig15/{regime}/equal-cost-data-plane-identical",
                all(
                    v["r"]["tiers"] == none["r"]["tiers"]
                    and v["r"]["store"].class_b_requests
                    == none["r"]["store"].class_b_requests
                    for v in results.values()
                ),
                f"tiers {none['r']['tiers']} and class B "
                f"{none['r']['store'].class_b_requests} across all conditions",
            )
        )
        for tag, v in results.items():
            report = run_parity(v["spec"], epochs=2)
            checks.append(
                check(
                    f"fig15/{regime}/{tag}/parity-exact",
                    report.exact,
                    report.describe().splitlines()[0],
                )
            )
    return {
        "name": "Fig. 15 — allreduce cost, bucket overlap, straggler mitigation (beyond-paper)",
        "table": fmt_table(
            ["regime / condition", "gradient", "allreduce comm", "allreduce wait", "max wall", "class B"],
            rows,
        ),
        "rows": rows,
        "checks": checks,
        "traces": traces,
        "notes": (
            "fig11's 4-node straggler cluster with the collective itself "
            "modeled: ring allreduce over the Table-I-calibrated network, "
            "gradient bytes from the paper's CNN (~1.8 MB) and a "
            "130M-parameter LM config (~520 MB). Bucketed overlap "
            "(BucketedBatchComm, shared verbatim by both projections) "
            "charges only the exposed tail of the bucket pipeline; "
            "backup_workers=1 releases barriers without the straggler, "
            "dropping its gradient while keeping its samples accounted. "
            "Every condition is also parity-checked exactly against the "
            "lock-step runtime."
        ),
    }
