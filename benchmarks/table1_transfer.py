"""Table I: transfer speed of reading MNIST into memory (disk, sequential
bucket, 16-thread parallel bucket).  Validates the bandwidth-model
calibration against the paper's measured operating points."""
from __future__ import annotations

from benchmarks.common import check, fmt_table
from repro.core import DEFAULT_BUCKET, DEFAULT_DISK
from repro.core.workloads import MNIST

PAPER = {"disk": 18.63e6, "seq": 49.80e3, "par16": 281.73e3}


def run(fast: bool = False) -> dict:
    s = MNIST.sample_bytes
    got = {
        "disk": DEFAULT_DISK.effective_bw,
        "seq": DEFAULT_BUCKET.sequential_throughput(s),
        "par16": DEFAULT_BUCKET.parallel_throughput(s, 16),
    }
    rows = [
        ["Disk", f"{got['disk']/1e6:.2f} MB/s", "18.63 MB/s"],
        ["Object storage (seq)", f"{got['seq']/1e3:.2f} kB/s", "49.80 kB/s"],
        ["Object storage (16 thr)", f"{got['par16']/1e3:.2f} kB/s", "281.73 kB/s"],
    ]
    checks = [
        check(
            f"table1/{k}",
            abs(got[k] - PAPER[k]) / PAPER[k] < 0.10,
            f"model {got[k]:.3e} vs paper {PAPER[k]:.3e} B/s",
        )
        for k in PAPER
    ]
    return {
        "name": "Table I — transfer speeds (model calibration)",
        "table": fmt_table(["source", "model", "paper"], rows),
        "rows": rows,
        "checks": checks,
    }
