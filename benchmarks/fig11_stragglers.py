"""Fig. 11 (beyond-paper): straggler-faithful cluster schedule — per-batch
allreduce barriers vs the epoch-barrier schedule (ISSUE 4).

The paper's workloads are data-parallel SGD: gradients synchronize at
EVERY batch, yet the epoch-barrier schedule lets a fast node run
arbitrarily far ahead, misattributing where skewed clusters actually spend
their time.  This benchmark runs a 4-node cluster with rank 0 slowed 2x in
both compute and I/O (``NodeProfile``) under both schedules and reports,
per condition:

  * per-node busy time (data-wait + compute) and wall time (busy +
    allreduce waits) — under ``sync="batch"`` every node's wall time
    equalizes to the barrier-to-barrier pace the straggler sets;
  * aggregate allreduce wait (the straggler tax the epoch schedule hides);
  * peer-tier hits and Class B — how one-batch lockstep changes what the
    cooperative cache tier can serve.

Claim checks (the provable invariants):

  * non-interacting condition (local cache only): per-node wall time under
    batch sync >= epoch sync, busy time identical, Class A/B identical —
    barriers move clocks, never cache behaviour;
  * slowest-node bound: under batch sync every node's wall time >= the
    busiest node's own busy time (sum of per-batch maxima dominates any
    node's own sum);
  * batch-sync walls equalize across nodes (everyone leaves the last
    barrier together) and allreduce wait is attributed to the fast nodes;
  * epoch-sync defaults keep ``allreduce_wait_seconds == 0`` — the ledger
    the PR 3 schedule never charged stays untouched.

The peer-tier deltas are *reported* rather than direction-asserted: with
capped caches the sign depends on how eviction windows align (the fast
nodes' caches stay near the straggler's working set under batch sync, which
can even shorten the straggler's own data-wait — see the notes).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import check, dump_trace, fmt_table, run_spec
from repro.core import MNIST, PrefetchConfig, straggler_profiles
from repro.pipeline import DataPlaneSpec

SLOW_RANK = 0
SLOWDOWN = 2.0


def _conditions(fast: bool):
    w = dataclasses.replace(MNIST.scaled(0.05 if fast else 0.1), n_nodes=4)
    half = max(2, w.partition_size // 2)
    profs = straggler_profiles(w.n_nodes, (SLOW_RANK,), SLOWDOWN, SLOWDOWN)
    # Vector engine (ISSUE 6): exact == results (tests/test_engine_
    # equivalence.py); the peer conditions fall back to scalar per node.
    base = dict(workload=w, cache_items=half, nodes=profs, engine="vector")
    return w, [
        ("local cache", DataPlaneSpec(**base)),
        ("peer", DataPlaneSpec(peer_cache=True, **base)),
        (
            "peer + 50/50 pf",
            DataPlaneSpec(
                peer_cache=True, prefetch=PrefetchConfig.fifty_fifty(half), **base
            ),
        ),
    ]


def _per_node(stats):
    busy, wall, allreduce = {}, {}, {}
    for s in stats:
        busy[s.node] = busy.get(s.node, 0.0) + s.data_wait_seconds + s.compute_seconds
        wall[s.node] = wall.get(s.node, 0.0) + s.wall_seconds
        allreduce[s.node] = allreduce.get(s.node, 0.0) + s.allreduce_wait_seconds
    return busy, wall, allreduce


def run(fast: bool = False, trace_dir=None) -> dict:
    rows, checks, traces = [], [], []
    w, conditions = _conditions(fast)
    for tag, base in conditions:
        results = {}
        for sync in ("epoch", "batch"):
            r = run_spec(dataclasses.replace(base, sync=sync), epochs=2)
            if trace_dir is not None and tag == "peer + 50/50 pf" and sync == "batch":
                # Headline condition: flight-recorder dump + the observer
                # claim (traced rerun's stats byte-identical, ISSUE 10).
                path = trace_dir / "fig11.trace.json"
                same, n_events = dump_trace(
                    dataclasses.replace(base, sync=sync), r["stats"], path
                )
                traces.append(path)
                checks.append(
                    check(
                        "fig11/trace-on-stats-identical",
                        same,
                        f"{n_events} events -> {path.name}; "
                        "traced EpochStats == untraced",
                    )
                )
            busy, wall, allreduce = _per_node(r["stats"])
            results[sync] = dict(
                r=r, busy=busy, wall=wall, allreduce=allreduce,
                peer=r["tiers"].get("peer", 0), class_b=r["store"].class_b_requests,
            )
            rows.append(
                [
                    f"{tag} / {sync}",
                    f"{results[sync]['peer']}",
                    f"{results[sync]['class_b']}",
                    f"{busy[SLOW_RANK]:.2f}s",
                    f"{min(busy[n] for n in busy if n != SLOW_RANK):.2f}s",
                    f"{max(wall.values()):.2f}s",
                    f"{max(wall.values()) / min(wall.values()):.3f}",
                    f"{sum(allreduce.values()):.2f}s",
                ]
            )
        e, b = results["epoch"], results["batch"]
        nodes = sorted(b["wall"])
        # Epoch schedule never charges the allreduce ledger.
        checks.append(
            check(
                f"fig11/{tag}/epoch-allreduce-zero",
                all(v == 0.0 for v in e["allreduce"].values()),
                f"epoch-sync allreduce={sum(e['allreduce'].values()):.3f}s",
            )
        )
        # Batch-sync walls equalize: everyone leaves the last barrier together.
        spread = max(b["wall"].values()) / min(b["wall"].values())
        checks.append(
            check(
                f"fig11/{tag}/batch-walls-equalize",
                spread < 1.0 + 1e-6,
                f"max/min wall = {spread:.9f}",
            )
        )
        # Slowest-node bound: every node's batch wall >= the busiest node's
        # own busy time (sum of per-batch maxima >= any own sum).
        busiest = max(b["busy"].values())
        checks.append(
            check(
                f"fig11/{tag}/slowest-node-bound",
                all(b["wall"][n] >= busiest * (1 - 1e-9) for n in nodes),
                f"min wall {min(b['wall'].values()):.2f}s >= busiest busy {busiest:.2f}s",
            )
        )
        # The allreduce tax is paid by the fast nodes, not the straggler.
        fast_nodes = [n for n in nodes if n != SLOW_RANK]
        checks.append(
            check(
                f"fig11/{tag}/straggler-waits-least",
                all(
                    b["allreduce"][SLOW_RANK] <= b["allreduce"][n] for n in fast_nodes
                )
                and sum(b["allreduce"].values()) > 0,
                f"allreduce slow={b['allreduce'][SLOW_RANK]:.2f}s "
                f"fast(min)={min(b['allreduce'][n] for n in fast_nodes):.2f}s",
            )
        )
        if tag == "local cache":
            # Non-interacting: barriers move clocks, never cache behaviour.
            checks.append(
                check(
                    "fig11/local-cache/wall-no-decrease-and-busy-identical",
                    all(
                        b["wall"][n] >= e["wall"][n] * (1 - 1e-12) for n in nodes
                    )
                    and all(
                        abs(b["busy"][n] - e["busy"][n]) <= 1e-9 * e["busy"][n]
                        for n in nodes
                    )
                    and b["class_b"] == e["class_b"],
                    f"walls {['%.2f' % e['wall'][n] for n in nodes]} -> "
                    f"{['%.2f' % b['wall'][n] for n in nodes]}, "
                    f"classB {e['class_b']} == {b['class_b']}",
                )
            )
        else:
            checks.append(
                check(
                    f"fig11/{tag}/peer-tier-alive-both-schedules",
                    e["peer"] > 0 and b["peer"] > 0,
                    f"peer hits epoch={e['peer']} batch={b['peer']} "
                    f"(delta {b['peer'] - e['peer']:+d}), "
                    f"classB epoch={e['class_b']} batch={b['class_b']} "
                    f"(delta {b['class_b'] - e['class_b']:+d})",
                )
            )
    return {
        "name": "Fig. 11 — stragglers under per-batch allreduce barriers (beyond-paper)",
        "engine": "vector",
        "table": fmt_table(
            [
                "condition / sync",
                "peer hits",
                "class B",
                "slow busy",
                "fast busy",
                "max wall",
                "wall spread",
                "allreduce",
            ],
            rows,
        ),
        "rows": rows,
        "checks": checks,
        "traces": traces,
        "notes": (
            "4-node MNIST-scale cluster, rank 0 slowed 2x in compute AND I/O "
            "(NodeProfile). sync='batch' parks every node at each gradient "
            "batch (BSP allreduce): wall times equalize to the straggler's "
            "pace and the fast nodes' blocked time lands in "
            "EpochStats.allreduce_wait_seconds — the straggler tax the "
            "epoch-barrier schedule reported as zero. Peer-tier deltas are "
            "reported, not direction-asserted: one-batch lockstep keeps the "
            "fast nodes' capped caches near the straggler's working set, "
            "which can cut the straggler's own data-wait even as same-epoch "
            "run-ahead fills disappear."
        ),
    }
