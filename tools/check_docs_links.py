#!/usr/bin/env python3
"""Link-check the documentation surface (CI docs lane).

Scans README.md and docs/*.md for intra-repo references and fails on any
that point at files or directories that do not exist:

  * inline markdown links  [text](target)  whose target is not an
    external URL or pure anchor;
  * inline-code path mentions (`path/to/file.py`) that look like repo
    paths (contain a slash and an extension or trailing slash).

No third-party dependencies — runnable anywhere Python is.  Exit status 0
when every reference resolves, 1 otherwise (one line per broken link).
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(r"`([A-Za-z0-9_.~/\-]+/[A-Za-z0-9_.\-]+)`")
EXTERNAL = ("http://", "https://", "mailto:")
# Inline-code mentions are only treated as paths when they end with a known
# file extension or a slash — `repro.pipeline` or `a/b` pseudo-paths in
# prose stay prose.
PATH_SUFFIXES = (".py", ".md", ".json", ".yml", ".yaml", ".txt", ".ini", "/")


def doc_files() -> list:
    docs = [REPO / "README.md"]
    docs.extend(sorted((REPO / "docs").glob("*.md")))
    return [d for d in docs if d.exists()]


def targets_in(text: str):
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        yield target.split("#", 1)[0], "link"
    for m in CODE_PATH.finditer(text):
        target = m.group(1)
        if target.endswith(PATH_SUFFIXES):
            yield target.rstrip("/"), "code-path"


def check() -> list:
    """Returns a list of 'file: broken target' strings (empty = clean)."""
    broken = []
    for doc in doc_files():
        text = doc.read_text(encoding="utf-8")
        for target, kind in targets_in(text):
            if not target:
                continue
            resolved = (doc.parent / target) if not target.startswith("/") else None
            if resolved is None:
                broken.append(f"{doc.relative_to(REPO)}: absolute path {target!r}")
                continue
            # Links resolve relative to the doc; code-path mentions are
            # written repo-relative by convention.
            if kind == "code-path":
                resolved = REPO / target
            if not resolved.exists():
                broken.append(
                    f"{doc.relative_to(REPO)}: {kind} -> {target!r} does not exist"
                )
    return broken


def main() -> int:
    docs = doc_files()
    if not docs:
        print("no documentation files found", file=sys.stderr)
        return 1
    broken = check()
    for line in broken:
        print(f"BROKEN  {line}", file=sys.stderr)
    print(f"checked {len(docs)} docs: {'OK' if not broken else f'{len(broken)} broken'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
