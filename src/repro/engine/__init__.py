"""Execution engines for the data-plane simulator.

``repro.engine.kernels`` holds the engine-agnostic per-sample cost
arithmetic (:class:`DemandKernel`) shared by every consumer — the scalar
event engine in ``repro.core.simulator``, the sub-step machine in
``repro.core.lockstep``, ``DeliLoader``'s runtime mirror, and the vector
engine here.

``repro.engine.vector`` is the batched engine: it advances each node's
between-interaction *segment* (the run of demand reads between prefetch
round completions and batch/epoch barriers) as numpy array ops, leaving
the event heap in ``lockstep.drive_interleaved_epoch`` as the sole
arbiter of cross-node ordering.  Selected via ``SimConfig(engine=
"vector")`` / ``DataPlaneSpec(engine="vector")``; equivalence with the
scalar engine is exact ``==`` (docs/PARITY.md).

``VectorNodeEngine`` is exposed lazily: ``repro.engine.vector`` imports
``repro.core.simulator``, while core modules import ``repro.engine.
kernels`` — the lazy hop keeps that acyclic.
"""
from __future__ import annotations

from repro.engine.kernels import DemandKernel

__all__ = ["DemandKernel", "VectorNodeEngine"]


def __getattr__(name: str):
    if name == "VectorNodeEngine":
        from repro.engine.vector import VectorNodeEngine

        return VectorNodeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
