"""Engine-agnostic per-sample cost kernels (ISSUE 6 tentpole).

The per-sample data-plane cost arithmetic — which float components a demand
read charges, in which order, and what it bills to the object store — used
to live three times: in ``NodeSimulator._access`` (the scalar event
engine), in ``SubstepAccess.run`` (the sub-step decomposition shared by
both projections) and in ``DeliLoader._sample_steps`` (the lock-step
runtime's modelled loop costs).  Introducing a *second* execution engine
(``repro.engine.vector``) would have made it four.  This module is the ONE
home:

  * :class:`DemandKernel` precomputes every per-sample charge component
    from a node's (profile-scaled) calibrated models.  Each component is a
    pure function of fixed inputs, so precomputing it yields bit-identical
    floats to recomputing it per access — the parity discipline
    (docs/PARITY.md) is preserved by construction.
  * :meth:`DemandKernel.tier_charges` maps a serving tier to its ordered
    charge tuple for the step-granularity schedule.  The scalar engine
    accumulates the tuple left-to-right with ``t += c``; the vector engine
    lays the same components into a flat charge array and runs one
    ``np.cumsum`` (a strictly sequential left-to-right scan — the same
    rounding as the scalar chain); the sub-step machine charges the same
    components one scheduler event at a time.  Same floats, same order,
    every engine.
  * :meth:`DemandKernel.bill_demand_gets` is the demand-path Class B
    billing (integer counters — exact under any batching).

Deliberately import-free of the rest of ``repro``: the models are
duck-typed (``BucketModel``/``DiskModel``/``NetworkModel``/
``PipelineCostModel`` from ``repro.core.bandwidth``), so ``repro.core``
modules can import this one without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

#: Serving tiers a demand read can resolve to (step-granularity schedule).
DEMAND_TIERS = ("disk-source", "ram", "peer", "bucket")


@dataclasses.dataclass(frozen=True)
class DemandKernel:
    """Precomputed per-sample charge components for one node.

    Fields are the exact floats the node's scaled models produce for its
    workload's nominal sample size; ``sample_bytes`` rides along for
    billing.  Construct via :meth:`from_models` (full data plane) or
    :meth:`loop_only` (just the modelled training-loop overheads — the
    ``DeliLoader`` runtime mirror, where tier latencies come from the real
    stores sleeping their own clocks).
    """

    ram_hit_s: float
    cpu_overhead_s: float
    disk_get_s: float
    bucket_get_s: float
    peer_stream_s: float  # sub-step schedule: payload streaming after the RTT
    peer_transfer_s: float  # step schedule: RTT + streaming as one component
    probe_rtt_s: float  # failed peer probe (and the sub-step probe flight)
    sample_bytes: int

    @classmethod
    def from_models(cls, *, bucket, network, pipeline, sample_bytes: int, disk=None):
        """``disk=None`` is for consumers that can never serve from the
        disk-source tier (e.g. the sub-step machine, which only exists for
        bucket-source specs)."""
        return cls(
            ram_hit_s=pipeline.ram_hit_s,
            cpu_overhead_s=pipeline.cpu_overhead_s,
            disk_get_s=0.0 if disk is None else disk.get_seconds(sample_bytes),
            bucket_get_s=bucket.get_seconds(sample_bytes),
            peer_stream_s=network.stream_seconds(sample_bytes),
            peer_transfer_s=network.transfer_seconds(sample_bytes),
            probe_rtt_s=network.lookup_seconds(),
            sample_bytes=sample_bytes,
        )

    @classmethod
    def loop_only(cls, pipeline, sample_bytes: int = 0):
        """Just the modelled loop overheads (the runtime loader's share)."""
        return cls(
            ram_hit_s=pipeline.ram_hit_s,
            cpu_overhead_s=pipeline.cpu_overhead_s,
            disk_get_s=0.0,
            bucket_get_s=0.0,
            peer_stream_s=0.0,
            peer_transfer_s=0.0,
            probe_rtt_s=0.0,
            sample_bytes=sample_bytes,
        )

    def tier_charges(self, tier: str, probed: bool = False) -> Tuple[float, ...]:
        """The ordered charge components of one step-granularity access
        served by ``tier`` (training-loop CPU overhead excluded — every
        access charges ``cpu_overhead_s`` after these, on every engine).

        ``probed`` marks a bucket read preceded by a failed peer probe
        (peer tier present but nobody held the key): the probe RTT is
        charged before the GET, exactly the scalar engine's order.
        """
        if tier == "ram":
            return (self.ram_hit_s,)
        if tier == "peer":
            return (self.peer_transfer_s,)
        if tier == "disk-source":
            return (self.disk_get_s,)
        if tier == "bucket":
            if probed:
                return (self.probe_rtt_s, self.bucket_get_s)
            return (self.bucket_get_s,)
        raise ValueError(f"unknown demand tier {tier!r}; expected {DEMAND_TIERS}")

    def bill_demand_gets(self, store_stats, n: int = 1) -> None:
        """Bill ``n`` demand-path Class B GETs (integer counters: ``n``
        batched adds equal ``n`` repeated adds exactly, so the scalar and
        vector engines may call this per-sample or per-segment)."""
        store_stats.class_b_requests += n
        store_stats.bytes_read += n * self.sample_bytes
