"""The vectorized segment engine (ISSUE 6 tentpole).

``VectorNodeEngine`` is a drop-in ``NodeSimulator`` whose epoch stepper
advances *between-interaction segments* — runs of demand reads between
prefetch-round completions, announce points, and batch/epoch barriers — as
batched numpy array ops, while ``lockstep.drive_interleaved_epoch``'s
event heap remains the sole arbiter of cross-node ordering.  Selected by
``SimConfig(engine="vector")`` under the interleaved schedule
(``simulate_cluster`` keeps scalar stepping otherwise).

Exactness (``==``, never tolerances — docs/PARITY.md) rests on three
pillars:

**Same floats.**  Per-sample charge components come from the shared
:class:`repro.engine.kernels.DemandKernel` — the identical precomputed
floats the scalar engine adds one at a time.

**Same accumulation order.**  Every float chain is built with
``np.cumsum``, whose float64 kernel is a strictly *sequential*
left-to-right scan — the same rounding as the scalar ``t += c`` chain.
(``np.sum`` would be pairwise and is never used on floats here.)  A
segment's charge chain lays out exactly the scalar event sequence — tier
charge, CPU overhead, per-sample, with the batch compute interleaved at
gradient boundaries — and running accumulators (data-wait, compute
seconds) are extended by prepending the carried value:
``np.cumsum(np.concatenate(([carry], deltas)))[-1]``.

**Same interaction points.**  A segment never spans a point where the
scalar engine's *state* could change: prefetch completions are folded at
segment boundaries only, and a segment that would straddle a pending
round's completion time is truncated at the first access whose start is
at/past it (the scalar engine folds before every access, so an access
starting before the completion provably cannot observe it).  Announce
points come from the planners' positional ``announce_schedule()``; the
oracle's residency filter — the one lazily-evaluated piece — is applied
at exactly the announce position, against the same cache state.  Cache
*state* itself always lives in the real ``CappedCache``: modes where the
demand path mutates it walk a per-sample loop over real ``get``/``put``
(membership, FIFO/Belady eviction order and ``CacheStats`` evolve
bit-identically); modes where only the prefetch service populates it read
a residency bitmask maintained by the cache's residency listener.

Epochs with a peer-cache registry fall back to inherited scalar stepping:
peer probes are per-sample cross-node interactions — there is no segment
to batch — and the registry also owns the residency-listener slot.  This
also covers cluster placement (``prefetch_policy="cluster-oracle"``): the
spec validation requires a peer cache, so placement epochs always take
the scalar path here and the cross-rank in-flight set never interacts
with vectorized segments — ``engine="vector"`` placement specs stay in
the exact ``==`` parity domain for free.
"""
from __future__ import annotations

from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.lockstep import (
    SENTINEL,
    STEP_BATCH_END,
    STEP_CONTINUE,
)
from repro.core.simulator import NodeSimulator


def _extend(carry: float, deltas: np.ndarray) -> float:
    """Fold ``deltas`` into a running scalar accumulator in strict
    left-to-right order — bit-identical to the scalar engine's repeated
    ``acc += d``."""
    return float(np.cumsum(np.concatenate(([carry], deltas)))[-1])


class VectorNodeEngine(NodeSimulator):
    """``NodeSimulator`` with segment-batched epoch stepping.

    Everything but the stepper is inherited: construction, the shared
    ``DemandKernel``, ``LockstepPrefetchService``, planner construction,
    ``sync_to``/``finish_epoch``/``fold_inserts_until``.  ``begin_epoch``
    swaps the scalar event generator for :meth:`_vector_events` when the
    epoch is batchable (no peer registry, no bucketed overlap).

    Allreduce cost specs (ISSUE 8) vectorize at ``overlap="none"``: the
    barrier's transfer is charged by ``sync_to`` *between* spans (spans
    are cut at gradient boundaries under ``sync="batch"``), so segment
    arithmetic never sees it.  ``overlap="buckets"`` interleaves comm
    charges *inside* the batch's compute (a stateful per-bucket pipeline
    the span chain cannot express), so those epochs keep inherited scalar
    stepping — the same loud-fallback-over-silent-drift policy as the
    peer registry."""

    def begin_epoch(self, epoch: int, order: Sequence[int], node: int = 0) -> None:
        super().begin_epoch(epoch, order, node=node)
        if self.registry is None and self._overlap is None:
            # The scalar generator installed by super() is lazy and
            # side-effect-free until first resumed — safe to discard.
            self._events = self._vector_events(list(order))

    # -- segment arithmetic --------------------------------------------------
    def _span_chain(
        self, pos: int, tier_charges: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """The virtual-time chain of a candidate span of ``m`` consecutive
        accesses starting at epoch position ``pos``: per sample its tier
        charge then the CPU overhead, with the per-batch compute charge
        interleaved at every gradient boundary — the exact scalar event
        sequence, accumulated by one sequential ``cumsum`` from ``self.t``.

        Returns ``(chain, slots)``: ``chain`` has length ``L+1`` with
        ``chain[0] == self.t``; sample ``j`` starts at ``chain[slots[j]]``
        and its access ends (post-CPU, pre-compute) at
        ``chain[slots[j] + 2]``."""
        m = len(tier_charges)
        batch = self.spec.batch_size
        off = np.arange(m)
        # Gradient boundaries completed before each sample / in the span:
        # position q ends a batch when (q+1) % batch == 0.
        be_before = (pos + off) // batch - pos // batch
        slots = 2 * off + be_before
        total_be = (pos + m) // batch - pos // batch
        charges = np.full(2 * m + total_be, self.compute_per_batch_s)
        charges[slots] = tier_charges
        charges[slots + 1] = self.kernel.cpu_overhead_s
        chain = np.cumsum(np.concatenate(([self.t], charges)))
        return chain, slots

    def _commit_span(
        self, pos: int, chain: np.ndarray, slots: np.ndarray, m_c: int
    ) -> int:
        """Advance the clock and per-epoch accumulators past the first
        ``m_c`` samples of the span; returns the new epoch position."""
        stats = self._stats
        assert stats is not None
        starts = chain[slots[:m_c]]
        ends = chain[slots[:m_c] + 2]
        # Per-sample data-wait is end - start: the same single subtraction
        # of the same two floats the scalar engine performs.
        stats.data_wait_seconds = _extend(stats.data_wait_seconds, ends - starts)
        stats.samples += m_c
        batch = self.spec.batch_size
        n_be = (pos + m_c) // batch - pos // batch
        if n_be:
            stats.compute_seconds = _extend(
                stats.compute_seconds, np.full(n_be, self.compute_per_batch_s)
            )
        if m_c == len(slots):
            self.t = float(chain[-1])  # includes a trailing batch compute
        else:
            self.t = float(chain[slots[m_c]])  # start of the first uncommitted
        self._samples_in_batch = (pos + m_c) % batch
        return pos + m_c

    def _trace_span(
        self,
        order: List[int],
        pos: int,
        chain: np.ndarray,
        slots: np.ndarray,
        m_c: int,
        hits: "np.ndarray | None" = None,
        tier: str = "",
    ) -> None:
        """Synthesize the scalar engine's per-sample trace events for the
        committed prefix of one span, straight from the committed chain:
        sample ``j`` starts at ``chain[slots[j]]``, ends (post-CPU) at
        ``chain[slots[j] + 2]``, and a gradient boundary it completes
        charges its compute span from that end — the identical floats the
        scalar ``_access``/``_epoch_events`` pair records, so trace parity
        holds across ``engine="scalar"|"vector"`` (ISSUE 10)."""
        trace = self._trace
        if trace is None or m_c == 0:
            return
        batch = self.spec.batch_size
        # Sub-step granularity decorates each demand event with its ordered
        # component decomposition.  In the vectorizable domain (no peer
        # registry — begin_epoch falls back otherwise) SubstepAccess charges
        # exactly tier-then-cpu per sample, so the components are a pure
        # function of the hit/miss outcome; the cache-less schedules
        # (_build_substep returns None for them) keep undecorated events.
        substep = (
            self.cfg.granularity == "substep"
            and self.cfg.source != "disk"
            and self.cache is not None
        )
        for j in range(m_c):
            t0 = float(chain[slots[j]])
            dur = float(chain[slots[j] + 2] - chain[slots[j]])
            tier_j = tier if hits is None else ("ram" if hits[j] else "bucket")
            attrs = dict(
                idx=int(order[pos + j]),
                tier=tier_j,
                class_b=1 if tier_j == "bucket" else 0,
            )
            if substep:
                attrs["components"] = (
                    (("local", self.kernel.ram_hit_s),
                     ("cpu", self.kernel.cpu_overhead_s))
                    if tier_j == "ram"
                    else (("bucket", self.kernel.bucket_get_s),
                          ("cpu", self.kernel.cpu_overhead_s))
                )
            trace.emit("demand", self.node_id, t0, dur, **attrs)
            if self.compute_per_batch_s and (pos + j + 1) % batch == 0:
                trace.emit(
                    "compute",
                    self.node_id,
                    float(chain[slots[j] + 2]),
                    self.compute_per_batch_s,
                )

    def _span_cut(self, pos: int, n: int) -> int:
        """A span's hard end: the next gradient boundary under the
        per-batch allreduce schedule (the engine must yield
        ``STEP_BATCH_END`` there so the driver can park this node), the
        epoch end otherwise."""
        if self.cfg.sync == "batch":
            batch = self.spec.batch_size
            return min(n, (pos // batch + 1) * batch)
        return n

    def _boundary_signal(self, pos: int, n: int) -> Iterator[int]:
        """Yield the scalar stepper's signal for a commit that ended at
        ``pos``: ``STEP_BATCH_END`` exactly when the last committed event
        completed a gradient batch.  (Intermediate non-boundary commits
        yield nothing — the heap only arbitrates cross-node interactions,
        and a batchable epoch has none between boundaries.)"""
        if self._samples_in_batch == 0:
            yield STEP_BATCH_END
        elif pos == n:
            yield STEP_CONTINUE  # final partial batch: scalar's last signal

    # -- the stepper ---------------------------------------------------------
    def _vector_events(self, order: List[int]) -> Iterator[int]:
        stats = self._stats
        assert stats is not None
        if not order:
            return
        if self.cfg.source == "disk":
            yield from self._constant_tier_events(
                order, "disk-source", self.kernel.disk_get_s
            )
        elif self.cache is None:
            yield from self._constant_tier_events(
                order, "bucket", self.kernel.bucket_get_s
            )
        elif self._insert_on_miss:
            yield from self._cache_demand_events(order)
        else:
            yield from self._prefetch_events(order)

    def _constant_tier_events(
        self, order: List[int], tier: str, charge_s: float
    ) -> Iterator[int]:
        """Disk-source / direct-from-bucket baselines: every access is
        served by one tier at one constant charge; no cache state exists,
        so whole inter-barrier spans vectorize unconditionally."""
        stats = self._stats
        assert stats is not None
        n = len(order)
        pos = 0
        while pos < n:
            end = self._span_cut(pos, n)
            m = end - pos
            chain, slots = self._span_chain(pos, np.full(m, charge_s))
            stats.record(tier, m)
            if tier == "bucket":
                self.kernel.bill_demand_gets(self.store_stats, m)
            self._trace_span(order, pos, chain, slots, m, tier=tier)
            pos = self._commit_span(pos, chain, slots, m)
            yield from self._boundary_signal(pos, n)

    def _cache_demand_events(self, order: List[int]) -> Iterator[int]:
        """Demand-populated cache (no active prefetch service, FIFO or
        Belady eviction): membership evolves on every access, so tier
        decisions walk a tight per-sample loop over the REAL cache —
        ``get``/``put`` evolve membership, eviction order, the clairvoyant
        cursor and ``CacheStats`` bit-identically to the scalar engine —
        and all *float* arithmetic batches over the resulting hit mask."""
        stats = self._stats
        assert stats is not None
        cache = self.cache
        assert cache is not None
        view = self.oracle_view
        get, put = cache.get, cache.put
        # The cache walk runs *before* the span's time chain exists, so the
        # tracer buffers insert/evict rows (capture mode) and flushes each
        # sample's rows at its chain-derived insert time — the post-tier-
        # charge instant where the scalar engine's ``put`` fires them.
        tracer = self._cache_tracer
        n = len(order)
        pos = 0
        while pos < n:
            end = self._span_cut(pos, n)
            seg = order[pos:end]
            hits = np.empty(len(seg), dtype=bool)
            marks: List[int] = []
            buf = tracer.begin_capture() if tracer is not None else None
            for j, idx in enumerate(seg):
                if view is not None:
                    # Cursor advances at access start (the scalar engine's
                    # mirrored line): a just-consumed key competes for
                    # cache space on its NEXT occurrence.
                    view.on_consume(idx)
                hit = get(idx) is not None
                if not hit:
                    put(idx, SENTINEL)  # paper §IV-B: worker inserts on miss
                hits[j] = hit
                if buf is not None:
                    marks.append(len(buf))
            n_ram = int(np.count_nonzero(hits))
            n_bucket = len(seg) - n_ram
            if n_ram:
                stats.record("ram", n_ram)
            if n_bucket:
                stats.record("bucket", n_bucket)
                self.kernel.bill_demand_gets(self.store_stats, n_bucket)
            chain, slots = self._span_chain(
                pos,
                np.where(hits, self.kernel.ram_hit_s, self.kernel.bucket_get_s),
            )
            if tracer is not None:
                ops = tracer.end_capture()
                lo = 0
                for j, hi in enumerate(marks):
                    if hi > lo:
                        tracer.flush(ops[lo:hi], float(chain[slots[j] + 1]))
                    lo = hi
            self._trace_span(order, pos, chain, slots, len(seg), hits=hits)
            pos = self._commit_span(pos, chain, slots, len(seg))
            yield from self._boundary_signal(pos, n)

    def _prefetch_events(self, order: List[int]) -> Iterator[int]:
        """Prefetch-populated cache (paper or oracle planner;
        ``insert_on_miss`` is off): demand reads never mutate the cache,
        so within a segment residency is frozen — a numpy bitmask, kept
        current by the cache's residency listener (free here: the listener
        slot is only otherwise used by the peer registry, which forces the
        scalar fallback).  Segments end at announce points, gradient
        boundaries (``sync="batch"``), and epoch end — and are truncated
        at the first access starting at/past the earliest pending round
        completion, the point where the scalar engine's fold-before-access
        could first change an outcome."""
        stats = self._stats
        assert stats is not None
        cache, service, planner = self.cache, self.service, self._planner
        assert cache is not None and service is not None and planner is not None
        view = self.oracle_view
        n = len(order)
        # Positional announce points (both planners); only the oracle's
        # residency filter is stateful, applied below at each point.
        schedule = planner.announce_schedule()
        filter_chunk = getattr(planner, "filter_chunk", None)
        si = 0
        mask = np.zeros(self.spec.n_samples, dtype=bool)
        mask[cache.keys()] = True

        def on_insert(i: int) -> None:
            mask[i] = True

        def on_evict(i: int) -> None:
            mask[i] = False

        cache.set_residency_listener(on_insert, on_evict)
        order_arr = np.asarray(order, dtype=np.int64)
        try:
            pos = 0
            while pos < n:
                # Boundary: fold completions <= now (the driver's fold_all
                # plus the access-start fold, both at cursor == pos), then
                # announce any round due at this position — filter (oracle)
                # and issue exactly as the scalar planner/stepper would.
                service.advance_to(self.t)
                while si < len(schedule) and schedule[si][0] == pos:
                    chunk = schedule[si][1]
                    si += 1
                    kept = list(chunk) if filter_chunk is None else filter_chunk(chunk)
                    if kept:
                        planner.rounds_issued += 1
                        service.issue(kept, now=self.t, stats=stats)
                end = self._span_cut(pos, n)
                if si < len(schedule):
                    end = min(end, schedule[si][0])
                hits = mask[order_arr[pos:end]]
                chain, slots = self._span_chain(
                    pos,
                    np.where(hits, self.kernel.ram_hit_s, self.kernel.bucket_get_s),
                )
                m_c = end - pos
                if service.pending:
                    # Truncate at the first access whose start is at/past
                    # the earliest pending completion: the scalar engine
                    # folds before every access, so that access (and none
                    # earlier) could observe the round.
                    next_done = min(done for done, _ in service.pending)
                    m_c = int(
                        np.searchsorted(chain[slots], next_done, side="left")
                    )
                    if m_c == 0:
                        continue  # a round completed exactly now: fold first
                    m_c = min(m_c, end - pos)
                committed = hits[:m_c]
                n_ram = int(np.count_nonzero(committed))
                n_bucket = m_c - n_ram
                if view is not None:
                    view.on_consume_many(m_c)
                if n_ram:
                    stats.record("ram", n_ram)
                    cache.stats.hits += n_ram  # mirror of per-access get()
                    cache.stats.ram_hits += n_ram
                if n_bucket:
                    stats.record("bucket", n_bucket)
                    cache.stats.misses += n_bucket
                    self.kernel.bill_demand_gets(self.store_stats, n_bucket)
                # Demand inserts never happen here (the service owns cache
                # population), so only demand/compute spans need synthesis;
                # insert/evict/issue/advance events come from the shared
                # service code this path already calls.
                self._trace_span(order, pos, chain, slots, m_c, hits=hits)
                pos = self._commit_span(pos, chain, slots, m_c)
                yield from self._boundary_signal(pos, n)
        finally:
            cache.set_residency_listener(None, None)
