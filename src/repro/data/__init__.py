from repro.data.synthetic import (
    decode_tokens,
    make_lm_payloads,
    make_lm_pipeline,
)
