from repro.data.synthetic import (
    decode_tokens,
    lm_payload_factory,
    lm_workload,
    make_lm_payloads,
    make_lm_pipeline,
    make_lm_spec,
)
