"""Synthetic LM dataset + the standard DELI pipeline assembly.

One "sample" (bucket object) = one packed int32 token sequence of
``seq_len + 1`` tokens (inputs + shifted labels), which mirrors how
pre-training shards store sequences as objects.  ``make_lm_pipeline``
wires store -> cache -> pre-fetch service -> DeliLoader exactly like the
paper's Fig. 1 and is what the examples and the trainer use.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.cache import CappedCache
from repro.core.clock import Clock, RealClock
from repro.core.dataset import CachingDataset
from repro.core.loader import DeliLoader
from repro.core.policy import PrefetchConfig
from repro.core.prefetcher import PrefetchService
from repro.core.sampler import DistributedPartitionSampler
from repro.core.store import SampleStore, SimulatedBucketStore
from repro.core.bandwidth import BucketModel


def make_lm_payloads(
    n_samples: int, seq_len: int, vocab: int, seed: int = 0
) -> Dict[int, bytes]:
    """Markov-ish synthetic token streams (so the loss actually falls)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=(n_samples, seq_len + 1), dtype=np.int32)
    # inject learnable structure: every odd position repeats its predecessor
    base[:, 1::2] = base[:, 0:-1:2]
    return {i: base[i].tobytes() for i in range(n_samples)}


def decode_tokens(payload: bytes) -> np.ndarray:
    return np.frombuffer(payload, dtype=np.int32)


def make_lm_pipeline(
    *,
    n_samples: int,
    seq_len: int,
    vocab: int,
    batch_size: int,
    cache_items: int = 2048,
    rank: int = 0,
    world: int = 1,
    policy: Optional[PrefetchConfig] = None,
    store: Optional[SampleStore] = None,
    bucket_model: Optional[BucketModel] = None,
    clock: Optional[Clock] = None,
    seed: int = 0,
) -> Tuple[DeliLoader, PrefetchService, CachingDataset]:
    """The paper's node pipeline over a simulated bucket.

    Returns (loader, service, dataset); callers ``service.start()`` / use the
    loader as a context-free iterator, and must ``service.close()`` at exit.
    The default policy is the paper's 50/50 for the given cache size.
    """
    payloads = make_lm_payloads(n_samples, seq_len, vocab, seed)
    clock = clock or RealClock()
    if store is None:
        # fast-forwarded bucket: Table-I ratios at 1/1000 wall time
        model = bucket_model or BucketModel(
            request_latency_s=0.020e-3, per_connection_bw=20e9,
            listing_latency_s=0.050e-3,
        )
        store = SimulatedBucketStore(payloads, model=model, clock=clock)
    policy = policy or PrefetchConfig.fifty_fifty(cache_items)
    cache = CappedCache(max_items=cache_items)
    dataset = CachingDataset(store, cache, insert_on_miss=policy.enabled is False)
    service = PrefetchService(store=store, cache=cache, n_connections=16, clock=clock)
    sampler = DistributedPartitionSampler(n_samples, rank=rank, world=world, seed=seed)
    loader = DeliLoader(
        dataset, sampler, batch_size=batch_size, config=policy,
        service=service, clock=clock, node=rank,
    )
    return loader, service, dataset
