"""Synthetic LM dataset + the standard DELI pipeline assembly.

One "sample" (bucket object) = one packed int32 token sequence of
``seq_len + 1`` tokens (inputs + shifted labels), which mirrors how
pre-training shards store sequences as objects.

Since ISSUE 4 the LM pipeline is a **named DataPlaneSpec condition**
(``repro.pipeline.condition("lm", workload, seq_len=..., vocab=...)``)
rather than a bespoke constructor: ``make_lm_spec`` builds the declarative
description (workload shape + ``payload_factory`` + fast-forwarded bucket
model + 50/50 policy) and both the trainer (``repro.launch.train``) and the
training-loop tests assemble their node pipelines through
``spec.build_runtime(...)`` like every other condition.  The historical
``make_lm_pipeline`` survives as a thin shim over that path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.bandwidth import BucketModel
from repro.core.cache import CappedCache
from repro.core.clock import Clock, RealClock
from repro.core.dataset import CachingDataset
from repro.core.loader import DeliLoader
from repro.core.policy import PrefetchConfig
from repro.core.prefetcher import PrefetchService
from repro.core.store import SampleStore
from repro.core.workloads import WorkloadSpec

#: The historical make_lm_pipeline bucket: Table-I ratios at 1/1000 wall
#: time, so threaded LM runs finish in test time.
FAST_FORWARD_BUCKET = BucketModel(
    request_latency_s=0.020e-3, per_connection_bw=20e9, listing_latency_s=0.050e-3
)


def make_lm_payloads(
    n_samples: int, seq_len: int, vocab: int, seed: int = 0
) -> Dict[int, bytes]:
    """Markov-ish synthetic token streams (so the loss actually falls)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=(n_samples, seq_len + 1), dtype=np.int32)
    # inject learnable structure: every odd position repeats its predecessor
    base[:, 1::2] = base[:, 0:-1:2]
    return {i: base[i].tobytes() for i in range(n_samples)}


def decode_tokens(payload: bytes) -> np.ndarray:
    return np.frombuffer(payload, dtype=np.int32)


def lm_workload(
    n_samples: int, seq_len: int, batch_size: int, world: int = 1
) -> WorkloadSpec:
    """The LM shard as a pipeline workload: one sample = one packed
    ``seq_len + 1``-token int32 sequence (inputs + shifted labels).
    Compute is 0 here — the trainer's real step time drives the clock on
    the free-running path."""
    return WorkloadSpec(
        name="lm-synthetic",
        n_samples=n_samples,
        sample_bytes=(seq_len + 1) * 4,
        batch_size=batch_size,
        compute_per_epoch_s=0.0,
        n_nodes=world,
    )


def lm_payload_factory(seq_len: int, vocab: int):
    """A ``DataPlaneSpec.payload_factory`` producing the synthetic token
    payloads (seeded by the spec, sized by its workload)."""

    def factory(spec) -> Dict[int, bytes]:
        return make_lm_payloads(
            spec.workload.n_samples, seq_len, vocab, seed=spec.seed
        )

    return factory


def make_lm_spec(
    *,
    n_samples: int,
    seq_len: int,
    vocab: int,
    batch_size: int,
    cache_items: int = 2048,
    world: int = 1,
    policy: Optional[PrefetchConfig] = None,
    bucket_model: Optional[BucketModel] = None,
    seed: int = 0,
):
    """The LM pipeline as a declarative ``DataPlaneSpec`` (ROADMAP item:
    fold ``make_lm_pipeline`` into the spec layer).

    Defaults match the historical constructor: fast-forwarded bucket
    timing, the paper's 50/50 policy for the given cache size, partition
    sampler.  Build a node pipeline with ``spec.build_runtime(clock=
    RealClock())`` (free-running, the trainer's mode) or drive the
    lock-step/simulator projections like any other condition.
    """
    from repro.pipeline.spec import DataPlaneSpec  # lazy: pipeline imports core

    return DataPlaneSpec(
        workload=lm_workload(n_samples, seq_len, batch_size, world),
        cache_items=cache_items,
        prefetch=policy if policy is not None else PrefetchConfig.fifty_fifty(cache_items),
        bucket=bucket_model or FAST_FORWARD_BUCKET,
        payload_factory=lm_payload_factory(seq_len, vocab),
        seed=seed,
    )


def make_lm_pipeline(
    *,
    n_samples: int,
    seq_len: int,
    vocab: int,
    batch_size: int,
    cache_items: int = 2048,
    rank: int = 0,
    world: int = 1,
    policy: Optional[PrefetchConfig] = None,
    store: Optional[SampleStore] = None,
    bucket_model: Optional[BucketModel] = None,
    clock: Optional[Clock] = None,
    seed: int = 0,
) -> Tuple[DeliLoader, PrefetchService, CachingDataset]:
    """Legacy shim over :func:`make_lm_spec` + ``build_runtime``.

    Returns rank's ``(loader, service, dataset)`` from the spec-built
    cluster; callers ``service.start()`` / use the loader as a
    context-free iterator, and must ``service.close()`` at exit — exactly
    the historical contract.  Passing ``store`` keeps the fully manual
    assembly (a spec cannot adopt a foreign store object).
    """
    clock = clock or RealClock()
    policy = policy or PrefetchConfig.fifty_fifty(cache_items)
    if store is not None:
        # Manual-store path: the pre-spec wiring, preserved verbatim.
        cache = CappedCache(max_items=cache_items)
        dataset = CachingDataset(store, cache, insert_on_miss=policy.enabled is False)
        service = PrefetchService(store=store, cache=cache, n_connections=16, clock=clock)
        from repro.core.sampler import DistributedPartitionSampler

        sampler = DistributedPartitionSampler(n_samples, rank=rank, world=world, seed=seed)
        loader = DeliLoader(
            dataset, sampler, batch_size=batch_size, config=policy,
            service=service, clock=clock, node=rank,
        )
        return loader, service, dataset
    spec = make_lm_spec(
        n_samples=n_samples,
        seq_len=seq_len,
        vocab=vocab,
        batch_size=batch_size,
        cache_items=cache_items,
        world=world,
        policy=policy,
        bucket_model=bucket_model,
        seed=seed,
    )
    cluster = spec.build_runtime(clock=clock)
    loader = cluster.loaders[rank]
    service = cluster.services[rank]
    if service is None:  # disabled policy: idle service for `with service:`
        service = PrefetchService(
            store=loader.dataset.store, cache=cluster.caches[rank], clock=clock
        )
        loader.service = service
    return loader, service, loader.dataset
