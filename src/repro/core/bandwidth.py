"""Storage bandwidth/latency models, calibrated to the paper's Table I.

Table I (3-node GCE VMs, us-east-1c, reading MNIST into memory):

    Disk                          18.63 MB/s   (many small files)
    Object storage, sequential    49.80 kB/s
    Object storage, 16 threads   281.73 kB/s   (= 5.66x sequential)

Model
-----
A bucket GET of ``size`` bytes costs

    t = request_latency + size / per_connection_bw

For MNIST-sized samples (784 B) the latency term dominates, which is exactly
why the paper observes kB/s-scale throughput.  Calibration:

  * sequential 49.8 kB/s on 784 B objects  =>  request_latency ~= 15.7 ms
    (784 B / 49.8 kB/s = 15.74 ms; the streaming term at 20 MB/s adds 39 us).
  * 16 threads give only 5.66x, not 16x (2 vCPUs, GIL, TCP setup): we model
    sub-linear parallel scaling  eff(n) = n ** alpha  with
    alpha = ln(5.66)/ln(16) ~= 0.626.
  * Disk at 18.63 MB/s is a pure-bandwidth regime for the small-file read
    pattern Table I measures (seek cost folded into the effective rate).

The paper measures *data loading time* at the training loop, which includes
per-sample CPU work (decode/collate).  We model that as ``cpu_overhead`` per
sample; it is what keeps the measured disk-vs-bucket gap at the paper's
8-16x rather than the raw 374x bandwidth ratio (§V-B discussion).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class BucketModel:
    """Simulated GCS bucket performance model."""

    request_latency_s: float = 784 / 49.80e3 - 784 / 20e6  # ~15.7 ms (Table I)
    per_connection_bw: float = 20e6  # bytes/s once a GET is streaming
    parallel_alpha: float = math.log(281.73 / 49.80) / math.log(16.0)  # ~0.626
    max_connections: int = 16
    # Listing (Class A) requests: latency per page.
    listing_latency_s: float = 0.050
    page_size: int = 1000

    def get_seconds(self, size_bytes: int) -> float:
        """Duration of a single sequential GET."""
        return self.request_latency_s + size_bytes / self.per_connection_bw

    def parallel_efficiency(self, n_connections: int) -> float:
        """Effective speedup of ``n`` concurrent GETs over sequential."""
        n = max(1, min(n_connections, self.max_connections))
        return float(n) ** self.parallel_alpha

    def bulk_get_seconds(self, sizes: list, n_connections: int = 16) -> float:
        """Duration of fetching ``len(sizes)`` objects over a thread pool.

        Total sequential work divided by the calibrated parallel efficiency
        (processor-sharing approximation of a thread pool on a small VM).
        """
        if not sizes:
            return 0.0
        seq = 0.0
        for s in sizes:
            seq += self.get_seconds(s)
        return seq / self.parallel_efficiency(n_connections)

    def list_seconds(self, n_objects: int) -> float:
        pages = max(1, math.ceil(n_objects / self.page_size))
        return pages * self.listing_latency_s

    def sequential_throughput(self, sample_bytes: int) -> float:
        """bytes/s — should reproduce Table I's 49.8 kB/s at ~1 kB objects."""
        return sample_bytes / self.get_seconds(sample_bytes)

    def parallel_throughput(self, sample_bytes: int, n: int = 16) -> float:
        return self.sequential_throughput(sample_bytes) * self.parallel_efficiency(n)


@dataclasses.dataclass(frozen=True)
class DiskModel:
    """Local persistent-disk model (Table I's small-file read regime)."""

    effective_bw: float = 18.63e6  # bytes/s
    seek_latency_s: float = 0.0  # folded into effective_bw per Table I

    def get_seconds(self, size_bytes: int) -> float:
        return self.seek_latency_s + size_bytes / self.effective_bw


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Inter-node (intra-cluster) network model for the cooperative peer
    cache tier (Hoard/NoPFS direction: nodes serve each other's misses).

    Defaults model the GCE VM-to-VM path in one zone: ~0.2 ms RTT and a
    ~10 Gbit/s per-flow ceiling.  For MNIST-sized samples the round trip
    dominates (~0.2 ms vs ~15.7 ms for a bucket GET) — a peer hit is two
    orders of magnitude cheaper than the Class B fallback, which is the
    entire premise of the tier.
    """

    rtt_s: float = 0.2e-3  # request/response round trip, same-zone VMs
    bw: float = 1.25e9  # bytes/s (~10 Gbit/s per flow)

    def transfer_seconds(self, size_bytes: int) -> float:
        """Duration of fetching one object from a peer's cache."""
        return self.rtt_s + size_bytes / self.bw

    def lookup_seconds(self) -> float:
        """A metadata-only peer lookup that misses (half a round trip is
        pipelined with the fallback GET; we charge the full RTT to stay
        conservative)."""
        return self.rtt_s

    def stream_seconds(self, size_bytes: int) -> float:
        """Payload streaming time *after* the probe round trip — the second
        half of ``transfer_seconds`` when the sub-step schedule charges the
        probe RTT (``lookup_seconds``) as its own event first."""
        return size_bytes / self.bw


@dataclasses.dataclass(frozen=True)
class CollectiveModel:
    """Gradient-allreduce cost model on top of the calibrated intra-cluster
    ``NetworkModel``.

    The per-batch barrier (``sync="batch"``) historically released ranks
    instantaneously once all arrived — skew was modeled, transfer was not.
    This model gives the allreduce a duration so blocked time splits into
    ``allreduce_wait_seconds`` (skew: waiting for stragglers to arrive) and
    ``allreduce_comm_seconds`` (transfer: moving gradient bytes).

    Two standard algorithms over ``n`` ranks exchanging ``gradient_bytes``:

      * ``"ring"`` — bandwidth-optimal reduce-scatter + all-gather:
        ``2(n-1)`` steps, each moving ``bytes/n`` over the per-flow link
        and paying one RTT of synchronization latency.
      * ``"tree"`` — latency-favoring reduce + broadcast:
        ``2*ceil(log2 n)`` rounds, each moving the full buffer once.

    Both are lower-bounded by the textbook ``2(n-1)/n * bytes / bw``
    (every rank must receive all but its own shard, twice).

    ``n_buckets`` decomposes the gradient for ``overlap="buckets"``: each
    bucket's allreduce costs exactly ``allreduce_seconds(...)/n_buckets``
    (latency amortized across the pipelined bucket stream — the olmax-style
    bucketed step this models issues them back-to-back on one channel), so
    bucketed total comm equals the unbucketed duration and overlap can only
    hide, never add, time.

    ``gradient_bytes=0`` is the free-allreduce limit: every duration is
    exactly 0.0, which must reproduce the historical instantaneous-barrier
    timeline bit-for-bit (the accounting-split bugfix's pin).
    """

    gradient_bytes: int
    algorithm: str = "ring"
    n_buckets: int = 4

    def __post_init__(self) -> None:
        if self.gradient_bytes < 0:
            raise ValueError("gradient_bytes must be >= 0")
        if self.algorithm not in ("ring", "tree"):
            raise ValueError(f"unknown collective algorithm {self.algorithm!r}")
        if self.n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")

    def allreduce_seconds(self, network: NetworkModel, n_ranks: int) -> float:
        """Duration of one full-gradient allreduce across ``n_ranks``."""
        if n_ranks <= 1 or self.gradient_bytes == 0:
            return 0.0
        if self.algorithm == "ring":
            steps = 2 * (n_ranks - 1)
            return steps * (network.rtt_s + (self.gradient_bytes / n_ranks) / network.bw)
        rounds = 2 * math.ceil(math.log2(n_ranks))
        return rounds * (network.rtt_s + self.gradient_bytes / network.bw)

    def bucket_seconds(self, network: NetworkModel, n_ranks: int) -> float:
        """Duration of one gradient bucket's allreduce (exact 1/n_buckets
        partition of the full duration — see class docstring)."""
        return self.allreduce_seconds(network, n_ranks) / self.n_buckets

    def ring_lower_bound_seconds(self, network: NetworkModel, n_ranks: int) -> float:
        """The algorithm-independent bandwidth lower bound
        ``2(n-1)/n * bytes / bw`` — both algorithms cost at least this."""
        if n_ranks <= 1 or self.gradient_bytes == 0:
            return 0.0
        return 2 * (n_ranks - 1) / n_ranks * self.gradient_bytes / network.bw


def mnist_cnn_gradient_bytes() -> int:
    """Gradient payload of the paper's 2-conv MNIST CNN, in fp32 bytes.

    conv1: 32 filters x (1 ch x 5x5 + bias)      =     832 params
    conv2: 64 filters x (32 ch x 5x5 + bias)     =  51,264 params
    fc1:   3136 -> 128 (+bias)                   = 401,536 params
    fc2:   128 -> 10 (+bias)                     =   1,290 params
    """
    conv1 = 32 * (1 * 25 + 1)
    conv2 = 64 * (32 * 25 + 1)
    fc1 = 3136 * 128 + 128
    fc2 = 128 * 10 + 10
    return 4 * (conv1 + conv2 + fc1 + fc2)


def arch_gradient_bytes(name: str) -> int:
    """fp32 gradient payload for one of the assigned arch configs
    (``repro.configs``).  Imported lazily: the configs package pulls in
    jax, which the pure-Python data plane must not require."""
    from repro import configs

    return 4 * configs.get(name).param_count()


@dataclasses.dataclass(frozen=True)
class PipelineCostModel:
    """Per-sample CPU-side cost of the data pipeline (decode + collate).

    Calibrated so the measured disk/bucket data-wait ratio lands in the
    paper's 8-16x band for MNIST-sized samples (see module docstring).
    """

    cpu_overhead_s: float = 1.3e-3
    # RAM-tier cache hit (the explicit analogue of MongoDB/WiredTiger's
    # in-memory cache the paper credits for beating the disk baseline).
    ram_hit_s: float = 0.05e-3
    # Disk-tier cache hit: one small read from the local cache spill.
    disk_hit_s: float = 0.4e-3


@dataclasses.dataclass(frozen=True)
class NodeProfile:
    """Per-node heterogeneity: multiplicative *time* scales (straggler knobs).

    The paper's 3-VM cluster is homogeneous, but real data-parallel jobs are
    not: NoPFS's per-step I/O traces show stragglers dominating distributed
    training I/O, and the per-batch allreduce schedule exists precisely to
    model them.  A profile slows one node down deterministically:

      * ``compute``   — multiplies CPU-side times (per-batch compute, the
        per-sample decode/collate overhead, and cache-hit service times);
      * ``bandwidth`` — multiplies I/O times (bucket GET latency and
        streaming, disk reads, inter-node network RTT and streaming).

    1.0 = the calibrated baseline; 2.0 = twice as slow.  Scaling is applied
    by *rebuilding the calibrated models* (``scale_bucket`` etc.), so both
    execution projections evaluate the identical scaled floats and exact
    parity holds for straggler specs too.  Multiplying by 1.0 is a bitwise
    no-op for IEEE-754 finite values, so default profiles leave every
    existing timeline bit-for-bit unchanged.
    """

    compute: float = 1.0
    bandwidth: float = 1.0

    def __post_init__(self) -> None:
        if self.compute <= 0 or self.bandwidth <= 0:
            raise ValueError("NodeProfile multipliers must be positive")

    def scale_bucket(self, model: BucketModel) -> BucketModel:
        b = self.bandwidth
        return dataclasses.replace(
            model,
            request_latency_s=model.request_latency_s * b,
            per_connection_bw=model.per_connection_bw / b,
            listing_latency_s=model.listing_latency_s * b,
        )

    def scale_disk(self, model: DiskModel) -> DiskModel:
        b = self.bandwidth
        return dataclasses.replace(
            model,
            effective_bw=model.effective_bw / b,
            seek_latency_s=model.seek_latency_s * b,
        )

    def scale_network(self, model: NetworkModel) -> NetworkModel:
        b = self.bandwidth
        return dataclasses.replace(model, rtt_s=model.rtt_s * b, bw=model.bw / b)

    def scale_pipeline(self, model: PipelineCostModel) -> PipelineCostModel:
        c = self.compute
        return dataclasses.replace(
            model,
            cpu_overhead_s=model.cpu_overhead_s * c,
            ram_hit_s=model.ram_hit_s * c,
            disk_hit_s=model.disk_hit_s * c,
        )

    def batch_compute_s(self, compute_per_batch_s: float) -> float:
        """This node's per-batch compute time (straggler-scaled)."""
        return compute_per_batch_s * self.compute


DEFAULT_PROFILE = NodeProfile()


def straggler_profiles(
    n_nodes: int,
    slow_ranks: tuple = (0,),
    compute: float = 2.0,
    bandwidth: float = 2.0,
) -> tuple:
    """A cluster profile with ``slow_ranks`` slowed by the given factors —
    the canonical straggler scenario (``pipeline.registry`` condition
    ``"straggler"``, ``benchmarks/fig11_stragglers.py``)."""
    return tuple(
        NodeProfile(compute=compute, bandwidth=bandwidth)
        if rank in slow_ranks
        else NodeProfile()
        for rank in range(n_nodes)
    )


DEFAULT_BUCKET = BucketModel()
DEFAULT_DISK = DiskModel()
DEFAULT_PIPELINE = PipelineCostModel()
DEFAULT_NETWORK = NetworkModel()
