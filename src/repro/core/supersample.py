"""Super-samples (beyond-paper, §VI): pack multiple samples per bucket object.

Groups ``group_size`` consecutive samples into one object with a trivial
length-prefixed framing.  Effects:

  * Class B requests / epoch drop by ~group_size (cost Eq. 3 term);
  * per-request latency (the dominant term for kB-scale samples — see
    bandwidth.py) is amortized: effective sequential throughput rises from
    size/(L + size/B) to G*size/(L + G*size/B);
  * the partitioner must deal in groups so a node never downloads an object
    to use only part of it ("the partitioning strategy would need to be
    altered to account for them", §VI) — ``GroupedPartitionSampler`` below
    permutes groups, not samples (shuffle granularity trade-off recorded in
    DESIGN.md).
"""
from __future__ import annotations

import struct
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.sampler import Sampler

_HDR = struct.Struct("<I")


def pack_supersample(payloads: Sequence[bytes]) -> bytes:
    parts = [_HDR.pack(len(payloads))]
    for p in payloads:
        parts.append(_HDR.pack(len(p)))
        parts.append(p)
    return b"".join(parts)


def unpack_supersample(blob: bytes) -> List[bytes]:
    (n,) = _HDR.unpack_from(blob, 0)
    off = _HDR.size
    out = []
    for _ in range(n):
        (ln,) = _HDR.unpack_from(blob, off)
        off += _HDR.size
        out.append(blob[off : off + ln])
        off += ln
    if off != len(blob):
        raise ValueError("trailing bytes in super-sample")
    return out


def build_supersample_store_payloads(
    payloads: Dict[int, bytes], group_size: int
) -> Tuple[Dict[int, bytes], Dict[int, Tuple[int, int]]]:
    """Pack per-sample payloads into grouped objects.

    Returns (group_payloads, sample_to_group): group object ``g`` holds
    samples [g*G, (g+1)*G); sample_to_group maps sample idx -> (group idx,
    offset within group).
    """
    if group_size < 1:
        raise ValueError("group_size must be >= 1")
    indices = sorted(payloads)
    groups: Dict[int, bytes] = {}
    mapping: Dict[int, Tuple[int, int]] = {}
    for gstart in range(0, len(indices), group_size):
        members = indices[gstart : gstart + group_size]
        g = gstart // group_size
        groups[g] = pack_supersample([payloads[i] for i in members])
        for off, i in enumerate(members):
            mapping[i] = (g, off)
    return groups, mapping


class GroupedPartitionSampler(Sampler):
    """Distributed partitioner over super-sample groups.

    Yields *group* indices: a random permutation of groups each epoch,
    strided across nodes — so each GET is fully consumed by its node.
    """

    def __init__(self, n_groups: int, rank: int, world: int, seed: int = 0):
        super().__init__(n_groups)
        self.rank = rank
        self.world = world
        self.seed = seed

    @property
    def partition_size(self) -> int:
        return self.n_samples // self.world

    def indices(self) -> List[int]:
        perm = np.random.default_rng((self.seed, self.epoch)).permutation(self.n_samples)
        usable = self.partition_size * self.world
        return perm[:usable][self.rank :: self.world].tolist()

    def __len__(self) -> int:
        return self.partition_size
