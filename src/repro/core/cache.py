"""Capped FIFO sample cache — the explicit analogue of the paper's MongoDB
capped collection (§IV-B).

Semantics copied from the paper:

  * entries are keyed by (session id, dataset index) — the "multi-key index";
  * capacity-limited; on overflow the *oldest inserted* entries are evicted
    (FIFO, exactly a capped collection);
  * lookups by key; inserts are idempotent (re-inserting refreshes nothing —
    FIFO order is insertion order, like capped collections).

Beyond the paper we make MongoDB's hidden RAM tier explicit: a ``ram_items``
budget worth of the most recently inserted entries stays in memory; the
remainder lives in an optional on-disk spill directory.  The paper observed
its 50/50 speedups partly came from WiredTiger holding the working set in
RAM (§V-D/§VI); with an explicit tier we can *measure* that effect
(``EpochStats.ram_hits``) instead of inheriting it silently.

Capacity may be expressed in items (as the paper's experiments do: cache
sizes are sample counts) or bytes (production: disks are sized in bytes).

Eviction is a pluggable **policy object** (ISSUE 5): the capped-collection
FIFO order above is ``FifoEviction``, the default; the oracle subsystem
(``repro.oracle``) provides ``BeladyEviction`` — farthest-future-use, the
provably optimal offline policy — built on the clairvoyant access order a
seeded sampler exposes.  The replication-aware ``eviction_guard`` composes
with *any* policy: guarded entries are skipped and capacity always wins
when everything is guarded.
"""
from __future__ import annotations

import collections
import os
import threading
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.types import SampleKey


class EvictionPolicy:
    """Strategy that picks which cached entry to evict.

    ``select_victim`` receives the cache's entries **in FIFO (insertion)
    order** plus the optional replication-aware guard, and returns
    ``(victim_key, guard_skips)`` — the entry to evict and how many guarded
    entries the guard *actually redirected away from* (the ``guard_skips``
    accounting ``CacheStats`` has always kept).  Policies must be
    deterministic pure functions of their inputs: both execution
    projections evaluate them against identical cache states, which is what
    keeps policy-driven eviction inside the exact-parity domain
    (docs/PARITY.md).  Called under the cache lock — must not call back
    into the cache.
    """

    name = "policy"

    def select_victim(
        self,
        entries: Iterable[SampleKey],
        guard: Optional[Callable[[int], bool]],
    ) -> Tuple[SampleKey, int]:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class FifoEviction(EvictionPolicy):
    """The paper's capped-collection order: evict the oldest insert.

    Byte-for-byte the pre-ISSUE-5 ``CappedCache`` behaviour, as a policy
    object: oldest *unguarded* entry first (early-stopping scan, so the
    typical probe count is 1); plain FIFO fallback — with no skips counted
    — when every entry is guarded, so capacity bounds always hold.
    """

    name = "fifo"

    def select_victim(
        self,
        entries: Iterable[SampleKey],
        guard: Optional[Callable[[int], bool]],
    ) -> Tuple[SampleKey, int]:
        first: Optional[SampleKey] = None
        skipped = 0
        for key in entries:
            if first is None:
                first = key
            if guard is None or not guard(key.index):
                return key, skipped
            skipped += 1
        assert first is not None, "select_victim called on an empty cache"
        return first, 0  # everything guarded: capacity wins, no redirect


class CacheStats:
    __slots__ = (
        "hits",
        "misses",
        "inserts",
        "evictions",
        "ram_hits",
        "disk_hits",
        "guard_skips",
    )

    def __init__(self) -> None:
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0
        self.ram_hits = 0
        self.disk_hits = 0
        # Entries the eviction guard protected during evictions that DID
        # find another victim (how often Hoard-style last-copy protection
        # actually changed an outcome; all-protected FIFO fallbacks add 0).
        self.guard_skips = 0

    def as_dict(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in self.__slots__}


class CappedCache:
    """Thread-safe capped FIFO cache with an explicit RAM tier.

    ``max_items``/``max_bytes``: either or both; ``None`` = unlimited (the
    paper's "unlimited cache" baseline).  ``ram_items`` bounds the in-memory
    tier; entries beyond it are transparently spilled to ``spill_dir`` (if
    given) or kept in RAM anyway (pure-RAM mode, used by the simulator where
    payloads are sizes, not bytes).  ``eviction_policy`` selects victims
    (default: ``FifoEviction``, the capped-collection order).
    ``spill_order`` selects *which* RAM payloads spill when ``ram_items``
    overflows (default ``None`` = oldest inserts, the historical FIFO slice
    pinned byte-for-byte; ``repro.oracle.OracleSpillOrder`` spills
    farthest-future-use keys first).
    """

    def __init__(
        self,
        max_items: Optional[int] = None,
        max_bytes: Optional[int] = None,
        ram_items: Optional[int] = None,
        spill_dir: Optional[str] = None,
        session: str = "default",
        eviction_policy: Optional[EvictionPolicy] = None,
        spill_order=None,
    ):
        if max_items is not None and max_items <= 0:
            raise ValueError("max_items must be positive or None")
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError("max_bytes must be positive or None")
        self.max_items = max_items
        self.max_bytes = max_bytes
        self.ram_items = ram_items
        self.spill_dir = spill_dir
        self.session = session
        self.eviction_policy = eviction_policy or FifoEviction()
        self.spill_order = spill_order
        self.stats = CacheStats()
        # Replication-aware eviction (Hoard-style): a guard saying "this
        # index must not be evicted" (e.g. it is the last cluster-resident
        # copy).  Guarded entries are skipped in FIFO order; if *every*
        # entry is guarded the plain FIFO victim is evicted anyway, so
        # capacity bounds always hold.
        self.eviction_guard: Optional[Callable[[int], bool]] = None
        # Residency listeners (the peer-cache registry's copy counter).
        self._on_insert: Optional[Callable[[int], None]] = None
        self._on_evict: Optional[Callable[[int], None]] = None
        # Flight-recorder listeners (ISSUE 10): a second, dedicated slot.
        # The residency slot above is contended (peer-cache registry, the
        # vector engine's residency bitmask) and observation must never
        # displace it.  Observe-only: fired after all state changes.
        self._trace_insert: Optional[Callable[[int], None]] = None
        self._trace_evict: Optional[Callable[[int], None]] = None
        self._lock = threading.RLock()
        # FIFO order: key -> payload (bytes) | None (spilled to disk).
        self._entries: "collections.OrderedDict[SampleKey, Optional[bytes]]" = (
            collections.OrderedDict()
        )
        self._sizes: Dict[SampleKey, int] = {}
        self._total_bytes = 0
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)

    # -- helpers -----------------------------------------------------------
    def _key(self, index: int) -> SampleKey:
        return SampleKey(index=index, session=self.session)

    def _spill_path(self, key: SampleKey) -> str:
        assert self.spill_dir is not None
        return os.path.join(self.spill_dir, f"{key.session}-{key.index}.bin")

    def _evict_one_locked(self) -> None:
        # The policy picks the victim (FIFO by default, farthest-future-use
        # under ``repro.oracle.BeladyEviction``); the guard semantics live
        # in the policy too, so ``guard_skips`` keeps counting protections
        # that actually redirected an eviction.
        victim, skipped = self.eviction_policy.select_victim(
            self._entries, self.eviction_guard
        )
        self.stats.guard_skips += skipped
        payload = self._entries.pop(victim)
        self._total_bytes -= self._sizes.pop(victim)
        if payload is None and self.spill_dir:
            try:
                os.remove(self._spill_path(victim))
            except FileNotFoundError:
                pass
        self.stats.evictions += 1
        if self._on_evict is not None:
            self._on_evict(victim.index)
        if self._trace_evict is not None:
            self._trace_evict(victim.index)

    def _over_capacity_locked(self) -> bool:
        if self.max_items is not None and len(self._entries) > self.max_items:
            return True
        if self.max_bytes is not None and self._total_bytes > self.max_bytes:
            return True
        return False

    def _maybe_spill_locked(self) -> None:
        """Keep only the newest ``ram_items`` payloads in RAM."""
        if self.ram_items is None or self.spill_dir is None:
            return
        in_ram = [k for k, v in self._entries.items() if v is not None]
        excess = len(in_ram) - self.ram_items
        if excess <= 0:
            return
        to_spill = (
            in_ram[:excess]
            if self.spill_order is None
            else self.spill_order.select(in_ram, excess)
        )
        for key in to_spill:
            payload = self._entries[key]
            assert payload is not None
            with open(self._spill_path(key), "wb") as f:
                f.write(payload)
            self._entries[key] = None

    # -- public API --------------------------------------------------------
    def put(self, index: int, payload: bytes) -> bool:
        """Insert; returns False if the key was already present (idempotent)."""
        key = self._key(index)
        with self._lock:
            if key in self._entries:
                return False
            self._entries[key] = payload
            self._sizes[key] = len(payload)
            self._total_bytes += len(payload)
            self.stats.inserts += 1
            if self._on_insert is not None:
                self._on_insert(index)
            if self._trace_insert is not None:
                self._trace_insert(index)
            while self._over_capacity_locked():
                self._evict_one_locked()
            self._maybe_spill_locked()
            return True

    def put_many(self, items: Iterable[Tuple[int, bytes]]) -> int:
        """Bulk insert (the pre-fetch service's 'cached in parallel' step)."""
        n = 0
        for index, payload in items:
            n += int(self.put(index, payload))
        return n

    def get(self, index: int) -> Optional[bytes]:
        """Lookup; None on miss. Tracks which tier served the hit."""
        return self.get_with_tier(index)[0]

    def get_with_tier(self, index: int) -> Tuple[Optional[bytes], Optional[str]]:
        """Lookup returning ``(payload, tier)``, tier in {"ram", "disk", None}.

        The tier is reported per-call (not via a stats-counter diff) so
        concurrent readers — the peer-cache tier reads other nodes' caches —
        can attribute their own hits correctly.
        """
        key = self._key(index)
        with self._lock:
            if key not in self._entries:
                self.stats.misses += 1
                return None, None
            payload = self._entries[key]
            self.stats.hits += 1
            if payload is not None:
                self.stats.ram_hits += 1
                return payload, "ram"
            self.stats.disk_hits += 1
        # Disk-tier read outside the lock (payload immutable once spilled).
        # Race: a concurrent insert may evict this entry and delete its spill
        # file between the lock release and the open(); re-treat as a miss.
        try:
            with open(self._spill_path(key), "rb") as f:
                return f.read(), "disk"
        except FileNotFoundError:
            with self._lock:
                self.stats.hits -= 1
                self.stats.disk_hits -= 1
                self.stats.misses += 1
            return None, None

    # -- tier-granular probes (repro.pipeline.tiers) -----------------------
    def probe_ram(self, index: int) -> Optional[bytes]:
        """RAM-tier lookup: hit accounting only on a hit, no miss counted.

        ``RamTier``/``DiskTier``/``note_miss`` together reproduce exactly
        the accounting ``get_with_tier`` performs in one call, but let the
        tier stack interleave other tiers between the probes.
        """
        key = self._key(index)
        with self._lock:
            payload = self._entries.get(key)
            if payload is None:  # absent, or spilled to the disk tier
                return None
            self.stats.hits += 1
            self.stats.ram_hits += 1
            return payload

    def probe_disk(self, index: int) -> Optional[bytes]:
        """Disk-(spill-)tier lookup; None when absent or RAM-resident."""
        key = self._key(index)
        with self._lock:
            if key not in self._entries or self._entries[key] is not None:
                return None
            self.stats.hits += 1
            self.stats.disk_hits += 1
        # Spill read outside the lock (same race handling as get_with_tier):
        # a concurrent eviction deleting the file re-treats this as a miss.
        try:
            with open(self._spill_path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            with self._lock:
                self.stats.hits -= 1
                self.stats.disk_hits -= 1
            return None

    def note_miss(self) -> None:
        """Count one full-cache miss (both tier probes came back empty)."""
        with self._lock:
            self.stats.misses += 1

    def set_residency_listener(
        self,
        on_insert: Optional[Callable[[int], None]],
        on_evict: Optional[Callable[[int], None]],
    ) -> None:
        """Install insert/evict callbacks (fired under the cache lock; the
        peer-cache registry uses them to maintain cluster copy counts).
        Callbacks must not call back into this cache."""
        with self._lock:
            self._on_insert = on_insert
            self._on_evict = on_evict

    def set_trace_listener(
        self,
        on_insert: Optional[Callable[[int], None]],
        on_evict: Optional[Callable[[int], None]],
    ) -> None:
        """Install the flight recorder's insert/evict observers (ISSUE 10).

        A dedicated slot so tracing composes with — never displaces — the
        residency listener.  Installed by the *host* projection wiring
        (``repro.core.simulator`` / ``repro.pipeline.spec``), pointed at a
        ``repro.obs.events.CacheTracer``; rule PL006 keeps ``repro.obs``
        itself from mutating cache state.  Fired under the cache lock,
        after all state changes; callbacks must not call back into this
        cache."""
        with self._lock:
            self._trace_insert = on_insert
            self._trace_evict = on_evict

    def peek(self, index: int) -> Optional[bytes]:
        """Read a payload WITHOUT touching stats (or FIFO state).

        Used by the peer-cache tier when serving another node's miss, so a
        holder's hit/miss counters keep describing its *own* workload
        rather than folding in cross-node traffic.  Returns None on a
        miss or when the spill file lost an eviction race.
        """
        key = self._key(index)
        with self._lock:
            if key not in self._entries:
                return None
            payload = self._entries[key]
        if payload is not None:
            return payload
        try:
            with open(self._spill_path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def contains(self, index: int) -> bool:
        with self._lock:
            return self._key(index) in self._entries

    def __contains__(self, index: int) -> bool:
        return self.contains(index)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return self._total_bytes

    def keys(self) -> List[int]:
        with self._lock:
            return [k.index for k in self._entries]

    def clear(self) -> None:
        with self._lock:
            while self._entries:
                self._evict_one_locked()
