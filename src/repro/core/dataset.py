"""Dataset layer: cache-through access to a sample store.

``CachingDataset`` is the analogue of the paper's custom Dataset wrapper
(§IV-B): a ``get`` walks the node's ordered read-tier stack — local cache
(RAM tier, then spill-disk tier), optional cooperative peer tier, then the
backing bucket — and, *only when no pre-fetch service owns cache
population*, inserts bucket/peer payloads into the cache ("we choose to
not have the worker perform a cache insert in this case, as the pre-fetch
service will eventually perform this insert operation", §IV-C).

The stack is built by ``repro.pipeline.tiers`` (explicit composition,
replacing the seed's ``getattr(store, "get_with_origin")`` duck-typing);
attribution comes back as a ``TierResult`` per read, surfaced here as
``AccessResult`` with backward-compatible ``hit``/``ram_hit``/``peer_hit``
views.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import TYPE_CHECKING, Callable, Optional, Sequence, Tuple

from repro.core.cache import CappedCache
from repro.core.store import SampleStore

# Late-bound module reference (not a from-import): ``repro.pipeline.tiers``
# imports repro.core back, so either package must be importable first.
# Binding the module object and resolving attributes at call time keeps
# both entry orders working (``pydoc repro.pipeline`` imports the pipeline
# package before repro.core has finished initializing).
import repro.pipeline.tiers as _tiers

if TYPE_CHECKING:
    from repro.pipeline.tiers import ReadTier


@dataclasses.dataclass
class AccessResult:
    """One read's attribution, keyed by the tier that served it."""

    payload: bytes
    tier: str  # "ram" | "disk" | "peer" | "bucket" | ...
    class_b: int = 0
    nbytes: int = 0
    seconds: float = 0.0

    @property
    def hit(self) -> bool:
        """Local-cache hit (the paper's 'cache hit')."""
        return self.tier in _tiers.LOCAL_TIERS

    @property
    def ram_hit(self) -> bool:
        return self.tier == "ram"

    @property
    def peer_hit(self) -> bool:
        """Served from a peer node's cache — no Class B request issued."""
        return self.tier == "peer"


class CachingDataset:
    """Cache-through dataset over an ordered read-tier stack.

    The legacy ``(store, cache)`` constructor is preserved: it composes
    ``[RamTier, DiskTier] + tiers_for_store(store)`` automatically.  Pass
    ``tiers`` to substitute a custom remote stack (the local cache tiers
    are always derived from ``cache``).
    """

    def __init__(
        self,
        store: SampleStore,
        cache: Optional[CappedCache],
        insert_on_miss: bool = True,
        transform: Optional[Callable[[bytes], bytes]] = None,
        tiers: Optional[Sequence[ReadTier]] = None,
    ):
        self.store = store
        self.cache = cache
        self.insert_on_miss = insert_on_miss
        self.transform = transform
        remote = list(tiers) if tiers is not None else _tiers.tiers_for_store(store)
        self.tiers = _tiers.TierStack(_tiers.local_tiers_for_cache(cache) + remote)
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, index: int) -> AccessResult:
        result = self.tiers.fetch(index)
        hit = result.tier in _tiers.LOCAL_TIERS
        with self._lock:
            if hit:
                self.hits += 1
            else:
                self.misses += 1
        payload = result.payload
        if not hit:
            if self.cache is not None:
                self.cache.note_miss()
                if self.insert_on_miss:
                    self.cache.put(index, payload)
        if self.transform:
            payload = self.transform(payload)
        return AccessResult(
            payload,
            tier=result.tier,
            class_b=result.class_b,
            nbytes=result.nbytes,
            seconds=result.seconds,
        )

    def __getitem__(self, index: int) -> bytes:
        return self.get(index).payload

    def reset_counters(self) -> Tuple[int, int]:
        with self._lock:
            h, m = self.hits, self.misses
            self.hits = 0
            self.misses = 0
        return h, m
