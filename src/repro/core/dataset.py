"""Dataset layer: cache-through access to a sample store.

``CachingDataset`` is the analogue of the paper's custom Dataset wrapper
(§IV-B): a ``get`` first consults the node-local capped cache; on a miss it
falls back to the backing store (the bucket), and — *only when no pre-fetch
service owns cache population* — inserts the fetched sample ("we choose to
not have the worker perform a cache insert in this case, as the pre-fetch
service will eventually perform this insert operation", §IV-C).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional, Tuple

from repro.core.cache import CappedCache
from repro.core.store import SampleStore


@dataclasses.dataclass
class AccessResult:
    payload: bytes
    hit: bool
    ram_hit: bool = False
    # Local-cache miss served from a peer node's cache (PeerStore tier)
    # instead of the bucket — no Class B request was issued.
    peer_hit: bool = False


class CachingDataset:
    """Cache-through dataset over (store, cache)."""

    def __init__(
        self,
        store: SampleStore,
        cache: Optional[CappedCache],
        insert_on_miss: bool = True,
        transform: Optional[Callable[[bytes], bytes]] = None,
    ):
        self.store = store
        self.cache = cache
        self.insert_on_miss = insert_on_miss
        self.transform = transform
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, index: int) -> AccessResult:
        if self.cache is not None:
            cached, tier = self.cache.get_with_tier(index)
            if cached is not None:
                with self._lock:
                    self.hits += 1
                payload = self.transform(cached) if self.transform else cached
                return AccessResult(payload, hit=True, ram_hit=tier == "ram")
        # A PeerStore exposes ``get_with_origin``: a per-call flag saying
        # whether this miss was served by a peer instead of the bucket
        # (per-call so concurrent prefetch workers can't misattribute it).
        get_with_origin = getattr(self.store, "get_with_origin", None)
        if get_with_origin is not None:
            payload, peer_hit = get_with_origin(index)
        else:
            payload = self.store.get(index)
            peer_hit = False
        with self._lock:
            self.misses += 1
        if self.cache is not None and self.insert_on_miss:
            self.cache.put(index, payload)
        if self.transform:
            payload = self.transform(payload)
        return AccessResult(payload, hit=False, peer_hit=peer_hit)

    def __getitem__(self, index: int) -> bytes:
        return self.get(index).payload

    def reset_counters(self) -> Tuple[int, int]:
        with self._lock:
            h, m = self.hits, self.misses
            self.hits = 0
            self.misses = 0
        return h, m
