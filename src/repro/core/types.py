"""Core datatypes shared across the DELI data plane.

Everything in the data plane speaks in terms of *sample keys* (dataset
indices), *payloads* (bytes), and *fetch requests* (ordered batches of keys
handed to the pre-fetch service).  Keeping these plain dataclasses (no jax,
no numpy requirements) lets the policy layer, the discrete-event simulator
and the threaded runtime share one vocabulary.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence


class StorageClass(enum.Enum):
    """GCP object-store request classes (drives the cost model)."""

    CLASS_A = "class_a"  # listing / mutation requests ($0.05 / 10k, paper §III-C)
    CLASS_B = "class_b"  # object GET requests          ($0.002 / 10k, paper §III-C)


@dataclasses.dataclass(frozen=True)
class SampleKey:
    """Identity of one training sample within a session.

    ``index`` is the dataset index; ``session`` mirrors the paper's
    "unique ID for the current training session" used in the MongoDB
    multi-key index (§IV-B) so stale cache entries from a previous run
    never produce hits.
    """

    index: int
    session: str = "default"


@dataclasses.dataclass
class Sample:
    key: SampleKey
    payload: bytes

    @property
    def nbytes(self) -> int:
        return len(self.payload)


@dataclasses.dataclass
class FetchRequest:
    """One pre-fetch round: 'cache these keys, in this order'."""

    keys: tuple
    request_id: int
    issued_at: float  # seconds (virtual or wall clock)


@dataclasses.dataclass
class StoreStats:
    """Request accounting for one store (feeds the cost model Eq. 3-5)."""

    class_a_requests: int = 0
    class_b_requests: int = 0
    bytes_read: int = 0
    read_seconds: float = 0.0  # total time spent inside reads

    def merge(self, other: "StoreStats") -> "StoreStats":
        return StoreStats(
            self.class_a_requests + other.class_a_requests,
            self.class_b_requests + other.class_b_requests,
            self.bytes_read + other.bytes_read,
            self.read_seconds + other.read_seconds,
        )


@dataclasses.dataclass
class EpochStats:
    """Per-node, per-epoch data-plane metrics (the paper's two metrics)."""

    epoch: int
    node: int
    samples: int = 0
    hits: int = 0
    misses: int = 0
    data_wait_seconds: float = 0.0  # time the training loop blocked on data
    compute_seconds: float = 0.0
    evictions: int = 0
    ram_hits: int = 0  # two-tier cache: hits served from the RAM tier
    # Cooperative peer-cache tier: reads served by a peer node's cache over
    # the inter-node network instead of the bucket; each one is a Class B
    # request avoided.  Demand misses served by peers stay counted inside
    # ``misses`` (the local cache did miss).  The simulator additionally
    # folds pre-fetch round pulls into this field; the threaded runtime
    # reports service-side pulls on ``PrefetchService.peer_fetches`` /
    # ``PeerStore.peer_hits`` instead (the async service can't attribute
    # them to an epoch).
    peer_hits: int = 0

    @property
    def miss_rate(self) -> float:
        return self.misses / self.samples if self.samples else 0.0

    @property
    def hit_rate(self) -> float:
        return 1.0 - self.miss_rate


@dataclasses.dataclass
class RunStats:
    """Aggregate over epochs/nodes; what benchmarks report."""

    epochs: Sequence[EpochStats]
    store_stats: Optional[StoreStats] = None

    def epoch(self, e: int) -> Sequence[EpochStats]:
        return [s for s in self.epochs if s.epoch == e]

    def mean_miss_rate(self, e: int) -> float:
        rows = self.epoch(e)
        return sum(r.miss_rate for r in rows) / len(rows) if rows else 0.0

    def mean_data_wait(self, e: int) -> float:
        rows = self.epoch(e)
        return sum(r.data_wait_seconds for r in rows) / len(rows) if rows else 0.0

    def total_data_wait(self) -> float:
        return sum(r.data_wait_seconds for r in self.epochs)
