"""Core datatypes shared across the DELI data plane.

Everything in the data plane speaks in terms of *sample keys* (dataset
indices), *payloads* (bytes), and *fetch requests* (ordered batches of keys
handed to the pre-fetch service).  Keeping these plain dataclasses (no jax,
no numpy requirements) lets the policy layer, the discrete-event simulator
and the threaded runtime share one vocabulary.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, Optional, Sequence

#: Tiers whose hits count as *local cache* hits.  Defined here (the
#: dependency root) and re-exported by ``repro.pipeline.tiers`` as part of
#: the tier API — one source of truth for hit/miss derivation.
LOCAL_TIERS = ("ram", "disk")


class StorageClass(enum.Enum):
    """GCP object-store request classes (drives the cost model)."""

    CLASS_A = "class_a"  # listing / mutation requests ($0.05 / 10k, paper §III-C)
    CLASS_B = "class_b"  # object GET requests          ($0.002 / 10k, paper §III-C)


@dataclasses.dataclass(frozen=True)
class SampleKey:
    """Identity of one training sample within a session.

    ``index`` is the dataset index; ``session`` mirrors the paper's
    "unique ID for the current training session" used in the MongoDB
    multi-key index (§IV-B) so stale cache entries from a previous run
    never produce hits.
    """

    index: int
    session: str = "default"


@dataclasses.dataclass
class Sample:
    key: SampleKey
    payload: bytes

    @property
    def nbytes(self) -> int:
        return len(self.payload)


@dataclasses.dataclass
class FetchRequest:
    """One pre-fetch round: 'cache these keys, in this order'."""

    keys: tuple
    request_id: int
    issued_at: float  # seconds (virtual or wall clock)


@dataclasses.dataclass
class StoreStats:
    """Request accounting for one store (feeds the cost model Eq. 3-5)."""

    class_a_requests: int = 0
    class_b_requests: int = 0
    bytes_read: int = 0
    read_seconds: float = 0.0  # total time spent inside reads

    def merge(self, other: "StoreStats") -> "StoreStats":
        return StoreStats(
            self.class_a_requests + other.class_a_requests,
            self.class_b_requests + other.class_b_requests,
            self.bytes_read + other.bytes_read,
            self.read_seconds + other.read_seconds,
        )


@dataclasses.dataclass
class EpochStats:
    """Per-node, per-epoch data-plane metrics (the paper's two metrics).

    Attribution is a *per-tier counter map*: ``tier_hits[tier]`` counts
    reads served by that tier ("ram"/"disk" = local cache, "peer" = a peer
    node's cache over the network, "bucket" = a Class B object GET).  The
    legacy scalar fields (``hits``, ``misses``, ``ram_hits``,
    ``peer_hits``) survive as derived properties so every seed-era consumer
    keeps working.

    Peer accounting note (unchanged semantics from PR 1): a demand read
    served by a peer is recorded under ``tier_hits["peer"]`` and still
    counts as a local-cache miss.  The simulator additionally folds
    pre-fetch round pulls into the "peer" counter; the threaded runtime
    reports service-side pulls on ``PrefetchService.peer_fetches`` /
    ``PeerStore.peer_hits`` instead (the async service can't attribute them
    to an epoch).
    """

    epoch: int
    node: int
    samples: int = 0
    data_wait_seconds: float = 0.0  # time the training loop blocked on data
    compute_seconds: float = 0.0
    # Time blocked at gradient-synchronization (allreduce) barriers.  Only
    # the per-batch BSP schedule (``sync="batch"``) accounts it; the legacy
    # epoch-barrier schedule leaves it 0.0 (ISSUE 4).
    allreduce_wait_seconds: float = 0.0
    # Time spent *transferring* gradient bytes in the allreduce itself
    # (``CollectiveModel`` duration, ISSUE 8).  Zero unless a collective
    # cost model is configured; with ``overlap="buckets"`` only the
    # non-hidden (exposed) fraction lands here.
    allreduce_comm_seconds: float = 0.0
    evictions: int = 0
    tier_hits: Dict[str, int] = dataclasses.field(default_factory=dict)

    def record(self, tier: str, n: int = 1) -> None:
        """Attribute ``n`` reads to ``tier``."""
        self.tier_hits[tier] = self.tier_hits.get(tier, 0) + n

    def tier(self, name: str) -> int:
        return self.tier_hits.get(name, 0)

    # -- legacy scalar views -------------------------------------------------
    @property
    def hits(self) -> int:
        """Local-cache hits (RAM + spill-disk tiers)."""
        return sum(self.tier_hits.get(t, 0) for t in LOCAL_TIERS)

    @property
    def misses(self) -> int:
        """Local-cache misses: every sample access not served locally
        (includes peer-served reads — the local cache did miss — and the
        disk-source baseline, which has no cache at all)."""
        return self.samples - self.hits

    @property
    def ram_hits(self) -> int:
        return self.tier_hits.get("ram", 0)

    @property
    def disk_hits(self) -> int:
        return self.tier_hits.get("disk", 0)

    @property
    def peer_hits(self) -> int:
        return self.tier_hits.get("peer", 0)

    @property
    def bucket_reads(self) -> int:
        return self.tier_hits.get("bucket", 0)

    @property
    def wall_seconds(self) -> float:
        """The node's busy+blocked time inside the epoch: data-wait +
        compute + allreduce waits + allreduce transfer.  Under
        ``sync="batch"`` this is the node's barrier-to-barrier epoch
        duration (fig11's metric).  With zero collective cost the comm
        term is 0.0 and this reproduces the pre-ISSUE-8 total exactly.
        This is also exactly the per-rank row of the flight recorder's
        wall-time decomposition (``repro.obs.export.decomposition``):
        each traced span's duration is the very float added to the
        matching field, so the table sums back to this property with
        ``==``."""
        return (
            self.data_wait_seconds
            + self.compute_seconds
            + self.allreduce_wait_seconds
            + self.allreduce_comm_seconds
        )

    @property
    def wall_clock_seconds(self) -> float:
        """Legacy alias of :attr:`wall_seconds` (seed-era consumers)."""
        return self.wall_seconds

    def asdict(self) -> Dict[str, object]:
        """A stable plain-dict form: exactly the constructor fields, so
        ``EpochStats(**s.asdict()) == s`` round-trips (``tier_hits`` is
        copied, not aliased).  Derived properties are deliberately
        excluded — serialize facts, recompute views."""
        return {
            "epoch": self.epoch,
            "node": self.node,
            "samples": self.samples,
            "data_wait_seconds": self.data_wait_seconds,
            "compute_seconds": self.compute_seconds,
            "allreduce_wait_seconds": self.allreduce_wait_seconds,
            "allreduce_comm_seconds": self.allreduce_comm_seconds,
            "evictions": self.evictions,
            "tier_hits": dict(self.tier_hits),
        }

    @property
    def miss_rate(self) -> float:
        return self.misses / self.samples if self.samples else 0.0

    @property
    def hit_rate(self) -> float:
        return 1.0 - self.miss_rate


def aggregate_tier_hits(stats: Iterable["EpochStats"]) -> Dict[str, int]:
    """Sum per-tier counters over epochs/nodes (benchmark tables, parity)."""
    out: Dict[str, int] = {}
    for s in stats:
        for tier, n in s.tier_hits.items():
            out[tier] = out.get(tier, 0) + n
    return out


def sequential_sum(values: Iterable[float]) -> float:
    """Left-to-right float accumulation, spelled out.

    The parity contract forbids leaning on a fold whose order is an
    implementation detail (builtin ``sum`` happens to be sequential,
    ``np.sum`` is pairwise) — every float reduction in the sim domain uses
    this explicit chain, the scalar twin of ``np.cumsum(xs)[-1]``
    (see repro/engine/vector.py)."""
    total = 0.0
    for v in values:
        total += v
    return total


@dataclasses.dataclass
class RunStats:
    """Aggregate over epochs/nodes; what benchmarks report."""

    epochs: Sequence[EpochStats]
    store_stats: Optional[StoreStats] = None

    def epoch(self, e: int) -> Sequence[EpochStats]:
        return [s for s in self.epochs if s.epoch == e]

    def mean_miss_rate(self, e: int) -> float:
        rows = self.epoch(e)
        return sequential_sum(r.miss_rate for r in rows) / len(rows) if rows else 0.0

    def mean_data_wait(self, e: int) -> float:
        rows = self.epoch(e)
        return (
            sequential_sum(r.data_wait_seconds for r in rows) / len(rows)
            if rows
            else 0.0
        )

    def total_data_wait(self) -> float:
        return sequential_sum(r.data_wait_seconds for r in self.epochs)
