"""DeliLoader: the drop-in data loader that glues sampler, cache, pre-fetch
service and store into mini-batches, and *measures* the paper's two metrics
(data-wait time, miss rate) while doing so.

The iteration protocol matches the paper's Fig. 1/2 data flow:

  Sampler wrapper (PrefetchPlanner) --announce round--> PrefetchService
  DataLoader --get(idx)--> CachingDataset --hit--> cache
                                           --miss--> bucket (no insert)

Every ``__iter__`` is one epoch; ``set_epoch`` reshuffles the distributed
partition exactly like the paper's experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.clock import Clock, RealClock
from repro.core.dataset import CachingDataset
from repro.core.lockstep import (
    STEP_BATCH_END,
    STEP_CONTINUE,
    BucketedBatchComm,
    SubstepAccess,
)
from repro.core.policy import PrefetchConfig, PrefetchPlanner
from repro.core.prefetcher import PrefetchService
from repro.core.sampler import Sampler
from repro.core.types import EpochStats
from repro.engine.kernels import DemandKernel
from repro.obs.events import TraceRecorder, trace_demand, trace_emit, trace_sync

#: Internal marker yielded by ``_sample_steps`` for a sub-step phase (a
#: time component that is its own scheduler event, not a finished sample).
_PHASE = object()


@dataclasses.dataclass
class Batch:
    """One mini-batch of raw payloads + its data-plane accounting."""

    indices: List[int]
    payloads: List[bytes]
    data_wait_s: float
    hits: int
    misses: int

    def stacked(self, decode: Callable[[bytes], np.ndarray]) -> np.ndarray:
        return np.stack([decode(p) for p in self.payloads])


class DeliLoader:
    def __init__(
        self,
        dataset: CachingDataset,
        sampler: Sampler,
        batch_size: int,
        config: PrefetchConfig,
        service: Optional[PrefetchService] = None,
        clock: Optional[Clock] = None,
        node: int = 0,
        drop_last: bool = True,
        planner_factory: Optional[Callable[[Sequence[int]], object]] = None,
        oracle_view=None,
        trace: Optional[TraceRecorder] = None,
    ):
        """``planner_factory`` overrides the knob-driven ``PrefetchPlanner``
        with a custom epoch-order -> planner construction — the oracle data
        plane (ISSUE 5) passes ``repro.oracle.planner.make_planner_factory``
        here, the SAME construction ``NodeSimulator.begin_epoch`` uses.
        ``oracle_view`` is this node's clairvoyant ``NodeAccessView``; the
        loader drives it (``begin_epoch`` per epoch, ``on_consume`` per
        sample) in lines mirrored against the simulator's, which is what
        keeps Belady eviction and clairvoyant prefetch parity-exact."""
        if config.enabled and service is None:
            raise ValueError("prefetching enabled but no PrefetchService given")
        if planner_factory is not None and service is None:
            raise ValueError("planner_factory issues fetch rounds; give a service")
        self.dataset = dataset
        self.sampler = sampler
        self.batch_size = batch_size
        self.config = config
        self.service = service
        self.planner_factory = planner_factory
        self.oracle_view = oracle_view
        self.clock = clock or RealClock()
        self.node = node
        # Flight recorder (ISSUE 10): observe-only; ``None`` makes every
        # emit a no-op and the schedule byte-identical to an untraced run.
        self._trace = trace
        self.drop_last = drop_last
        self.epoch_history: List[EpochStats] = []
        self._epoch = 0
        self._resume_cursor = 0  # sample offset within the epoch (checkpointing)
        # The epoch-in-progress stats object (set while _sample_steps runs,
        # kept after epoch finalization): the cluster scheduler's allreduce
        # barriers account blocked time into it via sync_to(), including
        # the epoch-end barrier that fires after the stepper is exhausted.
        self._active_stats: Optional[EpochStats] = None

    def set_epoch(self, epoch: int) -> None:
        self._epoch = epoch
        self.sampler.set_epoch(epoch)

    # -- checkpoint/restore of the data-plane cursor -------------------------
    def state_dict(self) -> dict:
        """Checkpoint the data-plane cursor AND the accumulated per-epoch
        stats, so a resumed run reports its full trajectory (the seed
        dropped ``epoch_history`` across restore)."""
        return {
            "epoch": self._epoch,
            "cursor": self._resume_cursor,
            "history": [dataclasses.asdict(s) for s in self.epoch_history],
        }

    def load_state_dict(self, state: dict) -> None:
        self.set_epoch(int(state["epoch"]))
        self._resume_cursor = int(state["cursor"])
        if "history" in state:
            self.epoch_history = [
                s if isinstance(s, EpochStats) else EpochStats(**s)
                for s in state["history"]
            ]
        # Pre-history checkpoints carry no trajectory: keep whatever this
        # loader already accumulated (documented reset-free behaviour).

    # -- per-sample core (shared by batch iteration + lock-step stepping) ----
    def _sample_steps(
        self,
        stats: EpochStats,
        pipeline_model=None,
        compute_per_batch_s: float = 0.0,
        substep: Optional[SubstepAccess] = None,
        overlap: Optional[BucketedBatchComm] = None,
    ):
        """Process the epoch sample-by-sample, yielding
        ``(index, AccessResult, data_wait_s, consumed, batch_end)`` after
        each access (``batch_end`` = this sample completed a gradient
        batch), with bare ``_PHASE`` markers in between at sub-step
        granularity.

        ``pipeline_model`` (a ``PipelineCostModel``) enables *modelled
        training-loop costs*: after each read, the clock additionally
        sleeps the RAM-hit latency (local-cache hits) and the per-sample
        CPU overhead — the exact components, in the exact order, that
        ``NodeSimulator._access`` adds to its virtual time, so a lock-step
        runtime's clock trajectory is float-identical to the simulator's.
        ``compute_per_batch_s`` likewise sleeps the modelled compute after
        every full batch (inside the step, exactly like the simulator).
        Both default off, preserving the free-running loader's behaviour of
        measuring only what the stores really charge.

        ``substep`` (a ``repro.core.lockstep.SubstepAccess``) replaces the
        tier-stack read with the shared sub-step state machine: each time
        component yields ``_PHASE`` so the cluster scheduler can interleave
        other nodes' events inside this access (mirroring the simulator's
        sub-step decomposition exactly — the machine IS the same object
        type running the same generator).

        ``overlap`` (a ``repro.core.lockstep.BucketedBatchComm``) replaces
        the single batch-end compute sleep with the bucketed compute/
        allreduce pipeline — each span boundary yields ``_PHASE`` exactly
        like a sub-step component, and only the exposed comm tail is
        charged (ISSUE 8; same generator the simulator runs).

        Mid-epoch resume (ISSUE 4 bugfix): gradient batches are a property
        of the epoch's *sample order*, not of the resume point — the batch
        counter starts at ``skip % batch_size`` so a cursor inside a batch
        completes that partial batch (and reaches its allreduce barrier) at
        the true epoch boundary instead of re-spanning a full batch from
        the resume point; and re-announced rounds are flagged ``replay``
        so the pre-fetch service skips the keys it already fetched — and
        billed — before the checkpoint (no re-issued Class B GETs, no
        re-billed per-round listing).
        """
        order = list(self.sampler)
        skip = self._resume_cursor
        self._resume_cursor = 0
        # The loader's share of the shared cost arithmetic
        # (repro.engine.kernels): tier latencies come from the real stores
        # sleeping their own clocks, so only the modelled loop overheads
        # are mirrored here — through the same kernel fields every engine
        # charges (bit-identical floats; see docs/PARITY.md).
        loop_kernel = (
            DemandKernel.loop_only(pipeline_model)
            if pipeline_model is not None
            else None
        )
        if self.oracle_view is not None:
            self.oracle_view.begin_epoch(self._epoch, order)
        planner = (
            self.planner_factory(order)
            if self.planner_factory is not None
            else PrefetchPlanner(order, self.config)
        )
        # Mirrored line (NodeSimulator.begin_epoch): a cluster-placement
        # planner carries the epoch's ownership set — install it on the
        # shared service, whose round partition enforces it identically on
        # both projections.
        # parity-mirror: placement-install begin planner=planner
        owned = getattr(planner, "owned", None)
        if owned is not None and self.service is not None:
            self.service.set_placement(
                owned, in_flight=getattr(planner, "in_flight", None)
            )
        # parity-mirror: placement-install end
        if self.service is not None:
            # Flight recorder: stamp the epoch's policy family on the shared
            # service so every issue event carries its provenance (the
            # simulator's begin_epoch stamps the identical line).
            self.service.provenance = getattr(planner, "provenance", "paper")
        consumed = 0
        in_batch = skip % self.batch_size
        self._active_stats = stats
        for idx, round_ in planner:
            replaying = consumed < skip
            # parity-mirror: oracle-cursor begin
            if self.oracle_view is not None:
                # Cursor advances at access *start* (mirror of
                # NodeSimulator._epoch_events), replayed resumes included.
                self.oracle_view.on_consume(idx)
            # parity-mirror: oracle-cursor end
            if round_ is not None and self.service is not None:
                self.service.request(round_, stats=stats, replay=replaying)
            if replaying:
                consumed += 1
                continue  # resuming mid-epoch: rounds still announced above
            if substep is not None:
                for _ in substep.run(idx, stats):
                    yield _PHASE  # one time component = one scheduler event
                result = None
                dt = 0.0  # accounted inside the shared sub-step machine
                consumed += 1
            else:
                if self.service is not None:
                    # Lock-step completion barrier: fold prefetch rounds that
                    # finished by now (no-op for the free-running service).
                    self.service.advance_to(self.clock.now())
                t0 = self.clock.now()
                result = self.dataset.get(idx)
                if loop_kernel is not None:
                    if result.tier == "ram":
                        self.clock.sleep(loop_kernel.ram_hit_s)
                    self.clock.sleep(loop_kernel.cpu_overhead_s)
                dt = self.clock.now() - t0
                consumed += 1
                stats.samples += 1
                stats.record(result.tier)
                stats.data_wait_seconds += dt
                trace_demand(
                    self._trace,
                    self.node,
                    t0,
                    dt,
                    idx,
                    result.tier,
                    result.class_b,
                )
            in_batch += 1
            batch_end = False
            if in_batch == self.batch_size:
                in_batch = 0
                batch_end = True
                if overlap is not None:
                    for _ in overlap.run(stats):
                        yield _PHASE  # one bucket span = one scheduler event
                elif compute_per_batch_s:
                    c0 = self.clock.now()
                    self.clock.sleep(compute_per_batch_s)
                    stats.compute_seconds += compute_per_batch_s
                    trace_emit(
                        self._trace, "compute", self.node, c0, compute_per_batch_s
                    )
            yield idx, result, dt, consumed, batch_end

    def _finish_epoch(self, stats: EpochStats, evictions_before: int) -> None:
        if self.dataset.cache:
            stats.evictions = self.dataset.cache.stats.evictions - evictions_before
        self._resume_cursor = 0
        self.epoch_history.append(stats)

    def __iter__(self) -> Iterator[Batch]:
        stats = EpochStats(epoch=self._epoch, node=self.node)
        evictions_before = self.dataset.cache.stats.evictions if self.dataset.cache else 0
        batch_indices: List[int] = []
        batch_payloads: List[bytes] = []
        batch_wait = 0.0
        batch_hits = 0
        batch_misses = 0
        consumed = 0
        for idx, result, dt, consumed, _batch_end in self._sample_steps(stats):
            batch_wait += dt
            batch_indices.append(idx)
            batch_payloads.append(result.payload)
            if result.hit:
                batch_hits += 1
            else:
                batch_misses += 1
            if len(batch_indices) == self.batch_size:
                self._resume_cursor = consumed
                yield Batch(batch_indices, batch_payloads, batch_wait, batch_hits, batch_misses)
                batch_indices, batch_payloads = [], []
                batch_wait, batch_hits, batch_misses = 0.0, 0, 0
        if batch_indices and not self.drop_last:
            self._resume_cursor = consumed
            yield Batch(batch_indices, batch_payloads, batch_wait, batch_hits, batch_misses)
        self._finish_epoch(stats, evictions_before)

    def step_epoch(
        self,
        pipeline_model=None,
        compute_per_batch_s: float = 0.0,
        substep: Optional[SubstepAccess] = None,
        overlap: Optional[BucketedBatchComm] = None,
    ) -> Iterator[int]:
        """Event-granular epoch driver for a cluster scheduler.

        Each ``next()`` processes exactly one scheduler event — at step
        granularity a whole sample access (announcing its fetch round,
        folding due prefetch completions, reading through the tier stack,
        advancing the modelled loop costs), at sub-step granularity
        (``substep``) one virtual-time component of it — and yields a
        ``repro.core.lockstep`` signal: ``STEP_BATCH_END`` when the event
        completed a gradient batch (the ``sync="batch"`` parking point),
        else ``STEP_CONTINUE``.  An event-interleaved driver
        (``RuntimeCluster.run``) picks, after every event, whichever
        node's clock is earliest.  Exhausting the generator finalizes the
        epoch into ``epoch_history`` exactly like full-batch iteration.
        """
        stats = EpochStats(epoch=self._epoch, node=self.node)
        evictions_before = self.dataset.cache.stats.evictions if self.dataset.cache else 0
        for item in self._sample_steps(
            stats, pipeline_model, compute_per_batch_s, substep, overlap
        ):
            if item is _PHASE:
                yield STEP_CONTINUE
            else:
                yield STEP_BATCH_END if item[4] else STEP_CONTINUE
        self._finish_epoch(stats, evictions_before)

    def sync_to(self, t: float, comm_s: float = 0.0) -> None:
        """Allreduce barrier (lock-step cluster drive, ``sync="batch"``):
        account the blocked time into the epoch's stats, jump the node
        clock to the barrier, then serve the collective's transfer
        duration ``comm_s`` — the exact float operations
        ``NodeSimulator.sync_to`` performs, in the same order
        (``clock.sleep`` is the same ``+=`` the simulator applies)."""
        # parity-mirror: sync-to begin clock=self.clock stats=self._active_stats node=self.node trace=self._trace
        wait = t - self.clock.now()
        if wait > 0:
            if self._active_stats is not None:
                self._active_stats.allreduce_wait_seconds += wait
            self.clock.advance_to(t)
        if comm_s > 0:
            if self._active_stats is not None:
                self._active_stats.allreduce_comm_seconds += comm_s
            self.clock.sleep(comm_s)
        trace_sync(self._trace, self.node, self.clock.now(), wait, comm_s)
        # parity-mirror: sync-to end

    def __len__(self) -> int:
        n = len(self.sampler)
        return n // self.batch_size if self.drop_last else -(-n // self.batch_size)

    @property
    def last_epoch_stats(self) -> Optional[EpochStats]:
        return self.epoch_history[-1] if self.epoch_history else None


def run_epochs(
    loader: DeliLoader,
    epochs: int,
    compute_fn: Optional[Callable[[Batch], None]] = None,
    start_epoch: int = 0,
) -> List[EpochStats]:
    """Drive a loader for N epochs with an optional per-batch compute fn.

    ``compute_fn`` is where a training step goes; for pipeline-only
    experiments it simulates compute by sleeping on the loader's clock.
    """
    out: List[EpochStats] = []
    for e in range(start_epoch, start_epoch + epochs):
        loader.set_epoch(e)
        for batch in loader:
            if compute_fn is not None:
                compute_fn(batch)
        assert loader.last_epoch_stats is not None
        out.append(loader.last_epoch_stats)
    return out
