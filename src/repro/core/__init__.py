"""repro.core — the paper's contribution: the DELI data plane.

Public API:
    stores:      SimulatedBucketStore, FileSystemStore, InMemoryStore, ReliableStore
    cache:       CappedCache (+ pluggable EvictionPolicy; FifoEviction default,
                 repro.oracle.BeladyEviction = clairvoyant farthest-future-use)
    policy:      PrefetchConfig (incl. .fifty_fifty / .full_fetch), PrefetchPlanner
    runtime:     PrefetchService, CachingDataset, DeliLoader, run_epochs
    lock-step:   LockstepPrefetchService (deterministic prefetch events,
                 shared verbatim by the simulator and the lock-step runtime)
    simulation:  SimConfig, simulate_cluster (event-interleaved cluster
                 schedule by default; interleaved=False = legacy sequential),
                 NodeSimulator
    models:      BucketModel, DiskModel, PipelineCostModel (Table-I calibrated)
    cost:        GcpPrices, cost_disk_baseline, cost_bucket, ...

The declarative layer lives in ``repro.pipeline``: ``DataPlaneSpec`` builds
both the simulator and the threaded runtime from one description, and the
read path is an explicit ``TierStack`` (ram/disk/peer/bucket) with per-tier
attribution.  The constructors exported here remain supported shims.
"""
from repro.core.bandwidth import (
    DEFAULT_BUCKET,
    DEFAULT_DISK,
    DEFAULT_NETWORK,
    DEFAULT_PIPELINE,
    DEFAULT_PROFILE,
    BucketModel,
    CollectiveModel,
    DiskModel,
    NetworkModel,
    NodeProfile,
    PipelineCostModel,
    arch_gradient_bytes,
    mnist_cnn_gradient_bytes,
    straggler_profiles,
)
from repro.core.cache import CappedCache, EvictionPolicy, FifoEviction
from repro.core.clock import RealClock, VirtualClock
from repro.core.cost import (
    GcpPrices,
    WorkloadCostInputs,
    cost_bucket,
    cost_disk_baseline,
    cost_with_listing_cache,
    cost_with_peer_cache,
    cost_with_supersamples,
)
from repro.core.dataset import CachingDataset
from repro.core.listing_cache import ListingCache
from repro.core.lockstep import (
    STEP_BATCH_END,
    STEP_CONTINUE,
    STEP_DONE,
    BucketedBatchComm,
    LockstepPrefetchService,
    SubstepAccess,
)
from repro.core.loader import Batch, DeliLoader, run_epochs
from repro.core.policy import PrefetchConfig, PrefetchPlanner, validate_config_against_cache
from repro.core.prefetcher import PrefetchService
from repro.core.sampler import (
    DistributedPartitionSampler,
    LocalityAwareSampler,
    RandomSampler,
    SequentialSampler,
    SharedShuffleSampler,
)
from repro.core.simulator import NodeSimulator, SimConfig, mean_data_wait, mean_miss_rate, simulate_cluster
from repro.core.store import (
    FileSystemStore,
    InMemoryStore,
    ReliableStore,
    SampleStore,
    SimulatedBucketStore,
    StoreError,
    make_synthetic_payloads,
)
from repro.core.supersample import (
    GroupedPartitionSampler,
    build_supersample_store_payloads,
    pack_supersample,
    unpack_supersample,
)
from repro.core.types import (
    EpochStats,
    FetchRequest,
    RunStats,
    Sample,
    SampleKey,
    StoreStats,
    aggregate_tier_hits,
)
from repro.core.workloads import CIFAR10, MNIST, PAPER_WORKLOADS, WorkloadSpec, lm_token_workload
