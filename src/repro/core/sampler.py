"""Samplers: epoch ordering + distributed partitioning.

``DistributedPartitionSampler`` mirrors the behaviour of
``torch.utils.data.DistributedSampler`` that the paper's experiments rely on
(§V-A): every epoch a *new* seeded global permutation is drawn and node ``i``
takes a strided slice — so a node's partition is re-randomized each epoch.
This is precisely what produces the paper's ~66% epoch-2 miss rate for an
unlimited cache (Fig. 5): only ~1/n of a node's new partition was in its
previous partition.

``LocalityAwareSampler`` (beyond-paper, §VI direction + Yang & Cong '19):
keeps the global permutation but assigns each sample preferentially to a
node that already holds it in cache, subject to exact load balance.  All
nodes compute the same assignment from the same inputs (cache key sets are
exchanged via an all-gather in a real deployment; here they are passed in),
so no coordination service is needed.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence

import numpy as np


class Sampler:
    """Base: iterable over dataset indices for the current epoch."""

    def __init__(self, n_samples: int):
        self.n_samples = n_samples
        self.epoch = 0

    def set_epoch(self, epoch: int) -> None:
        self.epoch = epoch

    def indices(self) -> List[int]:
        raise NotImplementedError

    def __iter__(self):
        return iter(self.indices())

    def __len__(self) -> int:
        return self.n_samples


class SequentialSampler(Sampler):
    def indices(self) -> List[int]:
        return list(range(self.n_samples))


class RandomSampler(Sampler):
    def __init__(self, n_samples: int, seed: int = 0):
        super().__init__(n_samples)
        self.seed = seed

    def indices(self) -> List[int]:
        rng = np.random.default_rng((self.seed, self.epoch))
        return rng.permutation(self.n_samples).tolist()


def _global_permutation(n: int, seed: int, epoch: int) -> np.ndarray:
    return np.random.default_rng((seed, epoch)).permutation(n)


class DistributedPartitionSampler(Sampler):
    """Random global permutation, strided slice per node (PyTorch semantics).

    Node ``rank`` of ``world`` sees indices perm[rank::world]; all ranks draw
    the identical permutation (same seed+epoch), so partitions are disjoint
    and exhaustive. ``drop_last``-style truncation keeps partitions equal.
    """

    def __init__(self, n_samples: int, rank: int, world: int, seed: int = 0):
        if not (0 <= rank < world):
            raise ValueError(f"rank {rank} out of range for world {world}")
        super().__init__(n_samples)
        self.rank = rank
        self.world = world
        self.seed = seed

    @property
    def partition_size(self) -> int:
        return self.n_samples // self.world

    def indices(self) -> List[int]:
        perm = _global_permutation(self.n_samples, self.seed, self.epoch)
        usable = self.partition_size * self.world
        return perm[:usable][self.rank :: self.world].tolist()

    def __len__(self) -> int:
        return self.partition_size


class SharedShuffleSampler(Sampler):
    """Every node streams the *full* dataset in its own seeded order.

    The paper's experiments partition each epoch (DistributedSampler
    semantics), so two nodes never touch the same index within one epoch —
    which makes *same-epoch* cross-node cache effects invisible by
    construction.  Hoard's setting (Pinto et al.) is the opposite: nodes
    run IID passes over the whole dataset, so node B routinely wants a
    sample node A cached minutes ago in the *current* epoch.  This sampler
    models that regime; it is what the mid-epoch peer-visibility tests (and
    the event-interleaved scheduler's fidelity claim) exercise.

    The permutation is a pure function of ``(seed, rank, epoch)``: no
    coordination, deterministic on every node and on both execution paths.
    """

    def __init__(self, n_samples: int, rank: int, world: int, seed: int = 0):
        if not (0 <= rank < world):
            raise ValueError(f"rank {rank} out of range for world {world}")
        super().__init__(n_samples)
        self.rank = rank
        self.world = world
        self.seed = seed

    @property
    def partition_size(self) -> int:
        return self.n_samples  # every node sees everything

    def indices(self) -> List[int]:
        rng = np.random.default_rng((self.seed, self.rank, self.epoch))
        return rng.permutation(self.n_samples).tolist()


class LocalityAwareSampler(Sampler):
    """Cache-aware epoch partitioning (beyond-paper).

    Given every node's cached index set, assign each sample of the epoch's
    global permutation to a node that caches it when possible, while keeping
    partitions exactly balanced.  Determinism: assignment is a pure function
    of (seed, epoch, sorted cache sets), identical on every node.

    Expected effect: with an unlimited cache the epoch-2 miss rate drops
    from ~(1 - 1/n) to ~0 — benchmarked in benchmarks/beyond_paper.py.
    Shuffling quality note: within-node order remains a random subsequence
    of a uniform global permutation; cross-node sample-to-node assignment
    becomes cache-correlated, which is an explicit trade-off (recorded in
    DESIGN.md) and can be annealed with ``locality_fraction``.
    """

    def __init__(
        self,
        n_samples: int,
        rank: int,
        world: int,
        seed: int = 0,
        locality_fraction: float = 1.0,
        peer_aware: bool = False,
    ):
        super().__init__(n_samples)
        self.rank = rank
        self.world = world
        self.seed = seed
        self.locality_fraction = locality_fraction
        # Cooperative peer-cache tier: an index cached *anywhere* is cheap
        # for every node (one peer RTT), only bucket-only indices pay a
        # Class B GET.  With ``peer_aware`` the leftover fill spreads the
        # bucket-only indices evenly across nodes (on-node > on-peer >
        # bucket-only preference) so no node eats a disproportionate share
        # of the expensive misses.
        self.peer_aware = peer_aware
        self._cache_views: Optional[List[frozenset]] = None

    def update_cache_views(self, cached_indices_per_node: Sequence[Sequence[int]]) -> None:
        if len(cached_indices_per_node) != self.world:
            raise ValueError("need one cache view per node")
        self._cache_views = [frozenset(v) for v in cached_indices_per_node]

    @property
    def partition_size(self) -> int:
        return self.n_samples // self.world

    def _assign(self) -> Dict[int, List[int]]:
        perm = _global_permutation(self.n_samples, self.seed, self.epoch)
        usable = perm[: self.partition_size * self.world]
        quota = {r: self.partition_size for r in range(self.world)}
        assignment: Dict[int, List[int]] = {r: [] for r in range(self.world)}
        views = self._cache_views or [frozenset()] * self.world
        # Budget of locality-preferred picks per node (annealing knob).
        locality_budget = {
            r: int(self.partition_size * self.locality_fraction) for r in range(self.world)
        }
        leftovers: List[int] = []
        for idx in usable.tolist():
            holders = [r for r in range(self.world) if idx in views[r]]
            placed = False
            # Prefer the holder with the most remaining quota (break ties by
            # rank) — greedy balance.
            for r in sorted(holders, key=lambda r: (-quota[r], r)):
                if quota[r] > 0 and locality_budget[r] > 0:
                    assignment[r].append(idx)
                    quota[r] -= 1
                    locality_budget[r] -= 1
                    placed = True
                    break
            if not placed:
                leftovers.append(idx)
        # Round-robin the rest into remaining quota, in permutation order.
        # Peer-aware tiering: fill bucket-only leftovers first (max-quota
        # greedy spreads them evenly — they are the expensive ones under a
        # peer-cache tier), then the on-peer leftovers, which any node can
        # serve cheaply from whoever holds them.
        if self.peer_aware:
            anywhere = frozenset().union(*views)
            leftovers = sorted(leftovers, key=lambda idx: idx in anywhere)
        ranks_cycle = sorted(range(self.world), key=lambda r: -quota[r])
        for idx in leftovers:
            ranks_cycle.sort(key=lambda r: -quota[r])
            r = ranks_cycle[0]
            assignment[r].append(idx)
            quota[r] -= 1
        assert all(q == 0 for q in quota.values())
        return assignment

    def indices(self) -> List[int]:
        return self._assign()[self.rank]

    def __len__(self) -> int:
        return self.partition_size


def partition_fingerprint(indices: Sequence[int]) -> str:
    """Stable digest of a partition (used by elastic restart validation)."""
    h = hashlib.sha256()
    for i in indices:
        h.update(int(i).to_bytes(8, "little"))
    return h.hexdigest()[:16]
