"""Pre-fetch policy: the paper's two knobs, as pure logic.

The policy is deliberately free of threads, clocks and I/O so the threaded
runtime (`prefetcher.py` + `sampler.py`) and the discrete-event simulator
(`simulator.py`) share it verbatim — what the simulator predicts is what the
runtime does.

Paper semantics (§III-B, §IV-C):

  * the Sampler pulls ``fetch_size`` indices at a time from the sub-Sampler
    and announces each batch of indices to the pre-fetch service;
  * a new fetch is requested when the count of *announced but not yet
    consumed* indices drops below ``prefetch_threshold`` ("a minimum number
    of samples that have been fetched but not trained on");
  * threshold 0 is the default ("only fetches new samples when the
    Sampler's queue has been depleted");
  * the **50/50 approach**: fetch_size = prefetch_threshold = cache_size/2.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    fetch_size: int
    prefetch_threshold: int = 0
    cache_items: Optional[int] = None  # None = unlimited cache
    enabled: bool = True

    def __post_init__(self) -> None:
        if self.enabled:
            if self.fetch_size < 1:
                raise ValueError("fetch_size must be >= 1")
            if self.prefetch_threshold < 0:
                raise ValueError("prefetch_threshold must be >= 0")

    @classmethod
    def fifty_fifty(cls, cache_items: int) -> "PrefetchConfig":
        """The paper's best configuration (§V-B): f = T = cache/2."""
        if cache_items < 2:
            raise ValueError("50/50 needs cache_items >= 2")
        half = cache_items // 2
        return cls(fetch_size=half, prefetch_threshold=half, cache_items=cache_items)

    @classmethod
    def full_fetch(cls, fetch_size: int) -> "PrefetchConfig":
        """'Full Fetch': cache == fetch size, threshold 0 (Fig. 9 baseline)."""
        return cls(fetch_size=fetch_size, prefetch_threshold=0, cache_items=fetch_size)

    @classmethod
    def disabled(cls) -> "PrefetchConfig":
        return cls(fetch_size=1, prefetch_threshold=0, cache_items=None, enabled=False)


class PrefetchPlanner:
    """State machine that turns a stream of sample indices into fetch rounds.

    Feed it the epoch's index order (from any sub-sampler); iterate; it
    yields ``(index, fetch_round_or_None)`` pairs: when the pending count
    crosses the threshold, the next round of ``fetch_size`` indices is
    emitted *before* the index that triggered it is consumed — mirroring the
    Sampler wrapper which requests new samples as it hands indices out.

    Invariants (property-tested):
      * every index is yielded exactly once, in sub-sampler order;
      * each index appears in exactly one fetch round before (or at) the
        step where it is consumed;
      * a round is emitted exactly when pending (announced-unconsumed)
        would otherwise drop below ``prefetch_threshold``;
      * round sizes are ``fetch_size`` except possibly the last.
    """

    #: Flight-recorder provenance (ISSUE 10): the policy family stamped on
    #: every ``issue`` event this planner's rounds produce.  The paper's
    #: knob-driven heuristics (50/50, full-fetch, threshold sweeps) all
    #: plan here.
    provenance = "paper"

    def __init__(self, order: Sequence[int], config: PrefetchConfig):
        self.order = list(order)
        self.config = config
        self.rounds_issued = 0

    def announce_schedule(self) -> List[Tuple[int, List[int]]]:
        """The epoch's fetch rounds as ``(consume_position, round)`` pairs,
        ascending in position: the round is announced immediately *before*
        the access at that position.  Purely positional — the knob-driven
        policy never inspects cache state — so the vector engine can
        precompute it and batch the demand reads between announce points
        (``repro.engine.vector``).  ``__iter__`` delegates here, keeping
        this the ONE statement of announce timing."""
        cfg = self.config
        n = len(self.order)
        schedule: List[Tuple[int, List[int]]] = []
        if not cfg.enabled:
            return schedule
        announced = 0  # prefix of `order` announced to the service
        consumed = 0
        while consumed < n:
            pending = announced - consumed
            # Announce the next round when at/below the threshold (threshold
            # 0 => only when the queue is fully depleted).
            if pending <= cfg.prefetch_threshold and announced < n:
                round_ = self.order[announced : announced + cfg.fetch_size]
                announced += len(round_)
                schedule.append((consumed, round_))
            consumed += 1
        return schedule

    def __iter__(self) -> Iterator[Tuple[int, Optional[List[int]]]]:
        rounds = {pos: round_ for pos, round_ in self.announce_schedule()}
        for consumed, idx in enumerate(self.order):
            round_ = rounds.get(consumed)
            if round_ is not None:
                self.rounds_issued += 1
            yield idx, round_

    def fetch_rounds(self) -> List[List[int]]:
        """All rounds, ignoring consumption interleaving (for cost model)."""
        return [r for _, r in self if r is not None]


def expected_rounds(n_samples: int, config: PrefetchConfig) -> int:
    """ceil(m / f) — the listing multiplier in cost Eq. 5."""
    if not config.enabled or n_samples == 0:
        return 0
    return -(-n_samples // config.fetch_size)


def validate_config_against_cache(config: PrefetchConfig) -> List[str]:
    """Lint a configuration; returns human-readable warnings.

    Encodes the paper's findings: cache < fetch size wastes fetches (§V-D
    Fig. 7); cache > fetch + threshold buys nothing; the 50/50 point is the
    recommended optimum.
    """
    warnings = []
    if not config.enabled:
        return warnings
    c = config.cache_items
    if c is not None:
        if c < config.fetch_size:
            warnings.append(
                f"cache_items={c} < fetch_size={config.fetch_size}: fetched samples "
                "evict each other before they are trained on (Fig. 7 regime)"
            )
        if config.prefetch_threshold + config.fetch_size > c:
            warnings.append(
                "threshold + fetch_size exceeds cache: an in-flight fetch can evict "
                "not-yet-consumed samples"
            )
        # `2 * fetch_size + 1` so the 50/50 construction (f = T = c // 2)
        # never trips this on an odd cache size — c = 2*(c//2) + 1 is the
        # 50/50 point itself, not excess capacity.
        if c > 2 * config.fetch_size + 1 and config.prefetch_threshold <= c // 2:
            warnings.append(
                f"cache_items={c} > 2*fetch_size: extra capacity beyond 2x fetch size "
                "does not reduce miss rate (paper Fig. 7); consider the 50/50 config"
            )
    if config.prefetch_threshold > 0 and config.prefetch_threshold < config.fetch_size // 4:
        warnings.append("very small nonzero threshold behaves like threshold=0")
    return warnings
