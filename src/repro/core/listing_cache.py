"""Listing cache (beyond-paper cost optimization, paper §VI).

The DELI prototype lists the entire bucket on *every* fetch round, costing
``ceil(m/p)`` Class A requests per round (Eq. 5's multiplier).  The paper's
discussion section proposes caching the listing per node — one listing per
node per session — which collapses the Class A term of Eq. 5 back to Eq. 4.

``ttl_s`` optionally re-validates the listing (online-learning buckets where
objects arrive continuously); ``ttl_s=None`` lists exactly once.
"""
from __future__ import annotations

import threading
from typing import List, Optional

from repro.core.clock import Clock, RealClock
from repro.core.store import SampleStore


class ListingCache:
    def __init__(self, ttl_s: Optional[float] = None, clock: Optional[Clock] = None):
        self.ttl_s = ttl_s
        self.clock = clock or RealClock()
        self._lock = threading.Lock()
        self._listing: Optional[List[int]] = None
        self._listed_at: float = float("-inf")
        self.lists_issued = 0
        self.lists_served_from_cache = 0

    def list(self, store: SampleStore) -> List[int]:
        with self._lock:
            now = self.clock.now()
            fresh = self._listing is not None and (
                self.ttl_s is None or now - self._listed_at < self.ttl_s
            )
            if fresh:
                self.lists_served_from_cache += 1
                assert self._listing is not None
                return list(self._listing)
        listing = store.list_objects()
        with self._lock:
            self._listing = listing
            self._listed_at = self.clock.now()
            self.lists_issued += 1
        return list(listing)

    def invalidate(self) -> None:
        with self._lock:
            self._listing = None
