"""Clock abstraction: real, scaled, and virtual time.

The threaded pipeline runs against a ``Clock`` so that the *same* mechanism
code can run (a) in production against wall time, (b) in integration tests
against a scaled wall clock (simulated I/O durations shrunk by ``scale`` so a
"400 second" bucket epoch takes 40 ms of test time while preserving every
ratio the paper's results depend on), and (c) inside the discrete-event
simulator against pure virtual time.

Lock-step note (ISSUE 3): the lock-step runtime gives every node its own
``VirtualClock`` and sleeps the *same component sequence* the simulator
adds to its scalar time — each hop is one ``_t += seconds`` with identical
float operands, so the two timelines are bit-equal and the interleaved
cluster schedules coincide (docs/PARITY.md).  ``advance_to`` is the BSP
epoch-barrier primitive (monotonic jump, never backwards).
"""
from __future__ import annotations

import threading
import time
from typing import Protocol


class Clock(Protocol):
    def now(self) -> float: ...

    def sleep(self, seconds: float) -> None: ...


class RealClock:
    """Wall clock. ``scale`` < 1 shrinks simulated sleeps (I/O models only —

    never used to scale *measured* durations; measurements divide by scale
    to report virtual seconds)."""

    def __init__(self, scale: float = 1.0):
        if scale <= 0:
            raise ValueError("scale must be positive")
        self.scale = scale

    def now(self) -> float:
        return time.monotonic() / self.scale

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds * self.scale)


class VirtualClock:
    """Manually advanced clock for the discrete-event simulator.

    Thread-safe advance so the (single-threaded) simulator and property
    tests can share it; ``sleep`` advances time directly — there is no
    blocking in virtual time.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def advance(self, seconds: float) -> float:
        if seconds < 0:
            raise ValueError(f"cannot advance time backwards ({seconds})")
        with self._lock:
            self._t += seconds
            return self._t

    def advance_to(self, t: float) -> float:
        with self._lock:
            if t > self._t:
                self._t = t
            return self._t
