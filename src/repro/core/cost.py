"""GCP cost model — paper §III-C, Eq. (1)-(5), plus Table II reproduction.

Symbols (paper names kept):
    n    number of nodes
    s_r  per-node OS+deps disk, GB
    s_t  dataset size, GB
    m    number of samples
    m_c  samples held in each node's cache
    e    epochs
    p    bucket listing page size
    f    fetch size
    t_c  compute seconds (per run)
    t_d  data-wait seconds (per run)
    c_c  VM $/hour            c_d  disk $/GB/month
    c_b  bucket $/GB/month    c_A/c_B  $ per 10,000 requests

Constants below reproduce Table II's structure: a 16 GB boot disk at GCP
pd-standard pricing gives the paper's $0.65/node storage line; the VM rate
is the n1-highmem-2 + K80 list price with a calibration factor fitted so the
'Compute + Loading' column of Table II is matched (the paper's exact
machine-hour accounting isn't published; we document the fit and verify the
qualitative claims — orderings and which configurations save money).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class GcpPrices:
    vm_hourly: float = 0.95  # $/h: n1-highmem-2 ($0.1184) + K80 ($0.45), x calibration 1.67
    disk_gb_month: float = 0.04  # pd-standard
    bucket_gb_month: float = 0.026  # GCS standard regional
    class_a_per_10k: float = 0.05  # listing (paper §III-C)
    class_b_per_10k: float = 0.002  # GETs (paper §III-C)
    page_size: int = 1000


@dataclasses.dataclass(frozen=True)
class WorkloadCostInputs:
    n_nodes: int
    os_disk_gb: float  # s_r
    dataset_gb: float  # s_t
    n_samples: int  # m
    epochs: int  # e
    compute_seconds: float  # t_c  (whole run, per node)
    data_wait_seconds: float  # t_d (whole run, per node)
    cached_samples: int = 0  # m_c
    fetch_size: int = 0  # f (0 = no prefetching)
    months: float = 1.0  # billing horizon for storage lines
    # Cooperative peer-cache tier: per-epoch sample reads served from a
    # peer node's cache — each one is a Class B GET that was never issued
    # (beyond-paper; measured as EpochStats.peer_hits by the simulator).
    peer_served_samples: int = 0


def _tau(prices: GcpPrices, inp: WorkloadCostInputs) -> float:
    """Eq. (2): tau = c_c * (t_c + t_d)."""
    hours = (inp.compute_seconds + inp.data_wait_seconds) / 3600.0
    return prices.vm_hourly * hours


def cost_disk_baseline(prices: GcpPrices, inp: WorkloadCostInputs) -> dict:
    """Eq. (1): the dataset is stored on every node's disk."""
    storage = prices.disk_gb_month * (inp.dataset_gb + inp.os_disk_gb) * inp.months
    tau = _tau(prices, inp)
    return {
        "api": 0.0,
        "storage": inp.n_nodes * storage,
        "compute_loading": inp.n_nodes * tau,
        "total": inp.n_nodes * (storage + tau),
    }


def _alpha(prices: GcpPrices, inp: WorkloadCostInputs, with_prefetch: bool) -> float:
    """Eq. (4) / Eq. (5): per-epoch request charge in 'per-10k' units.

    ``peer_served_samples`` (beyond-paper peer-cache tier) subtracts the
    GETs that never reached the bucket from the Class B term.
    """
    m, n, p = inp.n_samples, inp.n_nodes, prices.page_size
    listings = n * math.ceil(m / p)
    if with_prefetch:
        if inp.fetch_size <= 0:
            raise ValueError("prefetch cost model needs fetch_size > 0")
        listings *= math.ceil(m / inp.fetch_size)  # naive per-fetch listing
    gets = max(0, m - inp.peer_served_samples)
    return listings * prices.class_a_per_10k + gets * prices.class_b_per_10k


def cost_with_peer_cache(
    prices: GcpPrices,
    inp: WorkloadCostInputs,
    peer_hits_per_epoch: int,
    with_prefetch: bool = False,
) -> dict:
    """Beyond-paper: the cooperative peer-cache tier.

    ``peer_hits_per_epoch`` is the cluster-wide count of *avoided Class B
    GETs* per epoch: sum of ``EpochStats.peer_hits`` over nodes (the
    simulator folds pre-fetch pulls in).  For the threaded runtime use
    demand ``EpochStats.peer_hits`` plus ``PrefetchService.peer_fetches``
    (winner-only) — NOT ``PeerStore.peer_hits``, which counts every
    physical peer read including hedged duplicates that avoided no GET.
    Intra-zone VM-to-VM traffic is free on GCP, so the entire effect is
    avoided Class B requests; VM time changes enter through the measured
    ``data_wait_seconds``.
    """
    peered = dataclasses.replace(inp, peer_served_samples=peer_hits_per_epoch)
    return cost_bucket(prices, peered, with_prefetch=with_prefetch)


def cost_bucket(
    prices: GcpPrices, inp: WorkloadCostInputs, with_prefetch: bool = False
) -> dict:
    """Eq. (3) with alpha from Eq. (4) (baseline) or Eq. (5) (DELI)."""
    m = inp.n_samples
    bucket_storage = prices.bucket_gb_month * inp.dataset_gb * inp.months
    per_node_disk = prices.disk_gb_month * (
        inp.os_disk_gb + (inp.dataset_gb / m) * inp.cached_samples
    ) * inp.months
    tau = _tau(prices, inp)
    api = 1e-4 * inp.epochs * _alpha(prices, inp, with_prefetch)
    return {
        "api": api,
        "storage": bucket_storage + inp.n_nodes * per_node_disk,
        "compute_loading": inp.n_nodes * tau,
        "total": bucket_storage + inp.n_nodes * (per_node_disk + tau) + api,
    }


def cost_with_listing_cache(prices: GcpPrices, inp: WorkloadCostInputs) -> dict:
    """Beyond-paper (§VI): one listing per node per session, not per fetch."""
    base = cost_bucket(prices, inp, with_prefetch=False)
    # alpha reverts to Eq. (4) but listings are NOT repeated every epoch:
    m, n, p = inp.n_samples, inp.n_nodes, prices.page_size
    api = 1e-4 * (
        n * math.ceil(m / p) * prices.class_a_per_10k
        + inp.epochs * m * prices.class_b_per_10k
    )
    base = dict(base)
    base["api"] = api
    base["total"] = base["storage"] + base["compute_loading"] + api
    return base


def cost_with_supersamples(
    prices: GcpPrices, inp: WorkloadCostInputs, group_size: int
) -> dict:
    """Beyond-paper (§VI): grouping ``group_size`` samples per object divides
    the Class B request count (and the listing length) by the group size."""
    m_groups = math.ceil(inp.n_samples / group_size)
    grouped = dataclasses.replace(inp, n_samples=m_groups)
    fetch_groups = max(1, inp.fetch_size // group_size) if inp.fetch_size else 0
    grouped = dataclasses.replace(grouped, fetch_size=fetch_groups)
    return cost_bucket(prices, grouped, with_prefetch=inp.fetch_size > 0)
