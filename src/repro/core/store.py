"""Sample stores: the data sources DELI loads from.

Three implementations of one interface:

  * ``SimulatedBucketStore`` — an in-memory object store whose timing follows
    the calibrated ``BucketModel`` (this container has no cloud); request
    accounting (Class A/B) feeds the cost model.  This is the stand-in for
    GCS; the interface is the integration point for a real client.
  * ``FileSystemStore``      — real local files (the paper's disk baseline);
    can also *simulate* disk timing via ``DiskModel`` for deterministic
    benchmarks.
  * ``InMemoryStore``        — zero-latency store for unit tests.

``ReliableStore`` wraps any store with retry + exponential backoff and
hedged requests (issue a duplicate GET once the first exceeds a deadline) —
the fault-tolerance / straggler-mitigation layer required at pod scale,
where a 512-host job sees slow/failed GETs every step.
"""
from __future__ import annotations

import abc
import math
import os
import random
import threading
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.bandwidth import BucketModel, DiskModel
from repro.core.clock import Clock, RealClock
from repro.core.types import StoreStats


class StoreError(RuntimeError):
    pass


class SampleStore(abc.ABC):
    """Abstract sample source keyed by integer dataset index.

    Every store carries a ``clock`` (wall time by default); wrappers and
    services read it directly instead of duck-typing ``getattr(store,
    "clock")`` — it is part of the interface.
    """

    def __init__(self) -> None:
        self.stats = StoreStats()
        self.clock: Clock = RealClock()
        self._stats_lock = threading.Lock()

    @abc.abstractmethod
    def get(self, index: int) -> bytes:
        """Fetch one object (a Class B request for bucket stores)."""

    @abc.abstractmethod
    def size_of(self, index: int) -> int:
        """Object size in bytes without fetching (metadata)."""

    @abc.abstractmethod
    def list_objects(self) -> List[int]:
        """List all object indices (Class A request(s) for bucket stores)."""

    def __len__(self) -> int:
        return len(self.list_objects())

    def _account(self, *, a: int = 0, b: int = 0, nbytes: int = 0, seconds: float = 0.0) -> None:
        with self._stats_lock:
            self.stats.class_a_requests += a
            self.stats.class_b_requests += b
            self.stats.bytes_read += nbytes
            self.stats.read_seconds += seconds


class InMemoryStore(SampleStore):
    """Latency-free store for unit tests."""

    def __init__(self, payloads: Dict[int, bytes]):
        super().__init__()
        self._payloads = dict(payloads)

    def get(self, index: int) -> bytes:
        try:
            payload = self._payloads[index]
        except KeyError as e:
            raise StoreError(f"no object {index}") from e
        self._account(b=1, nbytes=len(payload))
        return payload

    def size_of(self, index: int) -> int:
        return len(self._payloads[index])

    def list_objects(self) -> List[int]:
        self._account(a=1)
        return sorted(self._payloads)


class SimulatedBucketStore(SampleStore):
    """GCS-bucket stand-in with Table-I-calibrated timing.

    ``get`` sleeps the modelled GET duration on the injected clock; with a
    scaled ``RealClock`` the ratios of the paper's experiments are preserved
    while tests run in milliseconds.  Thread-safe: concurrent ``get`` calls
    model independent connections (the thread pool's sub-linear scaling is
    applied by callers that know their fan-out, e.g. the pre-fetch service,
    via ``penalty``).
    """

    def __init__(
        self,
        payloads: Dict[int, bytes],
        model: Optional[BucketModel] = None,
        clock: Optional[Clock] = None,
        failure_rate: float = 0.0,
        seed: int = 0,
    ):
        super().__init__()
        self._payloads = dict(payloads)
        self.model = model or BucketModel()
        self.clock = clock or RealClock()
        self.failure_rate = failure_rate
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()

    def _maybe_fail(self) -> None:
        if self.failure_rate > 0.0:
            with self._rng_lock:
                r = self._rng.random()
            if r < self.failure_rate:
                raise StoreError("simulated transient bucket error (503)")

    def get(self, index: int, penalty: float = 1.0) -> bytes:
        """One GET. ``penalty`` >= 1 stretches the duration (shared NIC)."""
        try:
            payload = self._payloads[index]
        except KeyError as e:
            raise StoreError(f"no object {index}") from e
        dt = self.model.get_seconds(len(payload)) * penalty
        self._maybe_fail()
        self.clock.sleep(dt)
        self._account(b=1, nbytes=len(payload), seconds=dt)
        return payload

    def size_of(self, index: int) -> int:
        return len(self._payloads[index])

    def bulk_get(self, indices: Sequence[int], n_connections: int = 16) -> List[bytes]:
        """Parallel batch GET (what the pre-fetch service issues).

        GCS has no batch-download API (§II-B), so the service 'simulates a
        batch download by downloading multiple files in parallel' (§IV-C).
        Duration follows the calibrated sub-linear thread-pool model; one
        Class B request is billed per object.
        """
        payloads = []
        for i in indices:
            try:
                payloads.append(self._payloads[i])
            except KeyError as e:
                raise StoreError(f"no object {i}") from e
        self._maybe_fail()
        dt = self.model.bulk_get_seconds([len(p) for p in payloads], n_connections)
        self.clock.sleep(dt)
        self._account(b=len(payloads), nbytes=sum(len(p) for p in payloads), seconds=dt)
        return payloads

    def list_objects(self) -> List[int]:
        keys = sorted(self._payloads)
        pages = max(1, math.ceil(len(keys) / self.model.page_size))
        self.clock.sleep(self.model.list_seconds(len(keys)))
        self._account(a=pages)
        return keys


class FileSystemStore(SampleStore):
    """Local-disk store (the paper's disk baseline).

    With ``simulate_timing=True`` reads additionally sleep the DiskModel
    duration so benchmark ratios are deterministic on any machine.
    """

    def __init__(
        self,
        root: str,
        model: Optional[DiskModel] = None,
        clock: Optional[Clock] = None,
        simulate_timing: bool = False,
    ):
        super().__init__()
        self.root = root
        self.model = model or DiskModel()
        self.clock = clock or RealClock()
        self.simulate_timing = simulate_timing

    @staticmethod
    def path_for(root: str, index: int) -> str:
        return os.path.join(root, f"{index:08d}.bin")

    @classmethod
    def write_dataset(cls, root: str, payloads: Dict[int, bytes]) -> "FileSystemStore":
        os.makedirs(root, exist_ok=True)
        for i, p in payloads.items():
            with open(cls.path_for(root, i), "wb") as f:
                f.write(p)
        return cls(root)

    def get(self, index: int) -> bytes:
        path = self.path_for(self.root, index)
        try:
            with open(path, "rb") as f:
                payload = f.read()
        except FileNotFoundError as e:
            raise StoreError(f"no object {index}") from e
        dt = self.model.get_seconds(len(payload)) if self.simulate_timing else 0.0
        if dt:
            self.clock.sleep(dt)
        self._account(b=1, nbytes=len(payload), seconds=dt)
        return payload

    def size_of(self, index: int) -> int:
        return os.path.getsize(self.path_for(self.root, index))

    def list_objects(self) -> List[int]:
        self._account(a=1)
        return sorted(
            int(name.split(".")[0]) for name in os.listdir(self.root) if name.endswith(".bin")
        )


class ReliableStore(SampleStore):
    """Retry + hedging wrapper: the data-plane fault-tolerance layer.

    * Transient ``StoreError``s are retried with exponential backoff
      (``base_backoff * 2**attempt``), up to ``max_attempts``.
    * Straggler mitigation: if a GET exceeds ``hedge_after_s`` the caller
      may issue a duplicate request ("request hedging", beyond-paper; in
      the threaded runtime this is realized by the pre-fetch service's
      per-request deadline — see prefetcher.py).  Here we count hedges.
    """

    def __init__(
        self,
        inner: SampleStore,
        max_attempts: int = 5,
        base_backoff_s: float = 0.01,
        clock: Optional[Clock] = None,
        on_retry: Optional[Callable[[int, Exception], None]] = None,
    ):
        super().__init__()
        self.inner = inner
        self.max_attempts = max_attempts
        self.base_backoff_s = base_backoff_s
        self.clock = clock or inner.clock
        self.on_retry = on_retry
        self.retries = 0
        self.hedges = 0

    def get(self, index: int, **kw) -> bytes:
        last: Optional[Exception] = None
        for attempt in range(self.max_attempts):
            try:
                return self.inner.get(index, **kw) if kw else self.inner.get(index)
            except StoreError as e:  # transient class
                last = e
                self.retries += 1
                if self.on_retry:
                    self.on_retry(attempt, e)
                self.clock.sleep(self.base_backoff_s * (2.0**attempt))
        raise StoreError(f"GET {index} failed after {self.max_attempts} attempts: {last}")

    def size_of(self, index: int) -> int:
        return self.inner.size_of(index)

    def list_objects(self) -> List[int]:
        last: Optional[Exception] = None
        for attempt in range(self.max_attempts):
            try:
                return self.inner.list_objects()
            except StoreError as e:
                last = e
                self.retries += 1
                self.clock.sleep(self.base_backoff_s * (2.0**attempt))
        raise StoreError(f"LIST failed after {self.max_attempts} attempts: {last}")

    @property
    def stats(self) -> StoreStats:  # type: ignore[override]
        return self.inner.stats

    @stats.setter
    def stats(self, v: StoreStats) -> None:
        # abc __init__ assigns; route to inner when present, else stash.
        if hasattr(self, "inner"):
            self.inner.stats = v
        else:
            self.__dict__["_pre_init_stats"] = v


def make_synthetic_payloads(
    n: int, sample_bytes: int, seed: int = 0
) -> Dict[int, bytes]:
    """Deterministic pseudo-random payloads (index-tagged for integrity checks)."""
    rng = random.Random(seed)
    out = {}
    for i in range(n):
        head = i.to_bytes(8, "little")
        body = rng.randbytes(max(0, sample_bytes - 8))
        out[i] = head + body
    return out
