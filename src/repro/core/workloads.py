"""Workload specs for the paper's experiments and for TPU-scale goodput
analysis.

The two paper workloads (§V-A) with their measured compute times (§V-B:
"our models spent an average of 14.7 s and 147.2 s training on MNIST and
CIFAR-10 respectively" per epoch):
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    n_samples: int  # training-set size
    sample_bytes: int  # raw sample payload
    batch_size: int
    compute_per_epoch_s: float  # per-node compute time for its partition
    n_nodes: int = 3  # the paper's fixed 3-node setup

    @property
    def partition_size(self) -> int:
        return self.n_samples // self.n_nodes

    @property
    def batches_per_epoch(self) -> int:
        return self.partition_size // self.batch_size

    @property
    def compute_per_batch_s(self) -> float:
        return self.compute_per_epoch_s / max(1, self.batches_per_epoch)

    @property
    def dataset_gb(self) -> float:
        return self.n_samples * self.sample_bytes / 1e9

    def scaled(self, factor: float) -> "WorkloadSpec":
        """Shrink a workload for fast tests, preserving every ratio that the
        paper's results depend on (compute:fetch balance, partition:batch)."""
        n = max(self.n_nodes * self.batch_size, int(self.n_samples * factor))
        return dataclasses.replace(
            self,
            name=f"{self.name}-x{factor:g}",
            n_samples=n,
            compute_per_epoch_s=self.compute_per_epoch_s * (n / self.n_samples),
        )


# MNIST: 60k train images, 28x28 grayscale = 784 B raw; 2-conv CNN.
MNIST = WorkloadSpec(
    name="mnist-cnn",
    n_samples=60_000,
    sample_bytes=784,
    batch_size=256,
    compute_per_epoch_s=14.7,
)

# CIFAR-10: 50k train images, 32x32x3 = 3072 B raw; ResNet-50 (~15x slower
# per batch than the CNN, §V-D).
CIFAR10 = WorkloadSpec(
    name="cifar10-resnet50",
    n_samples=50_000,
    sample_bytes=3072,
    batch_size=256,
    compute_per_epoch_s=147.2,
)

PAPER_WORKLOADS = {w.name: w for w in (MNIST, CIFAR10)}


def lm_token_workload(
    name: str,
    seq_len: int,
    global_batch: int,
    steps_per_epoch: int,
    step_time_s: float,
    n_hosts: int,
    bytes_per_token: int = 4,
) -> WorkloadSpec:
    """Cast an LM pre-training shard into the same pipeline vocabulary:
    one 'sample' = one packed sequence of ``seq_len`` tokens.  Used by the
    TPU-scale goodput analysis (EXPERIMENTS.md §Perf) to size fetch/threshold
    for the assigned architectures."""
    return WorkloadSpec(
        name=name,
        n_samples=global_batch * steps_per_epoch,
        sample_bytes=seq_len * bytes_per_token,
        batch_size=max(1, global_batch // n_hosts),
        compute_per_epoch_s=step_time_s * steps_per_epoch,
        n_nodes=n_hosts,
    )
