"""Discrete-event simulator of the DELI node pipeline.

Why a simulator: the container has no cloud and no wall-clock budget for
hundred-second epochs; the paper's results are *timing races* between the
training loop and the pre-fetch service.  The simulator advances a virtual
clock through exactly the mechanism the threaded runtime implements — same
``PrefetchPlanner`` policy object, same ``CappedCache`` class, same
calibrated ``BucketModel`` — so its predictions are the runtime's behaviour
(property-tested against the threaded pipeline in
tests/test_core_sim_and_cost.py).

Event structure (single service worker, paper §IV-C: one subprocess per
request on a 2-vCPU VM => effectively serialized):

  * the training loop is the driving process: it consumes samples in
    planner order, paying hit/miss latencies and per-batch compute;
  * fetch rounds queue on the service; round r starts at
    max(request time, completion of round r-1), runs for the calibrated
    bulk duration, and bulk-inserts at completion;
  * cache inserts/evictions are applied lazily: before each lookup, all
    rounds with completion <= now are folded into the cache.

Measured outputs per epoch = the paper's metrics: miss rate, data-wait.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.bandwidth import (
    DEFAULT_BUCKET,
    DEFAULT_DISK,
    DEFAULT_PIPELINE,
    BucketModel,
    DiskModel,
    PipelineCostModel,
)
from repro.core.cache import CappedCache
from repro.core.policy import PrefetchConfig, PrefetchPlanner
from repro.core.sampler import DistributedPartitionSampler, LocalityAwareSampler
from repro.core.types import EpochStats, StoreStats
from repro.core.workloads import WorkloadSpec

_SENTINEL = b"\x00"  # cache payloads are placeholders; experiments count items


@dataclasses.dataclass
class SimConfig:
    """One experimental condition (a bar in the paper's figures)."""

    source: str = "bucket"  # "bucket" | "disk"
    cache_items: Optional[int] = None  # None = no cache; 0 < n = capped; -1 = unlimited
    prefetch: Optional[PrefetchConfig] = None  # None = no prefetching
    n_connections: int = 16
    streaming_insert: bool = False  # beyond-paper knob
    list_every_fetch: bool = True  # paper prototype; False = listing cache
    locality_aware: bool = False  # beyond-paper partitioner

    def label(self) -> str:
        if self.source == "disk":
            return "disk"
        if self.cache_items is None:
            return "gcp-direct"
        cache = "unlimited" if self.cache_items == -1 else str(self.cache_items)
        if self.prefetch is None:
            return f"cache[{cache}]"
        return (
            f"cache[{cache}]+pf(f={self.prefetch.fetch_size},"
            f"T={self.prefetch.prefetch_threshold})"
        )


@dataclasses.dataclass
class _ServiceState:
    free_at: float = 0.0
    pending: List[Tuple[float, List[int]]] = dataclasses.field(default_factory=list)
    rounds: int = 0


class NodeSimulator:
    """Simulates one node's data plane across epochs (cache persists)."""

    def __init__(
        self,
        spec: WorkloadSpec,
        cfg: SimConfig,
        bucket: BucketModel = DEFAULT_BUCKET,
        disk: DiskModel = DEFAULT_DISK,
        pipeline: PipelineCostModel = DEFAULT_PIPELINE,
    ):
        self.spec = spec
        self.cfg = cfg
        self.bucket = bucket
        self.disk = disk
        self.pipeline = pipeline
        self.t = 0.0
        self.store_stats = StoreStats()
        self.cache: Optional[CappedCache] = None
        if cfg.cache_items is not None:
            max_items = None if cfg.cache_items == -1 else cfg.cache_items
            self.cache = CappedCache(max_items=max_items)
        self.service = _ServiceState()

    # -- store timing --------------------------------------------------------
    def _sequential_get_s(self) -> float:
        return self.bucket.get_seconds(self.spec.sample_bytes)

    def _bulk_get_s(self, n: int) -> float:
        return self.bucket.bulk_get_seconds(
            [self.spec.sample_bytes] * n, self.cfg.n_connections
        )

    # -- service -------------------------------------------------------------
    def _issue_round(self, keys: List[int]) -> None:
        start = max(self.t, self.service.free_at)
        listing_s = 0.0
        if self.cfg.list_every_fetch or self.service.rounds == 0:
            listing_s = self.bucket.list_seconds(self.spec.n_samples)
            self.store_stats.class_a_requests += max(
                1, -(-self.spec.n_samples // self.bucket.page_size)
            )
        # The round's keys are known when it is issued, so the (naive)
        # per-round listing proceeds CONCURRENTLY with the parallel GETs —
        # it is pure Class A accounting traffic, not a serialization point.
        dur = max(listing_s, self._bulk_get_s(len(keys)))
        done = start + dur
        self.store_stats.class_b_requests += len(keys)
        self.store_stats.bytes_read += len(keys) * self.spec.sample_bytes
        self.store_stats.read_seconds += dur
        if self.cfg.streaming_insert:
            # Spread inserts uniformly across the round duration.
            per = dur / len(keys)
            for j, k in enumerate(keys):
                self.service.pending.append((start + per * (j + 1), [k]))
        else:
            self.service.pending.append((done, list(keys)))
        self.service.free_at = done
        self.service.rounds += 1

    def _apply_completed_inserts(self) -> None:
        assert self.cache is not None
        remaining = []
        for done, keys in self.service.pending:
            if done <= self.t:
                for k in keys:
                    self.cache.put(k, _SENTINEL)
            else:
                remaining.append((done, keys))
        self.service.pending = remaining

    # -- sample access -------------------------------------------------------
    def _access(self, idx: int, stats: EpochStats) -> None:
        pipeline = self.pipeline
        wait = pipeline.cpu_overhead_s
        if self.cfg.source == "disk":
            wait += self.disk.get_seconds(self.spec.sample_bytes)
            stats.misses += 1  # no cache in the disk baseline; count as miss=read
        elif self.cache is None:
            # Direct-from-bucket baseline: sequential fallback GET.
            wait += self._sequential_get_s()
            stats.misses += 1
            self.store_stats.class_b_requests += 1
            self.store_stats.bytes_read += self.spec.sample_bytes
        else:
            self._apply_completed_inserts()
            if self.cache.get(idx) is not None:
                wait += pipeline.ram_hit_s
                stats.hits += 1
                stats.ram_hits += 1
            else:
                wait += self._sequential_get_s()
                stats.misses += 1
                self.store_stats.class_b_requests += 1
                self.store_stats.bytes_read += self.spec.sample_bytes
                if self.cfg.prefetch is None:
                    # Cache-only mode inserts on miss (paper §IV-B); with a
                    # pre-fetch service the worker does not (§IV-C).
                    self.cache.put(idx, _SENTINEL)
        self.t += wait
        stats.samples += 1
        stats.data_wait_seconds += wait

    # -- epoch ----------------------------------------------------------------
    def run_epoch(self, epoch: int, order: Sequence[int], node: int = 0) -> EpochStats:
        stats = EpochStats(epoch=epoch, node=node)
        ev0 = self.cache.stats.evictions if self.cache else 0
        pf = self.cfg.prefetch if self.cfg.prefetch is not None else PrefetchConfig.disabled()
        if self.cfg.source == "disk" or self.cache is None:
            pf = PrefetchConfig.disabled()
        planner = PrefetchPlanner(order, pf)
        samples_in_batch = 0
        for idx, round_ in planner:
            if round_ is not None:
                self._issue_round(list(round_))
            self._access(idx, stats)
            samples_in_batch += 1
            if samples_in_batch == self.spec.batch_size:
                self.t += self.spec.compute_per_batch_s
                stats.compute_seconds += self.spec.compute_per_batch_s
                samples_in_batch = 0
        if self.cache:
            stats.evictions = self.cache.stats.evictions - ev0
        return stats


def simulate_cluster(
    spec: WorkloadSpec,
    cfg: SimConfig,
    epochs: int = 2,
    seed: int = 0,
    bucket: BucketModel = DEFAULT_BUCKET,
    disk: DiskModel = DEFAULT_DISK,
    pipeline: PipelineCostModel = DEFAULT_PIPELINE,
) -> Tuple[List[EpochStats], StoreStats]:
    """Run all nodes of the paper's setup for N epochs; returns per-node
    per-epoch stats + aggregate store accounting."""
    nodes = [NodeSimulator(spec, cfg, bucket, disk, pipeline) for _ in range(spec.n_nodes)]
    samplers: List = []
    for rank in range(spec.n_nodes):
        if cfg.locality_aware:
            samplers.append(
                LocalityAwareSampler(spec.n_samples, rank, spec.n_nodes, seed=seed)
            )
        else:
            samplers.append(
                DistributedPartitionSampler(spec.n_samples, rank, spec.n_nodes, seed=seed)
            )
    all_stats: List[EpochStats] = []
    for e in range(epochs):
        if cfg.locality_aware:
            views = [n.cache.keys() if n.cache else [] for n in nodes]
            for s in samplers:
                s.update_cache_views(views)
        for rank, (node, sampler) in enumerate(zip(nodes, samplers)):
            sampler.set_epoch(e)
            all_stats.append(node.run_epoch(e, sampler.indices(), node=rank))
    agg = StoreStats()
    for n in nodes:
        agg = agg.merge(n.store_stats)
    return all_stats, agg


def mean_miss_rate(stats: List[EpochStats], epoch: int) -> float:
    rows = [s for s in stats if s.epoch == epoch]
    return sum(r.miss_rate for r in rows) / len(rows)


def mean_data_wait(stats: List[EpochStats], epoch: int) -> float:
    rows = [s for s in stats if s.epoch == epoch]
    return sum(r.data_wait_seconds for r in rows) / len(rows)
