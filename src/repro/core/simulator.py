"""Discrete-event simulator of the DELI cluster, event-interleaved.

Why a simulator: the container has no cloud and no wall-clock budget for
hundred-second epochs; the paper's results are *timing races* between the
training loop and the pre-fetch service.  The simulator advances a virtual
clock through exactly the mechanism the threaded runtime implements — same
``PrefetchPlanner`` policy object, same ``CappedCache`` class, same
calibrated ``BucketModel``, and (since the lock-step refactor) literally
the same ``LockstepPrefetchService`` event code — so its predictions are
the runtime's behaviour, exactly (``pipeline.parity``).

Event structure per node (single service worker, paper §IV-C: one
subprocess per request on a 2-vCPU VM => effectively serialized):

  * the training loop is the driving process: it consumes samples in
    planner order, paying hit/miss latencies and per-batch compute;
  * fetch rounds queue on the service; round r starts at
    max(request time, completion of round r-1), runs for the calibrated
    bulk duration, and bulk-inserts at completion;
  * cache inserts/evictions are events applied at well-defined barriers:
    before each of the node's own lookups, and — interleaved mode — before
    every cluster-scheduler step, so *peers* observe them too.

Cluster structure (the tentpole of ISSUE 3): nodes no longer run their
epochs sequentially.  ``simulate_cluster`` keeps one event heap keyed by
``(virtual_time, rank)`` and always advances the node whose next sample
access is earliest, so a peer-cache lookup observes every other node's
*mid-epoch* cache state — fills and evictions alike — instead of an
epoch-boundary snapshot (the fidelity gap Hoard's cluster-level results
highlight, and the old sequential loop's documented bias).  Epoch
boundaries are BSP barriers: all nodes finish epoch ``e`` before any
starts ``e+1``, and clocks synchronize to the slowest node (data-parallel
training synchronizes gradients; the epoch boundary certainly
synchronizes).  ``interleaved=False`` preserves the legacy sequential
schedule for A/B comparisons (``benchmarks/fig10_peer_cache.py`` reports
the delta).

Granularity note: one event = one sample access (with any fetch round it
triggers).  A step spans several virtual-time components (peer RTT, GET,
CPU overhead); probes observe cluster state as of the step's start time.

Measured outputs per epoch = the paper's metrics: miss rate, data-wait.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Iterator, List, Optional, Sequence, Tuple

from repro.core.bandwidth import (
    DEFAULT_BUCKET,
    DEFAULT_DISK,
    DEFAULT_NETWORK,
    DEFAULT_PIPELINE,
    DEFAULT_PROFILE,
    BucketModel,
    CollectiveModel,
    DiskModel,
    NetworkModel,
    NodeProfile,
    PipelineCostModel,
)
from repro.core.cache import CappedCache
from repro.core.lockstep import (
    SENTINEL,
    STEP_BATCH_END,
    STEP_CONTINUE,
    STEP_DONE,
    BucketedBatchComm,
    LockstepPrefetchService,
    SubstepAccess,
    drive_interleaved_epoch,
    peer_probe_payload,
)
from repro.core.policy import PrefetchConfig, PrefetchPlanner
from repro.core.sampler import DistributedPartitionSampler, LocalityAwareSampler, Sampler
from repro.core.types import EpochStats, StoreStats, sequential_sum
from repro.core.workloads import WorkloadSpec
from repro.engine.kernels import DemandKernel
from repro.obs.events import (
    CacheTracer,
    TraceRecorder,
    trace_demand,
    trace_emit,
    trace_sync,
)

if TYPE_CHECKING:  # runtime import is deferred: repro.core is imported by
    # repro.distributed.peer_cache, so a module-level import here would be
    # circular for processes whose first repro import is repro.distributed.
    from repro.distributed.peer_cache import PeerCacheRegistry

_SENTINEL = SENTINEL  # cache payloads are placeholders; experiments count items


@dataclasses.dataclass
class SimConfig:
    """One experimental condition (a bar in the paper's figures)."""

    source: str = "bucket"  # "bucket" | "disk"
    cache_items: Optional[int] = None  # None = no cache; 0 < n = capped; -1 = unlimited
    prefetch: Optional[PrefetchConfig] = None  # None = no prefetching
    n_connections: int = 16
    streaming_insert: bool = False  # beyond-paper knob
    list_every_fetch: bool = True  # paper prototype; False = listing cache
    locality_aware: bool = False  # beyond-paper partitioner
    # Cooperative peer-cache tier: on a local miss, ask peers' caches over
    # the modelled inter-node network before falling back to the bucket.
    peer_cache: bool = False
    # Hoard-style replication-aware eviction: a member cache declines to
    # evict the last cluster-resident copy of a sample (needs peer_cache).
    replication_aware_eviction: bool = False
    # Cluster synchronization schedule (ISSUE 4): "epoch" = BSP barriers at
    # epoch boundaries only (the PR 3 schedule); "batch" = an allreduce
    # barrier after every gradient batch (data-parallel SGD), with per-node
    # waits accounted in EpochStats.allreduce_wait_seconds.
    sync: str = "epoch"
    # Allreduce cost model (ISSUE 8): gives the per-batch barrier a real
    # transfer duration (ring/tree over the calibrated NetworkModel,
    # profile-scaled per rank), accounted in allreduce_comm_seconds.
    # None = the historical instantaneous barrier, bit-for-bit.
    collective: Optional[CollectiveModel] = None
    # Communication/compute overlap: "none" charges the whole allreduce at
    # the barrier; "buckets" pipelines per-bucket allreduces against the
    # remaining backprop spans (BucketedBatchComm) so only the exposed
    # tail is charged.  Needs a collective model.
    overlap: str = "none"
    # Straggler mitigation (ISSUE 8): barrier releases once n-k running
    # ranks parked (the slowest k drop their partial gradient and skip the
    # barrier)...
    backup_workers: int = 0
    # ...or stale-synchronous parallel: a rank may run up to s batches
    # ahead of the last released barrier before parking.  Mutually
    # exclusive with backup_workers; both need sync="batch".
    staleness_bound: int = 0
    # Event granularity: "step" = one event per sample access (probes
    # observe state at the step's start); "substep" = every virtual-time
    # component is its own event (peer probes evaluate at arrival time and
    # prefetch rounds complete *inside* long accesses).
    granularity: str = "step"
    # Oracle data plane (ISSUE 5): "belady" plugs farthest-future-use
    # eviction (repro.oracle.BeladyEviction) behind the capped cache;
    # "oracle" replaces the fetch_size/threshold planner with the
    # clairvoyant OraclePrefetchPlanner.  Both need a local cache and the
    # bucket source; both stay exactly parity-checked.
    # "cluster-oracle" (ISSUE 7) adds the cross-rank placement plan on top:
    # one ClusterPlacementPlanner partitions the union of access orders so
    # each key is bucket-fetched by exactly ONE owner rank and served to
    # everyone else over the peer tier — hence it additionally requires
    # peer_cache and replayable samplers (not locality_aware).
    eviction: str = "fifo"  # "fifo" | "belady"
    prefetch_policy: str = "paper"  # "paper" | "oracle" | "cluster-oracle"
    # Clairvoyant round sizing (ISSUE 7 satellite): "ramp" = the historical
    # doubling ramp (pinned byte-for-byte); "cost" = sizes solved from the
    # calibrated bandwidth models against next-use deadlines
    # (repro.oracle.planner.RoundCostModel).  Needs a clairvoyant policy.
    round_sizing: str = "ramp"  # "ramp" | "cost"
    # Execution engine (ISSUE 6): "scalar" = the historical one-event-per-
    # sample Python stepper; "vector" = repro.engine.vector's segment
    # batcher, which advances runs of demand reads between cross-node
    # interaction points as numpy array ops.  Results are exactly equal
    # (``==``, docs/PARITY.md); the vector engine applies under the
    # interleaved cluster schedule and falls back to scalar stepping for
    # epochs whose exactness it cannot batch (peer registry attached, or
    # the legacy sequential schedule).
    engine: str = "scalar"  # "scalar" | "vector"
    # Flight recorder (ISSUE 10): a shared TraceRecorder observing the run.
    # Observe-only — ``None`` (the default) must leave every stat, schedule
    # and parity fingerprint byte-identical to an untraced run — and
    # excluded from ``label()``: tracing is not an experimental condition.
    trace: Optional[TraceRecorder] = None

    def __post_init__(self) -> None:
        if self.sync not in ("epoch", "batch"):
            raise ValueError(f"unknown sync {self.sync!r}")
        if self.granularity not in ("step", "substep"):
            raise ValueError(f"unknown granularity {self.granularity!r}")
        if self.engine not in ("scalar", "vector"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.eviction not in ("fifo", "belady"):
            raise ValueError(f"unknown eviction {self.eviction!r}")
        if self.prefetch_policy not in ("paper", "oracle", "cluster-oracle"):
            raise ValueError(f"unknown prefetch_policy {self.prefetch_policy!r}")
        if self.round_sizing not in ("ramp", "cost"):
            raise ValueError(f"unknown round_sizing {self.round_sizing!r}")
        if self.overlap not in ("none", "buckets"):
            raise ValueError(f"unknown overlap {self.overlap!r}")
        if self.collective is not None and self.sync != "batch":
            raise ValueError(
                "a collective cost model prices the per-batch allreduce; "
                "set sync='batch' (the epoch schedule has no such barrier)"
            )
        if self.overlap == "buckets" and self.collective is None:
            raise ValueError(
                "overlap='buckets' pipelines the allreduce against backprop; "
                "it needs a CollectiveModel (collective=...)"
            )
        if self.backup_workers < 0 or self.staleness_bound < 0:
            raise ValueError("backup_workers and staleness_bound must be >= 0")
        if (self.backup_workers or self.staleness_bound) and self.sync != "batch":
            raise ValueError(
                "straggler mitigation (backup_workers/staleness_bound) "
                "relaxes the per-batch barrier; set sync='batch'"
            )
        if self.backup_workers and self.staleness_bound:
            raise ValueError(
                "backup_workers and staleness_bound are mutually exclusive "
                "mitigation policies; pick one"
            )
        if self.eviction == "belady" and (
            self.cache_items is None or self.source == "disk"
        ):
            raise ValueError("eviction='belady' needs a local cache (bucket source)")
        if self.prefetch_policy in ("oracle", "cluster-oracle"):
            if self.cache_items is None or self.source == "disk":
                raise ValueError(
                    f"prefetch_policy={self.prefetch_policy!r} needs a local "
                    "cache (bucket source)"
                )
            if self.prefetch is not None:
                raise ValueError(
                    f"prefetch_policy={self.prefetch_policy!r} replaces the "
                    "fetch_size/threshold knobs; leave prefetch=None"
                )
        if self.prefetch_policy == "cluster-oracle":
            if not self.peer_cache:
                raise ValueError(
                    "prefetch_policy='cluster-oracle' serves non-owned keys "
                    "over the peer tier; set peer_cache=True"
                )
            if self.locality_aware:
                raise ValueError(
                    "prefetch_policy='cluster-oracle' needs replayable "
                    "samplers; the locality sampler's order depends on "
                    "runtime cache state"
                )
        if self.round_sizing == "cost" and self.prefetch_policy == "paper":
            raise ValueError(
                "round_sizing='cost' requires a clairvoyant prefetch_policy "
                "('oracle' or 'cluster-oracle')"
            )

    def label(self) -> str:
        sched = "+bsync" if self.sync == "batch" else ""
        if self.collective is not None:
            sched += "+comm"
        if self.overlap == "buckets":
            sched += "+ovl"
        if self.backup_workers:
            sched += f"+backup{self.backup_workers}"
        if self.staleness_bound:
            sched += f"+stale{self.staleness_bound}"
        if self.granularity == "substep":
            sched += "+substep"
        if self.source == "disk":
            return "disk" + sched
        if self.cache_items is None:
            return "gcp-direct" + sched
        cache = "unlimited" if self.cache_items == -1 else str(self.cache_items)
        peer = "+peer" if self.peer_cache else ""
        if self.peer_cache and self.replication_aware_eviction:
            peer += "+repl"
        if self.eviction == "belady":
            peer += "+belady"
        if self.prefetch_policy in ("oracle", "cluster-oracle"):
            sizing = ",cost" if self.round_sizing == "cost" else ""
            return f"cache[{cache}]{peer}+pf({self.prefetch_policy}{sizing}){sched}"
        if self.prefetch is None:
            return f"cache[{cache}]{peer}{sched}"
        return (
            f"cache[{cache}]{peer}+pf(f={self.prefetch.fetch_size},"
            f"T={self.prefetch.prefetch_threshold}){sched}"
        )


class NodeSimulator:
    """Simulates one node's data plane across epochs (cache persists).

    Virtual time advances in exactly the component sequence the lock-step
    runtime sleeps on its per-node clock (tier latency first, then the
    modelled training-loop overheads) — same floats, same order — so the
    two projections' event timelines are bit-identical and the interleaved
    cluster schedules coincide (see docs/PARITY.md).

    Epochs run through a stepper API so a cluster scheduler can interleave
    nodes: ``begin_epoch`` installs the epoch's planner, each ``step``
    processes one sample access (plus any fetch round it triggers), and
    ``finish_epoch`` returns the epoch's ``EpochStats``.  ``run_epoch``
    wraps the three for single-node use.
    """

    def __init__(
        self,
        spec: WorkloadSpec,
        cfg: SimConfig,
        bucket: BucketModel = DEFAULT_BUCKET,
        disk: DiskModel = DEFAULT_DISK,
        pipeline: PipelineCostModel = DEFAULT_PIPELINE,
        network: NetworkModel = DEFAULT_NETWORK,
        node_id: int = 0,
        profile: NodeProfile = DEFAULT_PROFILE,
    ):
        self.spec = spec
        self.cfg = cfg
        self.node_id = node_id
        # Flight recorder (ISSUE 10): observe-only; ``None`` makes every
        # emit a no-op and the schedule byte-identical to an untraced run.
        self._trace = cfg.trace
        self._cache_tracer: Optional[CacheTracer] = None
        # Straggler-aware: this node's calibrated models are rebuilt through
        # its profile (the default 1.0 multipliers are bitwise no-ops, so
        # homogeneous clusters keep their exact historical timelines).  The
        # lock-step runtime scales the same base models through the same
        # profile methods, which keeps straggler specs parity-exact.
        self.profile = profile
        self.bucket = profile.scale_bucket(bucket)
        self.disk = profile.scale_disk(disk)
        self.pipeline = profile.scale_pipeline(pipeline)
        self.network = profile.scale_network(network)
        self.compute_per_batch_s = profile.batch_compute_s(spec.compute_per_batch_s)
        # Allreduce cost (ISSUE 8): this rank's full-gradient duration over
        # its *profile-scaled* network (a straggler's slow NIC slows its
        # allreduce too).  The lock-step runtime computes the identical
        # float through the same scaled model.
        self.allreduce_s = 0.0
        self._overlap: Optional[BucketedBatchComm] = None
        if cfg.collective is not None:
            self.allreduce_s = cfg.collective.allreduce_seconds(
                self.network, spec.n_nodes
            )
            if cfg.overlap == "buckets":
                # parity-mirror: overlap-build begin mode=call-shape callee=BucketedBatchComm
                self._overlap = BucketedBatchComm(
                    now=lambda: self.t,
                    charge=self._charge,
                    compute_span_s=self.compute_per_batch_s
                    / cfg.collective.n_buckets,
                    bucket_comm_s=cfg.collective.bucket_seconds(
                        self.network, spec.n_nodes
                    ),
                    n_buckets=cfg.collective.n_buckets,
                    node=self.node_id,
                    trace=self._trace,
                )
                # parity-mirror: overlap-build end
        # THE per-sample cost arithmetic (repro.engine.kernels), shared by
        # this scalar stepper, the sub-step machine, the vector engine and
        # DeliLoader's runtime mirror.  Precomputed from the *scaled*
        # models, so straggler profiles are baked in.
        self.kernel = DemandKernel.from_models(
            bucket=self.bucket,
            disk=self.disk,
            network=self.network,
            pipeline=self.pipeline,
            sample_bytes=spec.sample_bytes,
        )
        self.t = 0.0
        # Oracle data plane (ISSUE 5): the clairvoyant planner replaces the
        # knob-driven one, and/or Belady replaces FIFO eviction.  Both hang
        # off a per-node NodeAccessView, installed by the cluster driver
        # (``attach_oracle_view``) or auto-created (current-epoch horizon)
        # for standalone single-node use at ``begin_epoch``.
        self._oracle_prefetch = cfg.prefetch_policy in ("oracle", "cluster-oracle")
        self._needs_oracle = self._oracle_prefetch or cfg.eviction == "belady"
        self.oracle_view = None  # repro.oracle.NodeAccessView when needed
        self._belady = None
        # Cluster placement (ISSUE 7): the cross-rank ownership planner,
        # installed by simulate_cluster for cluster-oracle specs.
        self._placement = None
        self._round_cost = None  # RoundCostModel for round_sizing="cost"
        if cfg.round_sizing == "cost":
            from repro.oracle.planner import RoundCostModel  # lazy (cycle rule)

            self._round_cost = RoundCostModel.from_models(
                bucket=self.bucket,
                pipeline=self.pipeline,
                sample_bytes=spec.sample_bytes,
                n_connections=cfg.n_connections,
            )
        # Mirror of RuntimeCluster's ``insert_on_miss``: the demand path
        # inserts into the cache exactly when no *active* pre-fetch service
        # owns population (paper §IV-B vs §IV-C) — a present-but-disabled
        # PrefetchConfig counts as inactive on both projections; the
        # clairvoyant planner counts as active.
        self._insert_on_miss = not (
            (cfg.prefetch is not None and cfg.prefetch.enabled)
            or self._oracle_prefetch
        )
        self.store_stats = StoreStats()
        self.cache: Optional[CappedCache] = None
        self.service: Optional[LockstepPrefetchService] = None
        if cfg.cache_items is not None:
            max_items = None if cfg.cache_items == -1 else cfg.cache_items
            if cfg.eviction == "belady":
                from repro.oracle.eviction import BeladyEviction  # lazy: no
                # module-level repro.core -> repro.oracle imports (cycle rule)

                self._belady = BeladyEviction()
            self.cache = CappedCache(max_items=max_items, eviction_policy=self._belady)
            if self._trace is not None:
                # Dedicated trace-listener slot: inserts/evictions recorded
                # at this node's clock (or the pinned round-completion time
                # during pre-fetch folds).
                self._cache_tracer = CacheTracer(
                    self._trace,
                    node_id,
                    now=lambda: self.t,
                    policy=self.cache.eviction_policy.name,
                )
                self.cache.set_trace_listener(
                    self._cache_tracer.on_insert, self._cache_tracer.on_evict
                )
            self.service = LockstepPrefetchService(
                self.cache,
                sample_bytes=spec.sample_bytes,
                n_samples=spec.n_samples,
                bucket=self.bucket,
                network=self.network,
                store_stats=self.store_stats,
                n_connections=cfg.n_connections,
                list_every_fetch=cfg.list_every_fetch,
                streaming_insert=cfg.streaming_insert,
                node_id=node_id,
                trace=self._trace,
            )
        # Cooperative peer-cache tier (set by simulate_cluster / tests).
        self.registry: Optional["PeerCacheRegistry"] = None
        # Epoch-in-progress state (stepper API).
        self._stats: Optional[EpochStats] = None
        self._planner = None  # the epoch's planner object (engines introspect it)
        self._planner_iter = None
        self._events: Optional[Iterator[int]] = None
        self._samples_in_batch = 0
        self._evictions_before = 0

    # -- sub-step port (the shared SubstepAccess closures) -------------------
    def _charge(self, seconds: float) -> None:
        self.t += seconds

    def _fold_own(self) -> None:
        if self.service is not None:
            self.service.advance_to(self.t)

    def _bucket_read(self, idx: int) -> bytes:
        """Bill one demand Class B GET (payloads are sentinels here)."""
        self.kernel.bill_demand_gets(self.store_stats)
        return _SENTINEL

    def _build_substep(self) -> Optional[SubstepAccess]:
        """The sub-step decomposition of this node's demand read, built at
        epoch start (the peer registry is known by then).  Cache-less and
        disk-source modes keep the step schedule: they mutate no state a
        peer could observe, so there is nothing to decompose."""
        if (
            self.cfg.granularity != "substep"
            or self.cfg.source == "disk"
            or self.cache is None
        ):
            return None
        peer_lookup = None
        if self.registry is not None:
            peer_lookup = lambda idx: peer_probe_payload(  # noqa: E731
                self.registry, self.node_id, idx
            )
        # parity-mirror: substep-build begin mode=call-shape callee=SubstepAccess
        return SubstepAccess(
            now=lambda: self.t,
            charge=self._charge,
            fold_own=self._fold_own,
            local_lookup=self.cache.get,
            peer_lookup=peer_lookup,
            bucket_read=self._bucket_read,
            insert=self.cache.put,
            kernel=self.kernel,
            insert_on_miss=self._insert_on_miss,
            node=self.node_id,
            trace=self._trace,
        )
        # parity-mirror: substep-build end

    def attach_placement(self, placement) -> None:
        """Install the cluster-wide placement planner
        (``repro.oracle.placement.ClusterPlacementPlanner``), wired by the
        cluster driver for ``prefetch_policy="cluster-oracle"`` specs —
        one shared instance across all ranks, so every rank partitions
        ownership against the same memoized epoch plan.  Eviction stays
        per-rank (the rank's own clairvoyant view): placement's cross-rank
        runtime state is the shared in-flight set alone."""
        self._placement = placement

    def attach_oracle_view(self, view) -> None:
        """Install this node's clairvoyant view (``repro.oracle``), wired
        by the cluster driver so the view can replay the driver's own
        sampler for future-epoch lookahead.  Re-points the Belady policy,
        which outlives epochs along with the cache."""
        self.oracle_view = view
        if self._belady is not None:
            self._belady.attach_view(view)

    def join_peer_registry(self, registry: "PeerCacheRegistry") -> None:
        """Register this node's cache in the cluster-wide directory."""
        if self.cache is None:
            raise ValueError("peer cache tier needs a local cache (cache_items)")
        registry.register(self.node_id, self.cache)
        self.registry = registry
        if self.service is not None:
            self.service.registry = registry

    def _peer_fetch(self, idx: int) -> bool:
        """Try to serve ``idx`` from a peer's cache; returns hit/miss."""
        if self.registry is None:
            return False
        holder = self.registry.lookup(idx, requester=self.node_id)
        if holder is None:
            return False
        if self.registry.cache_of(holder).peek(idx) is None:
            return False  # evicted between lookup and read
        self.registry.record_hit()
        return True

    # -- store timing --------------------------------------------------------
    def _sequential_get_s(self) -> float:
        return self.bucket.get_seconds(self.spec.sample_bytes)

    # -- events --------------------------------------------------------------
    def fold_inserts_until(self, t: float) -> None:
        """Apply this node's prefetch completions with time <= ``t``.

        The interleaved cluster scheduler calls this on *every* node before
        stepping any of them, so a peer probing this cache observes rounds
        that completed (in virtual time) even while this node sits between
        its own accesses.  Safe because the scheduler only steps the
        globally-earliest node: this node's own next access is at >= t, so
        it would have folded these completions itself by then anyway.
        """
        if self.service is not None:
            self.service.advance_to(t)

    # -- sample access -------------------------------------------------------
    def _classify(self, idx: int) -> Tuple[str, bool]:
        """Resolve one demand read to its serving tier — the only stateful
        part of an access.  Returns ``(tier, probed)``; ``probed`` marks a
        bucket fallback that paid a failed peer-probe RTT first.  Folds
        this node's completed prefetch rounds before the lookup (barrier),
        and performs the cache/peer lookups whose side effects (CacheStats,
        Belady next_use queries, registry hit counters) are part of the
        modelled state evolution."""
        if self.cfg.source == "disk":
            # Disk-source baseline: no cache tier at all; every read is a
            # local-disk access — a distinct source tier, never a local
            # *cache* hit (misses stay derived as samples - local hits).
            return "disk-source", False
        if self.cache is None:
            # Direct-from-bucket baseline: sequential fallback GET.
            return "bucket", False
        assert self.service is not None
        self.service.advance_to(self.t)  # fold completed rounds (barrier)
        if self.cache.get(idx) is not None:
            # Sim caches are RAM-only (sentinel payloads, no spill).
            return "ram", False
        if self._peer_fetch(idx):
            # Local miss served by a peer's cache over the inter-node
            # network: RTT + streaming, no Class B request.
            return "peer", False
        return "bucket", self.registry is not None  # failed probe RTT if probed

    def _access(self, idx: int, stats: EpochStats) -> None:
        """One sample read: classify the serving tier, then advance ``t``
        through the tier's kernel charge components — the same floats, in
        the same order, every engine and the lock-step runtime use (see
        ``repro.engine.kernels``) — then the modelled loop overheads."""
        t0 = self.t
        tier, probed = self._classify(idx)
        for component_s in self.kernel.tier_charges(tier, probed):
            self.t += component_s
        stats.record(tier)
        if tier == "bucket":
            self.kernel.bill_demand_gets(self.store_stats)
        if tier in ("peer", "bucket") and self.cache is not None and self._insert_on_miss:
            # Cache-only mode inserts on miss (paper §IV-B); with a
            # pre-fetch service the worker does not (§IV-C).
            self.cache.put(idx, _SENTINEL)
        self.t += self.kernel.cpu_overhead_s
        stats.samples += 1
        dt = self.t - t0
        stats.data_wait_seconds += dt
        trace_demand(
            self._trace,
            self.node_id,
            t0,
            dt,
            idx,
            tier,
            1 if tier == "bucket" else 0,
        )

    # -- epoch stepper -------------------------------------------------------
    def begin_epoch(self, epoch: int, order: Sequence[int], node: int = 0) -> None:
        """Install one epoch's sample order; drive with :meth:`step`."""
        assert self._stats is None, "finish the current epoch first"
        self._stats = EpochStats(epoch=epoch, node=node)
        self._evictions_before = self.cache.stats.evictions if self.cache else 0
        if self._needs_oracle:
            # Standalone single-node runs get a view with no future-epoch
            # replay; cluster drivers attach a replay-capable one first.
            from repro.oracle.oracle import NodeAccessView

            if self.oracle_view is None:
                self.attach_oracle_view(NodeAccessView())
            self.oracle_view.begin_epoch(epoch, order)
        pf = self.cfg.prefetch if self.cfg.prefetch is not None else PrefetchConfig.disabled()
        if self.cfg.source == "disk" or self.cache is None:
            pf = PrefetchConfig.disabled()
        if self._oracle_prefetch:
            from repro.oracle.planner import planner_for

            assert self.cache is not None  # SimConfig validation
            # THE shared planner construction (repro.oracle.planner) — the
            # lock-step runtime builds its planner through the same call.
            self._planner = planner_for(
                order,
                policy=self.cfg.prefetch_policy,
                config=None,
                capacity=self.cfg.cache_items,
                resident=self.cache.contains,
                sizing=self.cfg.round_sizing,
                cost_model=self._round_cost,
                placement=self._placement,
                rank=self.node_id,
            )
        else:
            self._planner = PrefetchPlanner(order, pf)
        # Mirrored line (DeliLoader._sample_steps): a placement planner
        # carries the epoch's ownership set — install it on the shared
        # service, whose round partition enforces it on both projections.
        # parity-mirror: placement-install begin planner=self._planner
        owned = getattr(self._planner, "owned", None)
        if owned is not None and self.service is not None:
            self.service.set_placement(
                owned, in_flight=getattr(self._planner, "in_flight", None)
            )
        # parity-mirror: placement-install end
        if self.service is not None:
            # Flight recorder: stamp the epoch's policy family on the shared
            # service so every issue event carries its provenance (the
            # loader's _sample_steps stamps the identical line).
            self.service.provenance = getattr(self._planner, "provenance", "paper")
        self._planner_iter = iter(self._planner)
        self._samples_in_batch = 0
        self._events = self._epoch_events(self._build_substep())

    def _epoch_events(self, substep: Optional[SubstepAccess]) -> Iterator[int]:
        """The epoch as a stream of scheduler events.  At step granularity
        one event is a whole sample access (the PR 3 unit, same float ops
        in the same order); at sub-step granularity the shared
        ``SubstepAccess`` machine yields once per time component.  The
        event that completes a gradient batch (modelled compute included)
        is flagged ``STEP_BATCH_END`` — the ``sync="batch"`` parking
        point."""
        stats = self._stats
        assert stats is not None and self._planner_iter is not None
        for idx, round_ in self._planner_iter:
            # parity-mirror: oracle-cursor begin
            if self.oracle_view is not None:
                # Cursor advances at access *start* (mirrored line in
                # DeliLoader._sample_steps): a just-consumed key competes
                # for cache space on its NEXT occurrence.
                self.oracle_view.on_consume(idx)
            # parity-mirror: oracle-cursor end
            if round_ is not None:
                assert self.service is not None
                self.service.issue(list(round_), now=self.t, stats=stats)
            if substep is not None:
                yield from substep.run(idx, stats)
            else:
                self._access(idx, stats)
            self._samples_in_batch += 1
            if self._samples_in_batch == self.spec.batch_size:
                self._samples_in_batch = 0
                if self._overlap is not None:
                    # Bucketed compute/allreduce pipeline: the shared
                    # generator charges the spans and the exposed comm tail
                    # (same code the lock-step loader runs).
                    yield from self._overlap.run(stats)
                else:
                    c0 = self.t
                    self.t += self.compute_per_batch_s
                    stats.compute_seconds += self.compute_per_batch_s
                    if self.compute_per_batch_s:
                        # Guarded like the loader's ``elif compute_per_batch_s``
                        # branch: zero-compute specs emit no compute spans on
                        # either projection.
                        trace_emit(
                            self._trace,
                            "compute",
                            self.node_id,
                            c0,
                            self.compute_per_batch_s,
                        )
                yield STEP_BATCH_END
            else:
                yield STEP_CONTINUE

    def step(self) -> int:
        """Process one scheduler event; returns a ``repro.core.lockstep``
        signal: ``STEP_CONTINUE``, ``STEP_BATCH_END`` (this event finished
        a gradient batch), or the falsy ``STEP_DONE`` when the epoch is
        exhausted (so legacy ``while node.step():`` loops still work)."""
        assert self._events is not None
        return next(self._events, STEP_DONE)

    def sync_to(self, t: float, comm_s: float = 0.0) -> None:
        """Allreduce barrier: account the blocked time (skew) and jump to
        the barrier's virtual time (never backwards), then serve the
        collective's transfer duration ``comm_s`` — every participant
        leaves the barrier together at ``t + comm_s``.  Called by the
        cluster scheduler for every parked node under ``sync="batch"``,
        and (wait-only) for the epoch barrier of that schedule."""
        # parity-mirror: sync-to begin clock=self.t stats=self._stats node=self.node_id trace=self._trace
        wait = t - self.t
        if wait > 0:
            if self._stats is not None:
                self._stats.allreduce_wait_seconds += wait
            self.t = t
        if comm_s > 0:
            if self._stats is not None:
                self._stats.allreduce_comm_seconds += comm_s
            self.t += comm_s
        trace_sync(self._trace, self.node_id, self.t, wait, comm_s)
        # parity-mirror: sync-to end

    def finish_epoch(self) -> EpochStats:
        assert self._stats is not None
        stats = self._stats
        if self.cache:
            stats.evictions = self.cache.stats.evictions - self._evictions_before
        self._stats = None
        self._planner = None
        self._planner_iter = None
        self._events = None
        return stats

    def run_epoch(self, epoch: int, order: Sequence[int], node: int = 0) -> EpochStats:
        """Run one whole epoch on this node alone (no interleaving)."""
        self.begin_epoch(epoch, order, node=node)
        while self.step():
            pass
        return self.finish_epoch()


def _build_samplers(spec: WorkloadSpec, cfg: SimConfig, seed: int) -> List[Sampler]:
    """Legacy sampler construction from a SimConfig (specs pass their own)."""
    samplers: List[Sampler] = []
    for rank in range(spec.n_nodes):
        if cfg.locality_aware:
            samplers.append(
                LocalityAwareSampler(
                    spec.n_samples,
                    rank,
                    spec.n_nodes,
                    seed=seed,
                    peer_aware=cfg.peer_cache,
                )
            )
        else:
            samplers.append(
                DistributedPartitionSampler(spec.n_samples, rank, spec.n_nodes, seed=seed)
            )
    return samplers


def simulate_cluster(
    spec: WorkloadSpec,
    cfg: SimConfig,
    epochs: int = 2,
    seed: int = 0,
    bucket: BucketModel = DEFAULT_BUCKET,
    disk: DiskModel = DEFAULT_DISK,
    pipeline: PipelineCostModel = DEFAULT_PIPELINE,
    network: NetworkModel = DEFAULT_NETWORK,
    interleaved: bool = True,
    samplers: Optional[Sequence[Sampler]] = None,
    profiles: Optional[Sequence[NodeProfile]] = None,
) -> Tuple[List[EpochStats], StoreStats]:
    """Run all nodes of the paper's setup for N epochs; returns per-node
    per-epoch stats (rank order within each epoch) + aggregate store
    accounting.

    With ``cfg.peer_cache`` every node's cache joins one
    ``PeerCacheRegistry``; a node's local miss is first offered to its
    peers' caches over the modelled inter-node network.

    ``interleaved=True`` (default): one event heap over all nodes, keyed by
    ``(virtual_time, rank)``; the globally-earliest sample access always
    executes next and every node folds its completed prefetch rounds before
    each scheduler step, so peer lookups observe *mid-epoch* cache state —
    same-epoch fills and evictions alike.  Epoch boundaries are BSP
    barriers (clocks sync to the slowest node).  Prefetch-free nodes that
    never interact (no peer tier) produce results identical to the
    sequential schedule; with prefetching, the epoch barrier can nudge
    cross-epoch round timing (a fast node's clock jumps to the barrier, so
    a straddling round completes relatively earlier).

    ``interleaved=False``: the legacy sequential schedule — a rank-r node
    sees ranks < r at their post-current-epoch cache state and ranks > r at
    the previous epoch boundary (the bias documented in PR 1; kept for A/B
    comparison, see ``benchmarks/fig10_peer_cache.py``).

    ``samplers`` overrides per-rank sample orders (``DataPlaneSpec`` passes
    registry-built samplers so both execution paths share them verbatim);
    default builds from ``cfg.locality_aware``.

    ``cfg.sync="batch"`` adds an allreduce barrier after every gradient
    batch (ISSUE 4): a node finishing batch k parks until every
    still-running node finishes its own batch k, the blocked time is
    accounted in ``EpochStats.allreduce_wait_seconds``, and all clocks jump
    to the barrier.  ``profiles`` assigns per-node ``NodeProfile``
    multipliers (straggler scenarios); default = homogeneous.  Both require
    the interleaved schedule — a sequential node loop cannot express a
    same-step barrier.
    """
    if cfg.sync == "batch" and not interleaved:
        raise ValueError("sync='batch' requires the interleaved schedule")
    if cfg.granularity == "substep" and not interleaved:
        raise ValueError("granularity='substep' requires the interleaved schedule")
    if profiles is None:
        profiles = [DEFAULT_PROFILE] * spec.n_nodes
    profiles = list(profiles)
    if len(profiles) != spec.n_nodes:
        raise ValueError(f"need {spec.n_nodes} profiles, got {len(profiles)}")
    node_cls = NodeSimulator
    if cfg.engine == "vector" and interleaved:
        # The vectorized segment engine (ISSUE 6).  Lazy import: the engine
        # subclasses NodeSimulator, so a module-level import would be
        # circular.  Only the interleaved schedule is batchable — the
        # legacy sequential schedule folds prefetch completions in a
        # different order for the clairvoyant data plane, so it keeps
        # scalar stepping (silent per-node fallback; documented on
        # SimConfig.engine).
        from repro.engine.vector import VectorNodeEngine

        node_cls = VectorNodeEngine
    nodes = [
        node_cls(
            spec,
            cfg,
            bucket,
            disk,
            pipeline,
            network,
            node_id=rank,
            profile=profiles[rank],
        )
        for rank in range(spec.n_nodes)
    ]
    registry: Optional["PeerCacheRegistry"] = None
    if cfg.peer_cache:
        from repro.distributed.peer_cache import PeerCacheRegistry

        if cfg.cache_items is None:
            raise ValueError("peer_cache requires a local cache (cache_items)")
        registry = PeerCacheRegistry(
            replication_aware=cfg.replication_aware_eviction
        )
        for node in nodes:
            node.join_peer_registry(registry)
    if samplers is None:
        samplers = _build_samplers(spec, cfg, seed)
    samplers = list(samplers)
    if len(samplers) != spec.n_nodes:
        raise ValueError(f"need {spec.n_nodes} samplers, got {len(samplers)}")
    if cfg.eviction == "belady" or cfg.prefetch_policy in ("oracle", "cluster-oracle"):
        # Clairvoyant views over the driver's own samplers (ISSUE 5); the
        # lock-step RuntimeCluster builds the identical AccessOracle over
        # the identically-constructed samplers, so every next_use answer —
        # and every Belady/oracle decision — matches exactly.
        from repro.oracle import AccessOracle

        oracle = AccessOracle(samplers)
        for rank, node in enumerate(nodes):
            node.attach_oracle_view(oracle.view(rank))
    if cfg.prefetch_policy == "cluster-oracle":
        # The cross-rank ownership plan (ISSUE 7): ONE planner instance over
        # the same samplers, shared by all ranks; RuntimeCluster builds its
        # own over identically-constructed samplers, so the partitions — a
        # pure function of the seeded orders — match exactly.
        from repro.oracle import ClusterPlacementPlanner

        placement = ClusterPlacementPlanner(samplers)
        for node in nodes:
            node.attach_placement(placement)
    locality = [s for s in samplers if hasattr(s, "update_cache_views")]
    all_stats: List[EpochStats] = []
    for e in range(epochs):
        if locality:
            if registry is not None:
                views = registry.cache_views()  # ordered by node id == rank
            else:
                views = [n.cache.keys() if n.cache else [] for n in nodes]
            for s in locality:
                s.update_cache_views(views)
        for rank, (node, sampler) in enumerate(zip(nodes, samplers)):
            sampler.set_epoch(e)
            node.begin_epoch(e, sampler.indices(), node=rank)
        if interleaved:
            # The one shared schedule implementation (repro.core.lockstep):
            # earliest-access-first event heap, fold-before-step completion
            # barriers, BSP epoch barrier.

            def _fold_all(t: float) -> None:
                for n in nodes:  # completion events <= t are visible to all
                    n.fold_inserts_until(t)

            def _barrier(t: float) -> None:
                for n in nodes:
                    if cfg.sync == "batch":
                        n.sync_to(t)  # epoch-end allreduce: wait accounted
                    else:
                        n.t = t  # PR 3 epoch barrier (no accounting)

            def _batch_barrier(t: float, ranks: Tuple[int, ...]) -> None:
                # With a collective cost model and no overlap, the barrier
                # itself carries the transfer: its duration is the slowest
                # participant's full-gradient allreduce (a collective runs
                # at the pace of its slowest member).  Overlap specs charge
                # the exposed comm inside the batch (BucketedBatchComm), so
                # their barrier is wait-only.
                comm = 0.0
                if cfg.collective is not None and cfg.overlap == "none":
                    comm = max(nodes[r].allreduce_s for r in ranks)
                for r in ranks:
                    nodes[r].sync_to(t, comm)

            drive_interleaved_epoch(
                len(nodes),
                now=lambda rank: nodes[rank].t,
                fold_all=_fold_all,
                step=lambda rank: nodes[rank].step(),
                barrier=_barrier,
                sync=cfg.sync,
                batch_barrier=_batch_barrier if cfg.sync == "batch" else None,
                backup_workers=cfg.backup_workers,
                staleness_bound=cfg.staleness_bound,
                trace=cfg.trace,
            )
        else:
            for node in nodes:
                while node.step():
                    pass
        for node in nodes:
            all_stats.append(node.finish_epoch())
    agg = StoreStats()
    for n in nodes:
        agg = agg.merge(n.store_stats)
    return all_stats, agg


def mean_miss_rate(stats: List[EpochStats], epoch: int) -> float:
    rows = [s for s in stats if s.epoch == epoch]
    return sequential_sum(r.miss_rate for r in rows) / len(rows)


def mean_data_wait(stats: List[EpochStats], epoch: int) -> float:
    rows = [s for s in stats if s.epoch == epoch]
    return sequential_sum(r.data_wait_seconds for r in rows) / len(rows)
