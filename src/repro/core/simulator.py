"""Discrete-event simulator of the DELI node pipeline.

Why a simulator: the container has no cloud and no wall-clock budget for
hundred-second epochs; the paper's results are *timing races* between the
training loop and the pre-fetch service.  The simulator advances a virtual
clock through exactly the mechanism the threaded runtime implements — same
``PrefetchPlanner`` policy object, same ``CappedCache`` class, same
calibrated ``BucketModel`` — so its predictions are the runtime's behaviour
(property-tested against the threaded pipeline in
tests/test_core_sim_and_cost.py).

Event structure (single service worker, paper §IV-C: one subprocess per
request on a 2-vCPU VM => effectively serialized):

  * the training loop is the driving process: it consumes samples in
    planner order, paying hit/miss latencies and per-batch compute;
  * fetch rounds queue on the service; round r starts at
    max(request time, completion of round r-1), runs for the calibrated
    bulk duration, and bulk-inserts at completion;
  * cache inserts/evictions are applied lazily: before each lookup, all
    rounds with completion <= now are folded into the cache.

Measured outputs per epoch = the paper's metrics: miss rate, data-wait.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from repro.core.bandwidth import (
    DEFAULT_BUCKET,
    DEFAULT_DISK,
    DEFAULT_NETWORK,
    DEFAULT_PIPELINE,
    BucketModel,
    DiskModel,
    NetworkModel,
    PipelineCostModel,
)
from repro.core.cache import CappedCache
from repro.core.policy import PrefetchConfig, PrefetchPlanner
from repro.core.sampler import DistributedPartitionSampler, LocalityAwareSampler
from repro.core.types import EpochStats, StoreStats
from repro.core.workloads import WorkloadSpec

if TYPE_CHECKING:  # runtime import is deferred: repro.core is imported by
    # repro.distributed.peer_cache, so a module-level import here would be
    # circular for processes whose first repro import is repro.distributed.
    from repro.distributed.peer_cache import PeerCacheRegistry

_SENTINEL = b"\x00"  # cache payloads are placeholders; experiments count items


@dataclasses.dataclass
class SimConfig:
    """One experimental condition (a bar in the paper's figures)."""

    source: str = "bucket"  # "bucket" | "disk"
    cache_items: Optional[int] = None  # None = no cache; 0 < n = capped; -1 = unlimited
    prefetch: Optional[PrefetchConfig] = None  # None = no prefetching
    n_connections: int = 16
    streaming_insert: bool = False  # beyond-paper knob
    list_every_fetch: bool = True  # paper prototype; False = listing cache
    locality_aware: bool = False  # beyond-paper partitioner
    # Cooperative peer-cache tier: on a local miss, ask peers' caches over
    # the modelled inter-node network before falling back to the bucket.
    peer_cache: bool = False
    # Hoard-style replication-aware eviction: a member cache declines to
    # evict the last cluster-resident copy of a sample (needs peer_cache).
    replication_aware_eviction: bool = False

    def label(self) -> str:
        if self.source == "disk":
            return "disk"
        if self.cache_items is None:
            return "gcp-direct"
        cache = "unlimited" if self.cache_items == -1 else str(self.cache_items)
        peer = "+peer" if self.peer_cache else ""
        if self.peer_cache and self.replication_aware_eviction:
            peer += "+repl"
        if self.prefetch is None:
            return f"cache[{cache}]{peer}"
        return (
            f"cache[{cache}]{peer}+pf(f={self.prefetch.fetch_size},"
            f"T={self.prefetch.prefetch_threshold})"
        )


@dataclasses.dataclass
class _ServiceState:
    free_at: float = 0.0
    pending: List[Tuple[float, List[int]]] = dataclasses.field(default_factory=list)
    rounds: int = 0


class NodeSimulator:
    """Simulates one node's data plane across epochs (cache persists)."""

    def __init__(
        self,
        spec: WorkloadSpec,
        cfg: SimConfig,
        bucket: BucketModel = DEFAULT_BUCKET,
        disk: DiskModel = DEFAULT_DISK,
        pipeline: PipelineCostModel = DEFAULT_PIPELINE,
        network: NetworkModel = DEFAULT_NETWORK,
        node_id: int = 0,
    ):
        self.spec = spec
        self.cfg = cfg
        self.bucket = bucket
        self.disk = disk
        self.pipeline = pipeline
        self.network = network
        self.node_id = node_id
        self.t = 0.0
        self.store_stats = StoreStats()
        self.cache: Optional[CappedCache] = None
        if cfg.cache_items is not None:
            max_items = None if cfg.cache_items == -1 else cfg.cache_items
            self.cache = CappedCache(max_items=max_items)
        self.service = _ServiceState()
        # Cooperative peer-cache tier (set by simulate_cluster / tests).
        self.registry: Optional["PeerCacheRegistry"] = None

    def join_peer_registry(self, registry: "PeerCacheRegistry") -> None:
        """Register this node's cache in the cluster-wide directory."""
        if self.cache is None:
            raise ValueError("peer cache tier needs a local cache (cache_items)")
        registry.register(self.node_id, self.cache)
        self.registry = registry

    def _peer_fetch(self, idx: int) -> bool:
        """Try to serve ``idx`` from a peer's cache; returns hit/miss."""
        if self.registry is None:
            return False
        holder = self.registry.lookup(idx, requester=self.node_id)
        if holder is None:
            return False
        if self.registry.cache_of(holder).peek(idx) is None:
            return False  # evicted between lookup and read
        self.registry.record_hit()
        return True

    # -- store timing --------------------------------------------------------
    def _sequential_get_s(self) -> float:
        return self.bucket.get_seconds(self.spec.sample_bytes)

    def _bulk_get_s(self, n: int) -> float:
        return self.bucket.bulk_get_seconds(
            [self.spec.sample_bytes] * n, self.cfg.n_connections
        )

    # -- service -------------------------------------------------------------
    def _issue_round(self, keys: List[int], stats: Optional[EpochStats] = None) -> None:
        start = max(self.t, self.service.free_at)
        listing_s = 0.0
        if self.cfg.list_every_fetch or self.service.rounds == 0:
            listing_s = self.bucket.list_seconds(self.spec.n_samples)
            self.store_stats.class_a_requests += max(
                1, -(-self.spec.n_samples // self.bucket.page_size)
            )
        # Peer-cache tier: the pre-fetch service pulls keys a peer already
        # holds over the inter-node network (sequential RPCs) instead of
        # issuing bucket GETs for them — no Class B request billed.
        bucket_keys = keys
        peer_s = 0.0
        if self.registry is not None:
            bucket_keys = []
            n_peer = 0
            for k in keys:
                if self._peer_fetch(k):
                    n_peer += 1
                else:
                    bucket_keys.append(k)
            # Peer hits pay the transfer (RTT + streaming); failed probes
            # pay the lookup RTT — same charges as the demand path.
            peer_s = n_peer * self.network.transfer_seconds(
                self.spec.sample_bytes
            ) + len(bucket_keys) * self.network.lookup_seconds()
            if stats is not None and n_peer:
                stats.record("peer", n_peer)
        # The round's keys are known when it is issued, so the (naive)
        # per-round listing proceeds CONCURRENTLY with the parallel GETs —
        # it is pure Class A accounting traffic, not a serialization point.
        dur = max(listing_s, self._bulk_get_s(len(bucket_keys)) + peer_s)
        done = start + dur
        self.store_stats.class_b_requests += len(bucket_keys)
        self.store_stats.bytes_read += len(bucket_keys) * self.spec.sample_bytes
        self.store_stats.read_seconds += dur
        if self.cfg.streaming_insert:
            # Spread inserts uniformly across the round duration.
            per = dur / len(keys)
            for j, k in enumerate(keys):
                self.service.pending.append((start + per * (j + 1), [k]))
        else:
            self.service.pending.append((done, list(keys)))
        self.service.free_at = done
        self.service.rounds += 1

    def _apply_completed_inserts(self) -> None:
        assert self.cache is not None
        remaining = []
        for done, keys in self.service.pending:
            if done <= self.t:
                for k in keys:
                    self.cache.put(k, _SENTINEL)
            else:
                remaining.append((done, keys))
        self.service.pending = remaining

    # -- sample access -------------------------------------------------------
    def _access(self, idx: int, stats: EpochStats) -> None:
        pipeline = self.pipeline
        wait = pipeline.cpu_overhead_s
        if self.cfg.source == "disk":
            # Disk-source baseline: no cache tier at all; every read is a
            # (local-disk) miss — no tier recorded, misses are derived.
            wait += self.disk.get_seconds(self.spec.sample_bytes)
        elif self.cache is None:
            # Direct-from-bucket baseline: sequential fallback GET.
            wait += self._sequential_get_s()
            stats.record("bucket")
            self.store_stats.class_b_requests += 1
            self.store_stats.bytes_read += self.spec.sample_bytes
        else:
            self._apply_completed_inserts()
            if self.cache.get(idx) is not None:
                # Sim caches are RAM-only (sentinel payloads, no spill).
                wait += pipeline.ram_hit_s
                stats.record("ram")
            elif self._peer_fetch(idx):
                # Local miss served by a peer's cache over the inter-node
                # network: RTT + streaming, no Class B request.
                wait += self.network.transfer_seconds(self.spec.sample_bytes)
                stats.record("peer")
                if self.cfg.prefetch is None:
                    self.cache.put(idx, _SENTINEL)
            else:
                if self.registry is not None:
                    wait += self.network.lookup_seconds()  # failed peer probe
                wait += self._sequential_get_s()
                stats.record("bucket")
                self.store_stats.class_b_requests += 1
                self.store_stats.bytes_read += self.spec.sample_bytes
                if self.cfg.prefetch is None:
                    # Cache-only mode inserts on miss (paper §IV-B); with a
                    # pre-fetch service the worker does not (§IV-C).
                    self.cache.put(idx, _SENTINEL)
        self.t += wait
        stats.samples += 1
        stats.data_wait_seconds += wait

    # -- epoch ----------------------------------------------------------------
    def run_epoch(self, epoch: int, order: Sequence[int], node: int = 0) -> EpochStats:
        stats = EpochStats(epoch=epoch, node=node)
        ev0 = self.cache.stats.evictions if self.cache else 0
        pf = self.cfg.prefetch if self.cfg.prefetch is not None else PrefetchConfig.disabled()
        if self.cfg.source == "disk" or self.cache is None:
            pf = PrefetchConfig.disabled()
        planner = PrefetchPlanner(order, pf)
        samples_in_batch = 0
        for idx, round_ in planner:
            if round_ is not None:
                self._issue_round(list(round_), stats)
            self._access(idx, stats)
            samples_in_batch += 1
            if samples_in_batch == self.spec.batch_size:
                self.t += self.spec.compute_per_batch_s
                stats.compute_seconds += self.spec.compute_per_batch_s
                samples_in_batch = 0
        if self.cache:
            stats.evictions = self.cache.stats.evictions - ev0
        return stats


def simulate_cluster(
    spec: WorkloadSpec,
    cfg: SimConfig,
    epochs: int = 2,
    seed: int = 0,
    bucket: BucketModel = DEFAULT_BUCKET,
    disk: DiskModel = DEFAULT_DISK,
    pipeline: PipelineCostModel = DEFAULT_PIPELINE,
    network: NetworkModel = DEFAULT_NETWORK,
) -> Tuple[List[EpochStats], StoreStats]:
    """Run all nodes of the paper's setup for N epochs; returns per-node
    per-epoch stats + aggregate store accounting.

    With ``cfg.peer_cache`` every node's cache joins one
    ``PeerCacheRegistry``; a node's local miss is first offered to its
    peers' caches over the modelled inter-node network.  Nodes still run
    their epochs sequentially (as before), so a rank-r node sees ranks < r
    at their post-current-epoch cache state and ranks > r at the previous
    epoch boundary.  The bias is mixed relative to concurrently-running
    nodes: same-epoch fills from lower ranks are visible early (optimistic)
    while capped caches' same-epoch evictions are also visible early
    (pessimistic); an event-interleaved cluster sim is a ROADMAP item.
    """
    nodes = [
        NodeSimulator(spec, cfg, bucket, disk, pipeline, network, node_id=rank)
        for rank in range(spec.n_nodes)
    ]
    registry: Optional["PeerCacheRegistry"] = None
    if cfg.peer_cache:
        from repro.distributed.peer_cache import PeerCacheRegistry

        if cfg.cache_items is None:
            raise ValueError("peer_cache requires a local cache (cache_items)")
        registry = PeerCacheRegistry(
            replication_aware=cfg.replication_aware_eviction
        )
        for node in nodes:
            node.join_peer_registry(registry)
    samplers: List = []
    for rank in range(spec.n_nodes):
        if cfg.locality_aware:
            samplers.append(
                LocalityAwareSampler(
                    spec.n_samples,
                    rank,
                    spec.n_nodes,
                    seed=seed,
                    peer_aware=cfg.peer_cache,
                )
            )
        else:
            samplers.append(
                DistributedPartitionSampler(spec.n_samples, rank, spec.n_nodes, seed=seed)
            )
    all_stats: List[EpochStats] = []
    for e in range(epochs):
        if cfg.locality_aware:
            if registry is not None:
                views = registry.cache_views()  # ordered by node id == rank
            else:
                views = [n.cache.keys() if n.cache else [] for n in nodes]
            for s in samplers:
                s.update_cache_views(views)
        for rank, (node, sampler) in enumerate(zip(nodes, samplers)):
            sampler.set_epoch(e)
            all_stats.append(node.run_epoch(e, sampler.indices(), node=rank))
    agg = StoreStats()
    for n in nodes:
        agg = agg.merge(n.store_stats)
    return all_stats, agg


def mean_miss_rate(stats: List[EpochStats], epoch: int) -> float:
    rows = [s for s in stats if s.epoch == epoch]
    return sum(r.miss_rate for r in rows) / len(rows)


def mean_data_wait(stats: List[EpochStats], epoch: int) -> float:
    rows = [s for s in stats if s.epoch == epoch]
    return sum(r.data_wait_seconds for r in rows) / len(rows)
