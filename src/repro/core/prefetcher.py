"""The pre-fetch service (paper §III-B / §IV-C), threaded runtime.

One instance runs per node (per JAX host process).  The wrapped Sampler
announces fetch rounds; the service acknowledges immediately and downloads
the round's objects from the bucket *in parallel* in the background, then
bulk-inserts them into the node's capped cache ("once they are all ready,
they are cached in parallel").  The training loop never waits on the
service: on a cache miss it falls back to the bucket itself.

Faithful-to-paper behaviours:
  * requests are acknowledged instantly; fetch work is queued (the paper
    spins up a subprocess per request on a 2-vCPU VM — effective
    serialization; we use one worker thread, which also makes the runtime
    agree with the discrete-event simulator);
  * inserts happen only after the whole round is downloaded (bulk insert);
  * the naive prototype lists the bucket on every fetch round (this is the
    Class A cost the paper calls out in §III-C footnote 3) — disable with
    ``list_every_fetch=False`` to get the beyond-paper listing cache (§VI).

Beyond-paper behaviours:
  * ``streaming_insert=True`` inserts each object as it lands instead of at
    round completion, shaving the head-of-round miss window;
  * hedged GETs for straggler mitigation when running over a real threaded
    store (duplicate request after ``hedge_after_s``);
  * cooperative peer caching: hand the service a
    ``repro.distributed.PeerStore`` and every per-key GET walks the remote
    tier stack (peer tier first, bucket second — see
    ``repro.pipeline.tiers``), so fetch rounds pull cluster-resident
    samples over the inter-node network instead of issuing Class B bucket
    requests for them.  Attribution is explicit: each fetch returns a
    ``TierResult`` naming the serving tier.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import ThreadPoolExecutor, TimeoutError as FutureTimeout
from typing import TYPE_CHECKING, Optional, Sequence

from repro.core.cache import CappedCache
from repro.core.clock import Clock
from repro.core.listing_cache import ListingCache
from repro.core.store import SampleStore, SimulatedBucketStore
from repro.core.types import FetchRequest

# Late-bound module reference: repro.pipeline.tiers imports repro.core back;
# resolving attributes at call time keeps either package importable first
# (see the matching note in repro.core.dataset).
import repro.pipeline.tiers as _tiers

if TYPE_CHECKING:
    from repro.pipeline.tiers import ReadTier, TierResult


class PrefetchService:
    def __init__(
        self,
        store: SampleStore,
        cache: CappedCache,
        n_connections: int = 16,
        clock: Optional[Clock] = None,
        list_every_fetch: bool = True,
        listing_cache: Optional[ListingCache] = None,
        streaming_insert: bool = False,
        hedge_after_s: Optional[float] = None,
        tiers: Optional[Sequence["ReadTier"]] = None,
    ):
        self.store = store
        self.cache = cache
        self.n_connections = n_connections
        self.clock = clock or store.clock
        self.list_every_fetch = list_every_fetch
        self.listing_cache = listing_cache
        self.streaming_insert = streaming_insert
        self.hedge_after_s = hedge_after_s
        # Remote read path for per-key GETs: peer tier (when the store is a
        # PeerStore) then bucket — the same explicit stack the demand path
        # walks past its local cache tiers.
        self.tiers = _tiers.TierStack(
            list(tiers) if tiers is not None else _tiers.tiers_for_store(store)
        )
        self.hedges = 0
        self.rounds_completed = 0
        self.samples_fetched = 0
        # Round objects pulled from a peer's cache instead of the bucket
        # (populated when the tier stack contains a peer tier).
        self.peer_fetches = 0
        self._queue: "queue.Queue[Optional[FetchRequest]]" = queue.Queue()
        self._request_counter = 0
        self._idle = threading.Event()
        self._idle.set()
        self._worker = threading.Thread(target=self._run, daemon=True, name="deli-prefetch")
        self._started = False
        self._closed = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "PrefetchService":
        if not self._started:
            self._worker.start()
            self._started = True
        return self

    def close(self) -> None:
        if self._started and not self._closed:
            self._queue.put(None)
            self._worker.join(timeout=60)
        self._closed = True

    def __enter__(self) -> "PrefetchService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- API used by the Sampler wrapper ------------------------------------
    def request(self, keys: Sequence[int], stats=None, replay: bool = False) -> FetchRequest:
        """Announce a fetch round; returns immediately (paper semantics).

        ``stats`` (an ``EpochStats``) is accepted for interface symmetry
        with the deterministic ``repro.core.lockstep`` service and ignored:
        a free-running worker cannot attribute its peer pulls to an epoch
        (they are reported on ``peer_fetches`` / ``PeerStore.peer_hits``).

        ``replay=True`` marks a round re-announced by a mid-epoch resume
        (``DeliLoader``): a fully cache-resident replay is dropped here so
        it cannot re-bill the per-round Class A listing (the worker already
        filters resident keys from the GETs); partially evicted replays are
        fetched like any round.
        """
        if not self._started:
            self.start()
        if replay and all(self.cache.contains(k) for k in keys):
            self._request_counter += 1
            return FetchRequest(
                keys=(), request_id=self._request_counter, issued_at=self.clock.now()
            )
        self._request_counter += 1
        req = FetchRequest(
            keys=tuple(keys), request_id=self._request_counter, issued_at=self.clock.now()
        )
        self._idle.clear()
        self._queue.put(req)
        return req

    def drain(self, timeout: float = 120.0) -> bool:
        """Block until all queued rounds are fetched+inserted (tests only)."""
        return self._idle.wait(timeout)

    def advance_to(self, now: float) -> int:
        """No-op: a free-running worker applies completions on its own
        schedule.  Interface symmetry with ``LockstepPrefetchService`` so
        the loader can fold deterministic completions unconditionally."""
        return 0

    # -- worker --------------------------------------------------------------
    def _list_bucket(self) -> None:
        """The prototype's per-fetch listing (Class A traffic)."""
        if self.listing_cache is not None:
            self.listing_cache.list(self.store)
        else:
            self.store.list_objects()

    def _fetch_round(self, req: FetchRequest) -> None:
        keys = [k for k in req.keys if not self.cache.contains(k)]
        listing_thread: Optional[threading.Thread] = None
        if self.list_every_fetch:
            # The round's keys are already known: the naive per-round listing
            # overlaps the GETs (it is Class A accounting, not a dependency).
            listing_thread = threading.Thread(target=self._list_bucket, daemon=True)
            listing_thread.start()
        if not keys:
            if listing_thread:
                listing_thread.join()
            return
        if isinstance(self.store, SimulatedBucketStore):
            payloads = self.store.bulk_get(keys, self.n_connections)
            if self.streaming_insert:
                # Simulated time already elapsed in one block; insert order
                # still matters for FIFO eviction.
                for k, p in zip(keys, payloads):
                    self.cache.put(k, p)
            else:
                self.cache.put_many(zip(keys, payloads))
        else:
            payloads_by_key = {}

            def _get(k) -> "TierResult":
                return self.tiers.fetch(k)

            with ThreadPoolExecutor(max_workers=self.n_connections) as pool:
                futures = {k: pool.submit(_get, k) for k in keys}
                for k, fut in futures.items():
                    # Resolve the payload (hedged or plain), THEN fall through
                    # to a single insert point — a fast pre-deadline result
                    # must take the same streaming-insert path as everything
                    # else (regression: such payloads were never cached).
                    if self.hedge_after_s is not None:
                        try:
                            result = fut.result(timeout=self.hedge_after_s)
                        except FutureTimeout:
                            self.hedges += 1
                            hedge = pool.submit(_get, k)
                            result = None
                            for f in (fut, hedge):
                                try:
                                    result = f.result(timeout=self.hedge_after_s * 10)
                                    break
                                except FutureTimeout:
                                    continue
                            if result is None:
                                result = fut.result()
                    else:
                        result = fut.result()
                    if result.tier == "peer":
                        self.peer_fetches += 1
                    payloads_by_key[k] = result.payload
                    if self.streaming_insert:
                        self.cache.put(k, result.payload)
            if not self.streaming_insert:
                self.cache.put_many((k, payloads_by_key[k]) for k in keys)
        if listing_thread:
            listing_thread.join()
        self.samples_fetched += len(keys)

    def _run(self) -> None:
        while True:
            req = self._queue.get()
            if req is None:
                self._idle.set()
                return
            try:
                self._fetch_round(req)
                self.rounds_completed += 1
            except Exception:
                # A failed round is not fatal: the training loop falls back
                # to the bucket for those keys (paper's miss path).  The
                # ReliableStore wrapper should make this rare.
                pass
            finally:
                if self._queue.empty():
                    self._idle.set()
