"""Lock-step pre-fetch scheduling: one deterministic service, two hosts.

The paper's pre-fetch service is asynchronous by design — the training loop
never waits on it — which historically split this repo's two execution
paths: the discrete-event simulator modelled the service with virtual-time
event math (``_issue_round``/``_apply_completed_inserts``), while the
threaded runtime ran a real worker thread whose completion times depend on
OS scheduling.  Exact sim/runtime parity was therefore *defined away* for
prefetch-enabled specs (``pipeline.parity`` refused them).

Clairvoyant Prefetching (Dryden et al.) makes the case that reproducible
I/O claims need *schedule-aware, deterministic* prefetch ordering.  This
module is that scheduler: ``LockstepPrefetchService`` holds the one
canonical implementation of the service's event semantics —

  * a fetch round issued at virtual time ``t`` starts at
    ``max(t, free_at)`` (one service worker, paper §IV-C: a subprocess per
    request on a 2-vCPU VM is effectively serialized);
  * round duration is ``max(listing, bulk_get(bucket_keys) + peer_time)``
    from the calibrated models — the per-round listing is pure Class A
    accounting traffic that overlaps the parallel GETs;
  * keys a peer already holds are pulled over the modelled inter-node
    network (no Class B request billed) — the probe sequence
    (registry lookup -> holder peek -> record_hit) is the same one the
    demand path performs;
  * under cluster placement (``prefetch_policy="cluster-oracle"``,
    ``repro.oracle.placement``) the round partition gains an ownership
    rule: keys this rank does not own are *never* bucket-fetched here —
    if no peer holds one yet (the owner's fetch is still in flight) it is
    **deferred** and retried at the next round, by which time it is
    normally peer-resident.  With no placement installed the partition is
    byte-identical to the historical peer/bucket split;
  * completions are *events*: inserts are folded into the cache only when
    ``advance_to(now)`` observes virtual time at/past the round's
    completion — the well-defined barriers are each sample access (the
    owner folds before its cache lookup) and, under the event-interleaved
    cluster scheduler, every scheduler step (peers fold before any node is
    stepped, so mid-epoch cache state is consistently visible).

Both projections instantiate this class: ``NodeSimulator`` drives it with
sentinel payloads, the lock-step ``RuntimeCluster`` with real payload bytes
(``payload_for``).  Because the timing arithmetic, the key partitioning,
the billing and the insert order are literally the same code, per-tier hit
counts and Class A/B totals agree *exactly* — no tolerances anywhere (see
docs/PARITY.md).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import TYPE_CHECKING, Callable, Iterator, List, Optional, Sequence, Tuple

from repro.core.bandwidth import BucketModel, NetworkModel
from repro.core.cache import CappedCache
from repro.core.clock import Clock
from repro.core.types import EpochStats, StoreStats
from repro.engine.kernels import DemandKernel
from repro.obs.events import CLUSTER_NODE, TraceRecorder, trace_demand, trace_emit

if TYPE_CHECKING:  # deferred for the same reason as in core.simulator:
    # repro.distributed imports repro.core back.
    from repro.distributed.peer_cache import PeerCacheRegistry

#: Simulator payloads are placeholders; experiments count items, not bytes.
SENTINEL = b"\x00"

#: Stepper signals: what one scheduler event did to the node's epoch.
#: ``STEP_DONE`` is falsy on purpose — legacy ``while node.step():`` loops
#: keep working; ``STEP_BATCH_END`` marks "this event completed a gradient
#: batch (compute included)", the parking point of the per-batch allreduce
#: barrier (``sync="batch"``).
STEP_DONE = 0
STEP_CONTINUE = 1
STEP_BATCH_END = 2


def drive_interleaved_epoch(
    n_nodes: int,
    now: Callable[[int], float],
    fold_all: Callable[[float], None],
    step: Callable[[int], int],
    barrier: Callable[[float], None],
    *,
    sync: str = "epoch",
    batch_barrier: Optional[Callable[[float, Tuple[int, ...]], None]] = None,
    backup_workers: int = 0,
    staleness_bound: int = 0,
    trace: Optional[TraceRecorder] = None,
) -> None:
    """THE event-interleaved cluster schedule for one epoch — a single
    implementation shared verbatim by the simulator and the lock-step
    runtime (``pipeline.spec.RuntimeCluster``), so the schedule can never
    drift between the two projections:

      * event heap keyed by ``(now(rank), rank)`` — the globally-earliest
        event always executes next, ties broken by rank (one event is a
        whole sample access at ``granularity="step"``, or one virtual-time
        component of it at ``granularity="substep"``);
      * before every step, ``fold_all(t)`` applies every node's prefetch
        completions with time <= t (safe: the heap invariant guarantees
        every other node's own next event is at >= t);
      * ``step(rank)`` processes one event and returns a signal:
        ``STEP_DONE`` = epoch exhausted for that node (it leaves the heap),
        ``STEP_BATCH_END`` = the event completed a gradient batch,
        ``STEP_CONTINUE`` = anything else;
      * ``sync="batch"`` (the data-parallel SGD schedule, ISSUE 4): a node
        reaching ``STEP_BATCH_END`` *parks* until every still-running node
        reaches its own batch boundary, then
        ``batch_barrier(max(now(parked)), parked_ranks)`` models the
        allreduce — the projection accounts each parked node's wait and
        jumps its clock to the barrier time — and all parked nodes
        re-enter the heap together.  Within one barrier interval every node
        advances exactly one batch: BSP at gradient granularity.  A node
        whose epoch ends (unequal shard) simply stops participating, like
        a DDP join; its peers' remaining barriers exclude it.
      * **straggler mitigation** (ISSUE 8) relaxes the parking discipline:

          - ``backup_workers=k`` releases a barrier as soon as
            ``active - k`` running ranks have parked — the slowest ``k``
            ranks at that round skip the barrier entirely (their partial
            gradient is dropped; their sample reads remain accounted) and
            simply keep stepping until they are no longer behind;
          - ``staleness_bound=s`` lets a rank run up to ``s`` batches
            ahead of the last released barrier before parking (stale-
            synchronous parallel); ``s=0`` parks at every batch boundary.

        Both reduce to the plain BSP schedule event-for-event at their
        zero settings.  A barrier released while stragglers still hold
        heap events folds only up to ``min(t_bar, earliest heap event)``
        — folding past a still-running node's next event would break the
        fold-safety invariant above.
      * finally the BSP epoch barrier: ``barrier(max(now(r)))``
        synchronizes all clocks to the slowest node.

    With ``sync="epoch"`` (default) the schedule is the PR 3 schedule,
    event for event.

    ``trace`` (ISSUE 10) is the optional flight recorder: the driver emits
    ``park`` / ``release`` / ``epoch-barrier`` events from this one shared
    loop, so barrier provenance is parity-free by construction.  With
    ``trace=None`` the schedule is byte-identical to an untraced run.
    """
    if sync not in ("epoch", "batch"):
        raise ValueError(f"unknown sync {sync!r}; expected 'epoch' or 'batch'")
    if sync == "batch" and batch_barrier is None:
        raise ValueError("sync='batch' needs a batch_barrier callback")
    if backup_workers < 0 or staleness_bound < 0:
        raise ValueError("backup_workers and staleness_bound must be >= 0")
    if (backup_workers or staleness_bound) and sync != "batch":
        raise ValueError("straggler mitigation requires sync='batch'")
    if backup_workers >= n_nodes:
        raise ValueError("backup_workers must leave at least one syncing rank")
    heap = [(now(rank), rank) for rank in range(n_nodes)]
    heapq.heapify(heap)
    parked: List[int] = []  # ranks waiting at the current allreduce barrier
    done_batches = [0] * n_nodes  # per-rank completed gradient batches
    barrier_round = 0  # allreduce barriers released so far
    active = n_nodes  # ranks whose epoch is not yet exhausted
    while heap or parked:
        if parked and (
            not heap or len(parked) >= max(1, active - backup_workers)
        ):
            # Enough running nodes reached a batch boundary: allreduce.
            t_bar = max(now(rank) for rank in parked)
            trace_emit(
                trace, "release", CLUSTER_NODE, t_bar,
                # Sorted: the parked *set* is schedule-determined, but its
                # arrival order is an engine detail (the vector engine
                # reaches equal-time boundaries in a different step order).
                round=barrier_round, ranks=tuple(sorted(parked)),
            )
            # Rounds finishing during the wait become visible — but never
            # fold past a straggler's own next event (fold safety).
            fold_all(t_bar if not heap else min(t_bar, heap[0][0]))
            assert batch_barrier is not None
            batch_barrier(t_bar, tuple(parked))
            for rank in parked:
                heapq.heappush(heap, (now(rank), rank))
            parked = []
            barrier_round += 1
            continue
        t, rank = heapq.heappop(heap)
        fold_all(t)
        signal = step(rank)
        if signal == STEP_DONE:
            active -= 1
            continue
        if sync == "batch" and signal == STEP_BATCH_END:
            done_batches[rank] += 1
            if done_batches[rank] > barrier_round + staleness_bound:
                parked.append(rank)
                trace_emit(
                    trace, "park", rank, now(rank),
                    batch=done_batches[rank], round=barrier_round,
                )
            else:
                # Behind (a dropped straggler) or within the staleness
                # window: skip this barrier and keep running.
                heapq.heappush(heap, (now(rank), rank))
        else:
            heapq.heappush(heap, (now(rank), rank))
    t_end = max(now(rank) for rank in range(n_nodes))
    trace_emit(trace, "epoch-barrier", CLUSTER_NODE, t_end)
    barrier(t_end)


def peer_probe_payload(
    registry: Optional["PeerCacheRegistry"], node_id: int, idx: int
) -> Optional[bytes]:
    """THE peer-probe sequence (registry lookup -> holder peek ->
    record_hit), shared by the demand path of both projections and the
    pre-fetch service, so the directory observes identical traffic
    everywhere.  Returns the peeked payload (real bytes on the runtime,
    :data:`SENTINEL` in the simulator) or None on a miss/eviction race."""
    if registry is None:
        return None
    holder = registry.lookup(idx, requester=node_id)
    if holder is None:
        return None
    payload = registry.cache_of(holder).peek(idx)
    if payload is None:
        return None  # evicted between lookup and read
    registry.record_hit()
    return payload


@dataclasses.dataclass
class SubstepAccess:
    """One demand read decomposed into sub-step events (ISSUE 4 tentpole).

    At ``granularity="step"`` a sample access is one scheduler event: the
    probe observes cluster state at the step's *start*, and the whole
    multi-component latency (peer RTT, bucket GET, CPU) elapses atomically
    — a prefetch round completing one microsecond into a 15.7 ms GET only
    becomes visible at the next step.  ``granularity="substep"`` makes each
    time component its own event.  :meth:`run` is a generator that yields
    control to ``drive_interleaved_epoch`` at every boundary where other
    cluster events may interleave:

      1. issue time ``t0``: local cache lookup (own completions folded);
         a RAM hit finishes the access in this event;
      2. on a local miss with a peer tier, the probe spends one RTT in
         flight — **yield** — and is evaluated against the *arrival-time*
         cluster state, so a round that completed inside that RTT turns the
         probe into a hit;
      3. payload transfer (peer streaming or the bucket GET, billed at
         issue) — **yield** — so peers fold and act *inside* the long GET,
         and this node's own insert-at-arrival happens at its true virtual
         time (the step schedule leaked demand inserts to later-code-order
         but earlier-virtual-time peer probes);
      4. arrival: miss-insert (when the demand path owns population), CPU
         overhead, per-sample accounting.

    Both projections construct this object around the same cost kernel
    (``repro.engine.kernels.DemandKernel``, precomputed from the same
    scaled models) and run the same generator — identical
    charge/record/yield order — which is what keeps sub-step specs inside
    the exact-parity domain.
    The component *sums* differ from the step schedule only on the peer-hit
    path (RTT and streaming are charged as two adds instead of one), so
    sub-step results are a different — more faithful — schedule, compared
    within, never across, granularities.
    """

    now: Callable[[], float]
    charge: Callable[[float], None]  # advance this node's clock
    fold_own: Callable[[], None]  # apply own prefetch completions <= now
    local_lookup: Callable[[int], Optional[bytes]]  # CappedCache.get
    peer_lookup: Optional[Callable[[int], Optional[bytes]]]  # None = no tier
    bucket_read: Callable[[int], bytes]  # bills the Class B GET at issue
    insert: Callable[[int, bytes], None]  # demand-path cache insert
    kernel: "DemandKernel"  # precomputed per-sample charge components
    insert_on_miss: bool
    node: int = 0  # rank the flight recorder attributes events to
    trace: Optional[TraceRecorder] = None

    def run(self, idx: int, stats: EpochStats) -> Iterator[int]:
        t0 = self.now()
        self.fold_own()
        payload = self.local_lookup(idx)
        components: List[Tuple[str, float]] = []
        class_b = 0
        if payload is not None:
            self.charge(self.kernel.ram_hit_s)
            stats.record("ram")
            tier = "ram"
            components.append(("local", self.kernel.ram_hit_s))
        else:
            if self.peer_lookup is not None:
                self.charge(self.kernel.probe_rtt_s)  # probe in flight
                components.append(("probe", self.kernel.probe_rtt_s))
                yield STEP_CONTINUE
                self.fold_own()
                payload = self.peer_lookup(idx)
            if payload is not None:
                self.charge(self.kernel.peer_stream_s)
                stats.record("peer")
                tier = "peer"
                components.append(("peer", self.kernel.peer_stream_s))
            else:
                payload = self.bucket_read(idx)
                self.charge(self.kernel.bucket_get_s)
                stats.record("bucket")
                tier = "bucket"
                class_b = 1
                components.append(("bucket", self.kernel.bucket_get_s))
            yield STEP_CONTINUE  # transfer in flight; rounds land inside it
            self.fold_own()
            if self.insert_on_miss:
                self.insert(idx, payload)
        self.charge(self.kernel.cpu_overhead_s)
        components.append(("cpu", self.kernel.cpu_overhead_s))
        stats.samples += 1
        dt = self.now() - t0
        stats.data_wait_seconds += dt
        trace_demand(
            self.trace, self.node, t0, dt, idx, tier, class_b, tuple(components)
        )


@dataclasses.dataclass
class BucketedBatchComm:
    """One gradient batch's bucketed compute/allreduce overlap pipeline
    (ISSUE 8 tentpole (b)) — the comm analogue of :class:`SubstepAccess`.

    Models the olmax-style bucketed training step: backprop emits the
    gradient in ``n_buckets`` pieces; each piece's allreduce issues as soon
    as (a) its backprop span has finished and (b) the single comm channel
    is free (bucket allreduces serialize on one channel), while the next
    span keeps computing.  At the end of the last span the node blocks
    only for the *exposed* tail of the last in-flight allreduce:

        finish_b = max(compute_end_b, finish_{b-1}) + bucket_comm_s
        exposed  = finish_last - compute_end_last      (>= 0)

    The exposed tail lands in ``allreduce_comm_seconds``; the per-batch
    barrier then charges **no** comm for overlap specs (it already
    happened here).  Since ``sum(bucket_comm_s) == allreduce_seconds`` is
    an exact partition (``CollectiveModel.bucket_seconds``), the exposed
    tail never exceeds the unbucketed duration — overlap can only hide
    communication, never add it.

    :meth:`run` is a generator yielding ``STEP_CONTINUE`` at every span
    boundary, so prefetch rounds and peer activity interleave inside the
    batch's compute exactly like sub-step access events.  Both projections
    run this generator verbatim — the simulator charges ``self.t += s``,
    the lock-step loader ``clock.sleep(s)`` (the identical float op) — so
    overlap specs stay inside the exact-parity domain.
    """

    now: Callable[[], float]
    charge: Callable[[float], None]  # advance this node's clock
    compute_span_s: float  # per-bucket backprop span (compute/n_buckets)
    bucket_comm_s: float  # per-bucket allreduce duration (comm/n_buckets)
    n_buckets: int
    node: int = 0  # rank the flight recorder attributes events to
    trace: Optional[TraceRecorder] = None

    def run(self, stats: EpochStats) -> Iterator[int]:
        finish = self.now()  # when the comm channel frees up
        for b in range(self.n_buckets):
            c0 = self.now()
            self.charge(self.compute_span_s)
            stats.compute_seconds += self.compute_span_s
            trace_emit(
                self.trace, "compute", self.node, c0, self.compute_span_s, bucket=b
            )
            ready = self.now()
            start = ready if ready > finish else finish
            finish = start + self.bucket_comm_s
            trace_emit(
                self.trace, "overlap-bucket", self.node, start,
                self.bucket_comm_s, bucket=b,
            )
            if b + 1 < self.n_buckets:
                yield STEP_CONTINUE
        exposed = finish - self.now()
        if exposed > 0:
            e0 = self.now()
            self.charge(exposed)
            stats.allreduce_comm_seconds += exposed
            trace_emit(self.trace, "overlap-exposed", self.node, e0, exposed)


class LockstepPrefetchService:
    """Deterministic pre-fetch service: completions are virtual-time events.

    One instance per node.  The constructor wires the node's calibrated
    models and sinks; ``issue`` starts a round at an explicit virtual time,
    ``advance_to`` folds every completed round's inserts into the cache.

    Parameters
    ----------
    cache: the node-local capped cache rounds insert into.
    sample_bytes / n_samples: the workload's object size and dataset size
        (timing is modelled on the *nominal* sample size, exactly like the
        simulator — payload bytes only carry content, never timing).
    bucket / network: calibrated models (Table I defaults upstream).
    store_stats: the ``StoreStats`` this node's Class A/B requests are
        billed to (the simulator's per-node accounting, or the runtime
        bucket store's stats object).
    payload_for: materializes the payload inserted for a key — the
        runtime's payload map; ``None`` inserts :data:`SENTINEL` (simulator
        mode, where caches count items).
    clock: optional clock backing the :meth:`request` convenience entry
        point (the runtime's per-node virtual clock).  ``issue`` itself
        never reads or advances any clock — callers pass ``now`` — so a
        round's modelled duration costs the training loop nothing.
    registry / node_id: the cooperative peer-cache directory, when the
        spec enables the peer tier.
    """

    def __init__(
        self,
        cache: CappedCache,
        *,
        sample_bytes: int,
        n_samples: int,
        bucket: BucketModel,
        network: NetworkModel,
        store_stats: StoreStats,
        n_connections: int = 16,
        list_every_fetch: bool = True,
        streaming_insert: bool = False,
        payload_for: Optional[Callable[[int], bytes]] = None,
        clock: Optional[Clock] = None,
        registry: Optional["PeerCacheRegistry"] = None,
        node_id: int = 0,
        trace: Optional[TraceRecorder] = None,
    ):
        self.cache = cache
        self.sample_bytes = sample_bytes
        self.n_samples = n_samples
        self.bucket = bucket
        self.network = network
        self.store_stats = store_stats
        self.n_connections = n_connections
        self.list_every_fetch = list_every_fetch
        self.streaming_insert = streaming_insert
        self.payload_for = payload_for
        self.clock = clock
        self.registry = registry
        self.node_id = node_id
        self.trace = trace
        # Flight-recorder provenance for issued rounds: the epoch drivers
        # stamp the installed planner's policy family here ("paper" /
        # "oracle" / "cluster-oracle") at epoch begin.  Observe-only — the
        # partition itself never reads it.
        self.provenance = "paper"
        # Event state: the single worker's availability + pending insert
        # events, each ``(completion_time, [(key, payload), ...])``.
        self.free_at = 0.0
        self.pending: List[Tuple[float, List[Tuple[int, bytes]]]] = []
        self.rounds = 0
        self.samples_fetched = 0
        # Round keys pulled from a peer's cache instead of the bucket.
        self.peer_fetches = 0
        # Cluster-placement state (``set_placement``): the keys THIS rank
        # owns (None = no placement, historical behaviour), keys deferred
        # because no peer held them yet, and a lifetime deferral counter.
        self._owned: Optional[frozenset] = None
        self._deferred: List[int] = []
        self._in_flight: Optional[set] = None
        self.placement_deferrals = 0

    def set_placement(
        self,
        owned: Optional[Sequence[int]],
        in_flight: Optional[set] = None,
    ) -> None:
        """Install the epoch's ownership set (cluster placement).  Called by
        both projections' epoch drivers right after the epoch planner is
        built; resets the deferral queue — deferred keys from a finished
        epoch are already past their uses.  ``in_flight`` is the
        cluster-SHARED issued-but-not-yet-inserted key set (one per
        ``ClusterPlacementPlanner``): every rank's service marks its bucket
        keys at issue and clears them at insertion, so any rank can tell "a
        copy of this key is on its way" from "no copy exists anywhere".
        Never cleared here — a round straddling the epoch barrier still
        clears its own keys at its insertion event."""
        self._owned = None if owned is None else frozenset(owned)
        self._in_flight = in_flight
        self._deferred = []

    # -- peer probe (identical sequence to the demand path) ------------------
    def _peer_probe(self, idx: int) -> bool:
        """True when a peer's cache can serve ``idx`` right now."""
        return peer_probe_payload(self.registry, self.node_id, idx) is not None

    def _payload(self, key: int) -> bytes:
        return SENTINEL if self.payload_for is None else self.payload_for(key)

    # -- event API -----------------------------------------------------------
    def issue(
        self,
        keys: Sequence[int],
        now: float,
        stats: Optional[EpochStats] = None,
        replay: bool = False,
    ) -> float:
        """Start one fetch round at virtual time ``now``; returns its
        completion time.  Class A/B billing happens here (request issue),
        insertion happens at the completion event (``advance_to``).

        ``replay=True`` marks a round *re-announced* during a mid-epoch
        checkpoint resume (``DeliLoader``): its keys were fetched — and
        billed — before the crash, so still-cached keys are filtered out
        and a fully-resident round is a no-op (no listing, no Class B, no
        worker time).  Keys the capped cache evicted since the checkpoint
        are genuinely gone and are re-fetched (and re-billed) as a normal
        round.  Never set for live rounds: live billing is parity-exact
        with the simulator, which fetches every announced key."""
        keys = list(keys)
        if replay:
            keys = [k for k in keys if not self.cache.contains(k)]
            if not keys:
                return now
        n_retry = 0
        if self._deferred:
            # Placement: keys deferred at earlier rounds (owner fetch in
            # flight then) retry ahead of this round's keys — their
            # deadlines are earlier.  Locally-resident ones are dropped: a
            # demand probe already pulled them.
            retry = [k for k in self._deferred if not self.cache.contains(k)]
            self._deferred = []
            n_retry = len(retry)
            keys = retry + keys
        start = max(now, self.free_at)
        listing_s = 0.0
        class_a = 0
        if self.list_every_fetch or self.rounds == 0:
            listing_s = self.bucket.list_seconds(self.n_samples)
            class_a = max(1, -(-self.n_samples // self.bucket.page_size))
            self.store_stats.class_a_requests += class_a
        # Peer tier: keys a peer already holds travel the inter-node network
        # (sequential RPCs) instead of costing bucket GETs; failed probes pay
        # the lookup RTT — the same charges as the demand path.  Under
        # cluster placement, a non-owned key whose probe failed splits on
        # the shared in-flight set: a fetch already issued somewhere means
        # the copy is on its way — defer and retry next round (a peer hit
        # by then).  No copy resident AND none in flight means the owner
        # fetched and later evicted it under capacity pressure (ownership
        # puts the owner's announce at or before any consumer's, so "not
        # yet issued" is the rare straggler race) — the consumer
        # bulk-fetches it itself.  The invariant is "never a duplicate
        # bucket GET while a copy is resident or in flight"; an absent copy
        # must not degrade a cheap amortized prefetch GET into a serial
        # demand GET.
        bucket_keys = keys
        fetch_keys = keys  # the keys this round actually delivers
        peer_s = 0.0
        n_peer = 0
        n_deferred = 0
        dup_keys: List[int] = []
        if self.registry is not None:
            bucket_keys = []
            fetch_keys = []
            for k in keys:
                probe_hit = self._peer_probe(k)
                trace_emit(
                    self.trace, "probe", self.node_id, now,
                    idx=k, hit=int(probe_hit),
                )
                if probe_hit:
                    n_peer += 1
                    fetch_keys.append(k)
                elif self._owned is None or k in self._owned:
                    bucket_keys.append(k)
                    fetch_keys.append(k)
                elif self._in_flight is None or k in self._in_flight:
                    self._deferred.append(k)
                    n_deferred += 1
                else:
                    # Owner copy neither resident nor in flight: duplicate
                    # (bulk) GET beats a guaranteed serial demand GET.
                    bucket_keys.append(k)
                    fetch_keys.append(k)
                    dup_keys.append(k)
            self.placement_deferrals += n_deferred
            if self._in_flight is not None:
                self._in_flight.update(bucket_keys)
            peer_s = n_peer * self.network.transfer_seconds(
                self.sample_bytes
            ) + (len(bucket_keys) + n_deferred) * self.network.lookup_seconds()
            self.peer_fetches += n_peer
            if stats is not None and n_peer:
                stats.record("peer", n_peer)
        # The round's keys are known at issue, so the (naive) per-round
        # listing proceeds CONCURRENTLY with the parallel GETs — it is pure
        # Class A accounting traffic, not a serialization point.
        dur = max(
            listing_s,
            self.bucket.bulk_get_seconds(
                [self.sample_bytes] * len(bucket_keys), self.n_connections
            )
            + peer_s,
        )
        done = start + dur
        self.store_stats.class_b_requests += len(bucket_keys)
        self.store_stats.bytes_read += len(bucket_keys) * self.sample_bytes
        self.store_stats.read_seconds += dur
        trace_emit(
            self.trace, "issue", self.node_id, start, dur,
            round=self.rounds, provenance=self.provenance, done=done,
            n_keys=len(keys), n_retry=n_retry, n_peer=n_peer,
            n_bucket=len(bucket_keys) - len(dup_keys), n_dup=len(dup_keys),
            n_deferred=n_deferred, dup=tuple(dup_keys),
            keys=tuple(bucket_keys),
            class_a=class_a, class_b=len(bucket_keys),
        )
        items = [(k, self._payload(k)) for k in fetch_keys]
        if self.streaming_insert:
            # Spread inserts uniformly across the round duration (insert
            # order still matters for FIFO eviction).  A fully-deferred
            # placement round delivers nothing (items empty) yet still
            # advances the worker clock by its probe RTTs.
            if items:
                per = dur / len(items)
                for j, item in enumerate(items):
                    self.pending.append((start + per * (j + 1), [item]))
        elif items:
            self.pending.append((done, items))
        self.free_at = done
        self.rounds += 1
        return done

    def advance_to(self, now: float) -> int:
        """Fold every round completed by virtual time ``now`` into the
        cache (bulk insert, round order then key order); returns the number
        of samples inserted.  This is the completion *event* — callers
        invoke it at the defined barriers (own sample access; every
        interleaved-scheduler step for peers)."""
        if not self.pending:
            return 0
        inserted = 0
        remaining: List[Tuple[float, List[Tuple[int, bytes]]]] = []
        for done, items in self.pending:
            if done <= now:
                # Cache-insert events pin to the round's completion time:
                # the fold may be driven by another node's clock (fold_all),
                # which must never leak into this node's timestamps.
                if self.trace is not None:
                    self.trace.pin(done)
                for k, payload in items:
                    self.cache.put(k, payload)
                    if self._in_flight is not None:
                        self._in_flight.discard(k)
                if self.trace is not None:
                    self.trace.unpin()
                    self.trace.emit(
                        "advance", self.node_id, done,
                        n=len(items), keys=tuple(k for k, _ in items),
                    )
                inserted += len(items)
            else:
                remaining.append((done, items))
        self.pending = remaining
        self.samples_fetched += inserted
        return inserted

    # -- runtime-facing conveniences (PrefetchService-shaped) ----------------
    def request(
        self,
        keys: Sequence[int],
        stats: Optional[EpochStats] = None,
        replay: bool = False,
    ) -> float:
        """Loader entry point: issue a round at the node clock's now."""
        if self.clock is None:
            raise ValueError(
                "request() needs the service constructed with a clock; "
                "clockless callers (the simulator) use issue(keys, now=...)"
            )
        return self.issue(keys, now=self.clock.now(), stats=stats, replay=replay)

    def drain(self, timeout: float = 0.0) -> bool:
        """No-op: lock-step completions are *events*, folded strictly by
        ``advance_to`` at the parity barriers — force-completing them here
        would diverge from the simulator.  Exists for interface symmetry
        with the threaded ``PrefetchService``."""
        return True

    def close(self) -> None:
        """No worker thread to stop; interface symmetry only."""

    def __enter__(self) -> "LockstepPrefetchService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
