"""Mixture-of-Experts FFN with TPU-native expert parallelism.

Routing is top-k with capacity-based token dropping (GShard-style,
first-come-first-served by sequence position), expressed WITHOUT the giant
(tokens, experts, capacity) one-hot dispatch tensors: each expert *selects*
its assigned tokens with ``lax.top_k`` over an assignment score, computes a
dense (capacity, d_ff) FFN, and scatter-adds the result back.  All shapes
are static => AOT-lowerable for the dry-run.

Distribution: experts are sharded over the ``model`` mesh axis (EP ≡ TP for
the FFN).  Under tensor parallelism the block input is already replicated
across ``model``, so dispatch needs NO all-to-all at all: every shard
locally selects the tokens routed to *its* experts, computes them, and a
single ``psum`` over ``model`` combines expert outputs — the same collective
a TP dense FFN would need anyway.  (This adaptation — replicated-activation
EP instead of GPU-style all-to-all EP — is recorded in DESIGN.md §7.)

Both the sharded path (shard_map) and the local path (no mesh) share
``_expert_select_compute``, so tests can assert they agree exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """How model code sees the device mesh (None => single-process local)."""

    mesh: object  # jax.sharding.Mesh
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]


def router_probs(x_flat: jax.Array, router_w: jax.Array, top_k: int):
    """Top-k routing with renormalized combine weights.

    Returns (topk_idx (T,k) int32, topk_w (T,k) f32).
    """
    logits = jnp.einsum(
        "td,de->te", x_flat, router_w, preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, top_k)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)
    return topk_idx, topk_w


def _expert_weight(topk_idx, topk_w, e: jax.Array, n_experts: int):
    """Combine weight of expert ``e`` for every token (0 if not routed)."""
    sel = (topk_idx == e).astype(topk_w.dtype)  # (T, k)
    return (topk_w * sel).sum(-1)  # (T,)


def _expert_select_compute(
    x_flat: jax.Array,  # (T, d)
    weight: jax.Array,  # (T,) combine weight for this expert (0 = unrouted)
    w_gate: jax.Array,  # (d, f)
    w_up: jax.Array,
    w_down: jax.Array,  # (f, d)
    capacity: int,
    act: str,
) -> jax.Array:
    """One expert: select (<= capacity) assigned tokens, FFN, scatter back."""
    T, d = x_flat.shape
    assigned = weight > 0
    # Earlier tokens win capacity (GShard FCFS). Score: T-pos for assigned,
    # -1 for unassigned — top_k picks assigned tokens in position order.
    score = jnp.where(assigned, T - jnp.arange(T, dtype=jnp.int32), -1)
    top_scores, idx = jax.lax.top_k(score, min(capacity, T))
    valid = (top_scores > 0).astype(jnp.float32)  # (C,)
    xsel = x_flat[idx]  # (C, d)
    g = jnp.einsum("cd,df->cf", xsel, w_gate, preferred_element_type=jnp.float32)
    if act == "swiglu":
        u = jnp.einsum("cd,df->cf", xsel, w_up, preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g)
    y = jnp.einsum(
        "cf,fd->cd", h.astype(x_flat.dtype), w_down, preferred_element_type=jnp.float32
    )
    scale = (weight[idx] * valid)[:, None]  # zero out invalid slots
    out = jnp.zeros((T, d), jnp.float32).at[idx].add(y * scale)
    return out


def _capacity(n_tokens: int, cfg: ArchConfig, capacity_factor: Optional[float]) -> int:
    f = capacity_factor if capacity_factor is not None else cfg.capacity_factor
    return max(1, int(n_tokens * cfg.top_k / cfg.n_experts * f))


def _moe_local(params, x_flat, cfg: ArchConfig, capacity: int, n_local: int, e0):
    """Compute ``n_local`` experts starting at id ``e0`` over local tokens."""
    topk_idx, topk_w = router_probs(x_flat, params["router"], cfg.top_k)

    def one_expert(i, acc):
        e = e0 + i
        w = _expert_weight(topk_idx, topk_w, e, cfg.n_experts)
        out = _expert_select_compute(
            x_flat,
            w,
            params["w_gate"][i],
            params["w_up"][i] if cfg.mlp_act == "swiglu" else params["w_gate"][i],
            params["w_down"][i],
            capacity,
            cfg.mlp_act,
        )
        return acc + out

    acc0 = jnp.zeros(x_flat.shape, jnp.float32)
    return jax.lax.fori_loop(0, n_local, one_expert, acc0)


def moe_block(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    ctx: Optional[MeshContext] = None,
    capacity_factor: Optional[float] = None,
) -> jax.Array:
    """MoE FFN. params: router (d,E), w_gate/w_up (E,d,f), w_down (E,f,d)."""
    B, S, d = x.shape
    dt = x.dtype

    if ctx is None or ctx.model_size == 1:
        x_flat = x.reshape(B * S, d)
        cap = _capacity(B * S, cfg, capacity_factor)
        y = _moe_local(params, x_flat, cfg, cap, cfg.n_experts, 0)
        return y.astype(dt).reshape(B, S, d)

    if cfg.n_experts % ctx.model_size != 0:
        raise ValueError(
            f"n_experts={cfg.n_experts} not divisible by model axis {ctx.model_size}"
        )
    n_local = cfg.n_experts // ctx.model_size
    bd = P(ctx.batch_axes, None, None)
    ma = ctx.model_axis

    def sharded(x_loc, router_w, w_gate, w_up, w_down):
        # x_loc: (B_loc, S, d) — replicated over `model`; experts sharded.
        Bl, Sl, dl = x_loc.shape
        x_flat = x_loc.reshape(Bl * Sl, dl)
        cap = _capacity(Bl * Sl, cfg, capacity_factor)
        e0 = jax.lax.axis_index(ma) * n_local
        p_loc = {"router": router_w, "w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        y = _moe_local(p_loc, x_flat, cfg, cap, n_local, e0)
        # Combine expert outputs in the model dtype: each shard's partial sum
        # is already an f32 accumulation; the cross-shard psum carries bf16
        # (halves the per-layer combine collective — grad-compression-style).
        y = jax.lax.psum(y.astype(dt), ma)
        return y.reshape(Bl, Sl, dl)

    w_up = params["w_up"] if cfg.mlp_act == "swiglu" else params["w_gate"]
    y = jax.shard_map(
        sharded,
        mesh=ctx.mesh,
        in_specs=(bd, P(None, None), P(ma, None, None), P(ma, None, None), P(ma, None, None)),
        out_specs=bd,
        check_vma=False,
    )(x, params["router"], params["w_gate"], w_up, params["w_down"])
    return y.astype(dt)


def moe_param_shapes(cfg: ArchConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    shapes = {"router": (d, E), "w_gate": (E, d, f), "w_down": (E, f, d)}
    if cfg.mlp_act == "swiglu":
        shapes["w_up"] = (E, d, f)
    return shapes


def moe_reference(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Oracle: dense all-experts compute, exact top-k combine, NO capacity
    limit.  moe_block converges to this as capacity_factor -> inf."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d).astype(jnp.float32)
    topk_idx, topk_w = router_probs(x_flat, params["router"], cfg.top_k)
    y = jnp.zeros_like(x_flat)
    for e in range(cfg.n_experts):
        g = x_flat @ params["w_gate"][e].astype(jnp.float32)
        if cfg.mlp_act == "swiglu":
            u = x_flat @ params["w_up"][e].astype(jnp.float32)
            h = jax.nn.silu(g) * u
        else:
            h = jax.nn.gelu(g)
        out = h @ params["w_down"][e].astype(jnp.float32)
        w = (topk_w * (topk_idx == e)).sum(-1)
        y = y + out * w[:, None]
    return y.astype(x.dtype).reshape(B, S, d)
