"""Architecture configuration: one frozen dataclass describes every model in
the zoo (dense / MoE / SSM / hybrid / encoder-only / VLM-backbone).

A model is a stack of ``n_layers`` layers organized as ``n_layers / len(period)``
repeating *periods*.  ``period[i]`` names the token mixer of position ``i``
("attn" or "ssm"); ``mlp_pattern[i]`` names its channel mixer ("mlp", "moe"
or "none").  Homogeneous models use a period of length 1; Jamba's 1:7
attention:Mamba interleave with MoE every other layer is a period of 8.
Scanning over periods keeps compile time O(period) instead of O(depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0  # 0 => MHA (== n_heads)
    head_dim: int = 0  # 0 => d_model // n_heads

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- attention ----------------------------------------------------------
    window: Optional[int] = None  # sliding-window size (None = full)
    causal: bool = True  # False => bidirectional encoder
    rope_theta: float = 10_000.0
    attn_chunk: int = 1024  # kv-chunk for flash-style chunked attention
    qkv_bias: bool = False

    # --- SSM (Mamba-2 / SSD) --------------------------------------------------
    ssm_state: int = 0  # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # P
    ssm_groups: int = 1  # G
    ssm_conv: int = 4
    ssm_chunk: int = 128  # SSD chunk length Q

    # --- layer pattern --------------------------------------------------------
    period: Tuple[str, ...] = ("attn",)
    mlp_pattern: Tuple[str, ...] = ("mlp",)
    mlp_act: str = "swiglu"  # swiglu (3 mats) | gelu (2 mats)

    # --- embeddings / io -------------------------------------------------------
    frontend: str = "none"  # none | patch (vlm) | frame (audio)
    n_frontend_tokens: int = 0  # patch/frame positions occupied per sample
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- kernels ---------------------------------------------------------------
    # Route attention / SSD through the Pallas TPU kernels (kernels/ops.py).
    # On CPU the kernels run in interpret mode (slow but exact) — models
    # default to the XLA reference path; flip on TPU or in kernel tests.
    use_pallas: bool = False

    def __post_init__(self):
        if self.n_kv_heads == 0:
            object.__setattr__(self, "n_kv_heads", self.n_heads)
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if len(self.period) != len(self.mlp_pattern):
            raise ValueError("period and mlp_pattern must have equal length")
        if self.n_layers % len(self.period) != 0:
            raise ValueError(
                f"n_layers={self.n_layers} not divisible by period {len(self.period)}"
            )
        for kind in self.period:
            if kind not in ("attn", "ssm"):
                raise ValueError(f"unknown mixer kind {kind!r}")
        for kind in self.mlp_pattern:
            if kind not in ("mlp", "moe", "none"):
                raise ValueError(f"unknown mlp kind {kind!r}")
        if "moe" in self.mlp_pattern and (self.n_experts < 2 or self.top_k < 1):
            raise ValueError("moe layers need n_experts>=2 and top_k>=1")

    # -- derived -----------------------------------------------------------
    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return "attn" in self.period

    @property
    def has_ssm(self) -> bool:
        return "ssm" in self.period

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def subquadratic(self) -> bool:
        """True if context cost/token is O(1) or O(window) — the long_500k
        eligibility rule: SSM, hybrid, or sliding-window attention."""
        if not self.has_attention:
            return True
        return self.has_ssm or self.window is not None

    def param_count(self) -> int:
        """Exact parameter count (embeddings included)."""
        total = self.vocab * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.d_model * self.vocab  # head
        total += self.d_model  # final norm
        d, hd = self.d_model, self.head_dim
        for mixer, mlp in zip(self.period, self.mlp_pattern):
            n = self.n_periods
            total += n * d  # norm1
            if mixer == "attn":
                q = self.n_heads * hd
                kv = self.n_kv_heads * hd
                total += n * (d * q + 2 * d * kv + q * d)
                if self.qkv_bias:
                    total += n * (q + 2 * kv)
            else:
                di, g, N, H = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
                conv_dim = di + 2 * g * N
                total += n * (
                    d * (2 * di + 2 * g * N + H)  # in_proj (x,z,B,C,dt)
                    + conv_dim * self.ssm_conv  # conv
                    + 3 * H  # A_log, D, dt_bias
                    + di  # gated norm
                    + di * d  # out_proj
                )
            if mlp != "none":
                total += n * d  # norm2
            n_mats = 3 if self.mlp_act == "swiglu" else 2
            if mlp == "mlp":
                total += n * n_mats * d * self.d_ff
            elif mlp == "moe":
                total += n * (d * self.n_experts + self.n_experts * n_mats * d * self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if "moe" not in self.mlp_pattern:
            return self.param_count()
        total = self.param_count()
        n_mats = 3 if self.mlp_act == "swiglu" else 2
        expert_mats = self.d_model * self.d_ff * n_mats
        for mlp in self.mlp_pattern:
            if mlp == "moe":
                total -= self.n_periods * (self.n_experts - self.top_k) * expert_mats
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (paper-assigned shape sets)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """The dry-run cells for an architecture, per the assignment rules:
    encoder-only archs skip decode shapes; long_500k requires sub-quadratic
    attention (SSM / hybrid / SWA)."""
    out = []
    for s in ALL_SHAPES:
        if s.kind == "decode" and cfg.is_encoder:
            continue
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s)
    return tuple(out)
