"""Primitive layers: norms, RoPE, GQA attention (flash-style chunked), MLPs.

Everything is a pure function ``f(params, x, cfg, ...)`` over plain dict
pytrees — no framework.  Matmuls accumulate in fp32 (``preferred_element_type``)
and activations stay in the config dtype, which is what the MXU wants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (S,) or broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnMask:
    causal: bool
    window: Optional[int] = None  # keys with qpos - kpos >= window are masked
    kv_len: Optional[jax.Array] = None  # valid KV prefix length (decode padding)


def _mask_block(
    qpos: jax.Array, kpos: jax.Array, m: AttnMask
) -> jax.Array:
    """Boolean (…, Sq, Sk) mask block from absolute positions."""
    ok = jnp.ones((qpos.shape[-1], kpos.shape[-1]), dtype=bool)
    if m.causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if m.window is not None:
        ok &= kpos[None, :] > (qpos[:, None] - m.window)
    if m.kv_len is not None:
        # kv_len broadcasts per batch: (B, 1, 1) vs (Sq, Sk)
        ok = ok[None] & (kpos[None, None, :] < m.kv_len[:, None, None])
    return ok


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: AttnMask,
    *,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Flash-style attention: scan over KV chunks with an online softmax.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H = KV * G (GQA).
    Never materializes the (Sq, Sk) score matrix — peak live memory is
    O(Sq * chunk), which is what makes prefill_32k lowerable.  This is the
    XLA reference path; the Pallas kernel (kernels/flash_attention.py) is
    the TPU-optimized equivalent of this same computation.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    qg = (q * scale).reshape(B, Sq, KV, G, hd)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, hd)
    vc = v.reshape(B, n_chunks, chunk, KV, hd)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m_i, l_i, acc = carry
        j, k_j, v_j = xs
        kpos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqkgh,bckh->bkgqc", qg, k_j, preferred_element_type=jnp.float32
        )  # (B, KV, G, Sq, chunk)
        ok = _mask_block(qpos, kpos, mask)
        valid = kpos < Sk  # exclude right padding
        ok = ok & valid[..., None, :] if ok.ndim == 3 else ok & valid[None, :]
        # broadcast mask to (B, KV, G, Sq, chunk)
        if ok.ndim == 2:
            okb = ok[None, None, None]
        else:  # (B, Sq, chunk) from kv_len masking
            okb = ok[:, None, None]
        s = jnp.where(okb, s, -jnp.inf)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        # Rows with no valid key yet keep m=-inf; guard exp(-inf - -inf).
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(okb, p, 0.0)
        alpha = jnp.where(jnp.isneginf(m_i), 0.0, jnp.exp(m_i - m_safe))
        l_new = l_i * alpha + p.sum(axis=-1)
        # p is consumed by an MXU matmul: store it in the model dtype (the
        # statistics m/l and the accumulator stay f32) — this is what the
        # Pallas kernel does on TPU, and it halves the dominant HBM stream
        # of the 32k-context cells (exp-weight blocks).
        pv = jnp.einsum(
            "bkgqc,bckh->bkgqh", p.astype(v_j.dtype), v_j,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), dtype=jnp.float32)
    acc0 = jnp.zeros((B, KV, G, Sq, hd), dtype=jnp.float32)
    xs = (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, acc0), xs)
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]  # (B, KV, G, Sq, hd)
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)


def plain_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: AttnMask, q_offset: int = 0
) -> jax.Array:
    """Direct softmax attention (oracle for tests; decode fast path)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = (q * hd ** -0.5).reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32)
    ok = _mask_block(q_offset + jnp.arange(Sq), jnp.arange(Sk), mask)
    okb = ok[None, None, None] if ok.ndim == 2 else ok[:, None, None]
    s = jnp.where(okb, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v, preferred_element_type=jnp.float32)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)


def attention_block(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: jax.Array,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    kv_len: Optional[jax.Array] = None,
    use_chunked: bool = True,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full attention sub-layer: qkv proj, rope, SDPA, out proj.

    Training/prefill: ``kv_cache=None`` — attends within ``x``.
    Decode: ``kv_cache=(K, V)`` of shape (B, S_max, KV, hd); the new token's
    K/V are written at ``cache_pos`` and attention runs over the cache.
    """
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,dq->bsq", x, params["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dq->bsq", x, params["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dq->bsq", x, params["wv"], preferred_element_type=jnp.float32)
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.astype(dt).reshape(B, S, H, hd)
    k = k.astype(dt).reshape(B, S, KV, hd)
    v = v.astype(dt).reshape(B, S, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is None:
        mask = AttnMask(causal=cfg.causal, window=cfg.window)
        if cfg.use_pallas:
            from repro.kernels.ops import flash_attention  # lazy: no cycle

            out = flash_attention(q, k, v, causal=cfg.causal, window=cfg.window)
        elif use_chunked and S > cfg.attn_chunk:
            out = chunked_attention(q, k, v, mask, chunk=cfg.attn_chunk)
        else:
            out = plain_attention(q, k, v, mask)
    else:
        K, V = kv_cache
        assert cache_pos is not None
        K = jax.lax.dynamic_update_slice_in_dim(K, k, cache_pos, axis=1)
        V = jax.lax.dynamic_update_slice_in_dim(V, v, cache_pos, axis=1)
        new_cache = (K, V)
        q_off = cache_pos  # query absolute position == its cache slot
        mask = AttnMask(causal=cfg.causal, window=cfg.window, kv_len=kv_len)
        out = plain_attention(q, K, V, mask, q_offset=q_off)
    y = jnp.einsum(
        "bsq,qd->bsd", out.reshape(B, S, H * hd), params["wo"],
        preferred_element_type=jnp.float32,
    )
    return y.astype(dt), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_block(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"], preferred_element_type=jnp.float32)
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(dt)
    else:  # gelu: classic 2-matrix MLP (encoder stacks)
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"], preferred_element_type=jnp.float32)
        h = jax.nn.gelu(u).astype(dt)
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"], preferred_element_type=jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Parameter initialization helpers
# ---------------------------------------------------------------------------
def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype, fan_in: int) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * fan_in ** -0.5).astype(dtype)


def attn_param_shapes(cfg: ArchConfig) -> dict:
    q = cfg.n_heads * cfg.head_dim
    kv = cfg.n_kv_heads * cfg.head_dim
    d = cfg.d_model
    shapes = {"wq": (d, q), "wk": (d, kv), "wv": (d, kv), "wo": (q, d)}
    if cfg.qkv_bias:
        shapes.update({"bq": (q,), "bk": (kv,), "bv": (kv,)})
    return shapes


def mlp_param_shapes(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}
    return {"w_up": (d, f), "w_down": (f, d)}
