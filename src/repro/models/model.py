"""Full model assembly: embeddings -> scanned period stack -> head / losses /
decode state.

A model is ``n_periods`` repetitions of the config's layer *period* (see
``ArchConfig``).  Parameters for period position ``i`` live under
``params["stack"][f"pos{i}"]`` with a leading ``n_periods`` axis, and the
stack is driven by ``jax.lax.scan`` so compile time and HLO size are
O(len(period)), not O(n_layers) — essential for lowering 72-layer models on
a 512-device mesh in this container.

Entry points (all pure functions over plain dict pytrees):

  init_params / param_shapes     parameters (real / ShapeDtypeStruct)
  forward                        token/frame embeddings -> final hidden
  train_loss                     chunked-vocab cross entropy (never
                                 materializes (B,S,V) for the full sequence)
  prefill                        forward + KV/SSM decode state
  init_decode_state / decode_step one-token serving step
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (
    attention_block,
    attn_param_shapes,
    dense_init,
    mlp_block,
    mlp_param_shapes,
    rms_norm,
)
from repro.models.moe import MeshContext, moe_block, moe_param_shapes
from repro.models.ssm import (
    ssm_block,
    ssm_block_decode,
    ssm_empty_carry,
    ssm_param_shapes,
)

# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------
_F32_LEAVES = ("A_log", "D", "dt_bias")  # small SSM params stay f32


def _mixer_shapes(cfg: ArchConfig, kind: str) -> dict:
    return attn_param_shapes(cfg) if kind == "attn" else ssm_param_shapes(cfg)


def _mlp_shapes(cfg: ArchConfig, kind: str) -> dict:
    if kind == "mlp":
        return mlp_param_shapes(cfg)
    if kind == "moe":
        return moe_param_shapes(cfg)
    return {}


def _position_shapes(cfg: ArchConfig, i: int) -> dict:
    mixer, mlp = cfg.period[i], cfg.mlp_pattern[i]
    shapes = {"norm1": (cfg.d_model,), "mixer": _mixer_shapes(cfg, mixer)}
    if mlp != "none":
        shapes["norm2"] = (cfg.d_model,)
        shapes["mlp"] = _mlp_shapes(cfg, mlp)
    return shapes


def _init_leaf(key, name: str, shape, cfg: ArchConfig, stacked: int = 0):
    """One parameter leaf.  ``stacked`` > 0 prepends the period axis."""
    full = (stacked, *shape) if stacked else tuple(shape)
    dt = jnp.float32 if name in _F32_LEAVES else cfg.jnp_dtype
    if name.startswith("norm") or name in ("gate_norm", "final_norm"):
        return jnp.ones(full, dt)
    if name in ("conv_b", "dt_bias") or name.startswith("b"):
        return jnp.zeros(full, dt)
    if name == "A_log":
        return jnp.zeros(full, dt)  # A = -exp(0) = -1
    if name == "D":
        return jnp.ones(full, dt)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return dense_init(key, full, dt, fan_in)


def _init_tree(key, tree, cfg: ArchConfig, stacked: int = 0):
    out = {}
    for name, sub in tree.items():
        key, sub_key = jax.random.split(key)
        if isinstance(sub, dict):
            out[name] = _init_tree(sub_key, sub, cfg, stacked)
        else:
            out[name] = _init_leaf(sub_key, name, sub, cfg, stacked)
    return out


def init_params(key: jax.Array, cfg: ArchConfig) -> Dict:
    """Real parameter pytree (use only for reduced/smoke configs!)."""
    keys = jax.random.split(key, len(cfg.period) + 3)
    params: Dict = {}
    if cfg.frontend != "frame":  # audio encoders take embeddings directly
        params["embed"] = dense_init(keys[0], (cfg.vocab, cfg.d_model), cfg.jnp_dtype, cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab), cfg.jnp_dtype, cfg.d_model)
    params["final_norm"] = jnp.ones((cfg.d_model,), cfg.jnp_dtype)
    params["stack"] = {
        f"pos{i}": _init_tree(keys[3 + i], _position_shapes(cfg, i), cfg, cfg.n_periods)
        for i in range(len(cfg.period))
    }
    return params


def param_shapes(cfg: ArchConfig) -> Dict:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Embedding frontends
# ---------------------------------------------------------------------------
def embed_inputs(params: Dict, cfg: ArchConfig, batch: Dict) -> jax.Array:
    """(B, S, d) initial hidden states from the modality frontend.

    * text:   token embedding lookup
    * vlm:    token embedding; the first ``n_frontend_tokens`` positions are
              overwritten with precomputed patch embeddings (frontend stub)
    * audio:  precomputed frame embeddings *are* the input (no vocab lookup)
    """
    if cfg.frontend == "frame":
        return batch["frame_embeds"].astype(cfg.jnp_dtype)
    x = params["embed"][batch["tokens"]]  # (B, S, d)
    if cfg.frontend == "patch":
        patches = batch["patch_embeds"].astype(x.dtype)  # (B, P, d)
        x = jax.lax.dynamic_update_slice(x, patches, (0, 0, 0))
    return x


# ---------------------------------------------------------------------------
# The period body (one repetition of cfg.period)
# ---------------------------------------------------------------------------
def _channel_mix(p: dict, x, cfg: ArchConfig, kind: str, ctx: Optional[MeshContext]):
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if kind == "moe":
        return x + moe_block(p["mlp"], h, cfg, ctx)
    return x + mlp_block(p["mlp"], h, cfg)


def _period_forward(
    pslice: dict,
    x: jax.Array,
    cfg: ArchConfig,
    ctx: Optional[MeshContext],
    positions: jax.Array,
    collect_cache: bool,
    inner_remat: bool = False,
):
    """One period over a full sequence (train / prefill).

    Returns (x, caches) where caches[f"pos{i}"] holds the decode carry for
    position ``i`` (attn: dict(k,v); ssm: dict(state,conv)) when
    ``collect_cache`` — else an empty dict.

    ``inner_remat`` additionally checkpoints every SUBLAYER, so the backward
    pass holds one sublayer's FSDP-gathered weights + intermediates at a
    time instead of the whole period's — this is what keeps the long-period
    MoE hybrids (jamba: 8 sublayers with 4 expert banks per period) inside
    the 16 GB/chip HBM budget.
    """

    def ck(f, *args):
        return jax.checkpoint(f)(*args) if inner_remat else f(*args)

    caches = {}
    for i, (mixer, mlp) in enumerate(zip(cfg.period, cfg.mlp_pattern)):
        p = pslice[f"pos{i}"]
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if mixer == "attn":
            y, _ = ck(
                lambda pm, hh: attention_block(pm, hh, cfg, positions=positions),
                p["mixer"], h,
            )
            if collect_cache:
                # Recompute K/V cheaply for the cache (avoids threading them
                # out of attention_block's chunked path).
                from repro.models.layers import apply_rope  # local import

                B, S, _ = h.shape
                k = jnp.einsum("bsd,dq->bsq", h, p["mixer"]["wk"]).reshape(
                    B, S, cfg.n_kv_heads, cfg.head_dim
                )
                v = jnp.einsum("bsd,dq->bsq", h, p["mixer"]["wv"]).reshape(
                    B, S, cfg.n_kv_heads, cfg.head_dim
                )
                if cfg.qkv_bias:
                    k = k + p["mixer"]["bk"].reshape(1, 1, cfg.n_kv_heads, cfg.head_dim)
                    v = v + p["mixer"]["bv"].reshape(1, 1, cfg.n_kv_heads, cfg.head_dim)
                k = apply_rope(k, positions, cfg.rope_theta)
                caches[f"pos{i}"] = {"k": k.astype(cfg.jnp_dtype), "v": v.astype(cfg.jnp_dtype)}
        else:
            y, carry = ck(lambda pm, hh: ssm_block(pm, hh, cfg), p["mixer"], h)
            if collect_cache:
                caches[f"pos{i}"] = {"state": carry[0], "conv": carry[1]}
        x = x + y
        if mlp != "none":
            x = ck(
                lambda pp, xx, kind=mlp: _channel_mix(pp, xx, cfg, kind, ctx), p, x
            )
    return x, caches


def _period_decode(
    pslice: dict,
    cslice: dict,
    x: jax.Array,
    cfg: ArchConfig,
    ctx: Optional[MeshContext],
    cache_pos: jax.Array,
    kv_len: jax.Array,
):
    """One period for one new token. cslice holds this period's caches."""
    new_caches = {}
    positions = jnp.reshape(cache_pos, (1,))
    for i, (mixer, mlp) in enumerate(zip(cfg.period, cfg.mlp_pattern)):
        p = pslice[f"pos{i}"]
        c = cslice[f"pos{i}"]
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if mixer == "attn":
            y, kv = attention_block(
                p["mixer"],
                h,
                cfg,
                positions=positions,
                kv_cache=(c["k"], c["v"]),
                cache_pos=cache_pos,
                kv_len=kv_len,
            )
            new_caches[f"pos{i}"] = {"k": kv[0], "v": kv[1]}
        else:
            y, carry = ssm_block_decode(p["mixer"], h, cfg, (c["state"], c["conv"]))
            new_caches[f"pos{i}"] = {"state": carry[0], "conv": carry[1]}
        x = x + y
        if mlp != "none":
            x = _channel_mix(p, x, cfg, mlp, ctx)
    return x, new_caches


# ---------------------------------------------------------------------------
# Full forward passes
# ---------------------------------------------------------------------------
def forward(
    params: Dict,
    cfg: ArchConfig,
    batch: Dict,
    ctx: Optional[MeshContext] = None,
    *,
    remat: bool = True,
    collect_cache: bool = False,
    act_spec=None,
    remat_policy: Optional[str] = "minimal",
):
    """Embeddings -> scanned stack -> final norm.

    Returns (hidden (B,S,d), caches) — caches stacked over periods when
    ``collect_cache`` (prefill), else None.

    ``act_spec`` (a PartitionSpec for (B, S, d)) pins the activation
    sharding at every period boundary — without it GSPMD is free to
    replicate the scan carry across the batch axes, which multiplies
    activation memory by the data-parallel degree.

    ``remat_policy``: "minimal" saves only the period carries (full
    recompute in backward — the memory floor); "dots" additionally saves
    projection outputs (checkpoint_policies.dots_with_no_batch_dims);
    "sublayer" nests a checkpoint around every sublayer so backward peaks
    at ONE sublayer's gathered weights/intermediates (long-period MoE
    hybrids).
    """
    x = embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)

    def constrain(t):
        if act_spec is not None:
            return jax.lax.with_sharding_constraint(t, act_spec)
        return t

    x = constrain(x)
    inner = remat and remat_policy == "sublayer"

    def body(carry, pslice):
        y, caches = _period_forward(
            pslice, carry, cfg, ctx, positions, collect_cache, inner_remat=inner
        )
        return constrain(y), (caches if collect_cache else None)

    if remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if remat_policy == "dots"
            else None
        )
        body = jax.checkpoint(body, policy=policy)
    x, caches = jax.lax.scan(body, x, params["stack"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, (caches if collect_cache else None)


def lm_head(params: Dict, cfg: ArchConfig, hidden: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", hidden, w, preferred_element_type=jnp.float32)


def chunked_ce_loss(
    params: Dict,
    cfg: ArchConfig,
    hidden: jax.Array,
    labels: jax.Array,
    *,
    chunk: int = 512,
) -> jax.Array:
    """Cross entropy, scanned over sequence chunks so the (B, S, V) logits
    tensor never exists for more than ``chunk`` positions at a time.  With
    the head sharded over ``model`` on V, the logsumexp / one-hot reductions
    lower to partial reductions + a small all-reduce — no vocab gather.

    labels < 0 are masked out (padding / modality-frontend positions).
    """
    B, S, d = hidden.shape
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    chunk = min(chunk, S)
    n = S // chunk
    hs = hidden[:, : n * chunk].reshape(B, n, chunk, d)
    ls = labels[:, : n * chunk].reshape(B, n, chunk)

    def body(carry, xs):
        tot, cnt = carry
        h_c, l_c = xs  # (B, c, d), (B, c)
        logits = jnp.einsum(
            "bcd,dv->bcv", h_c, w, preferred_element_type=jnp.float32
        )  # f32 (B, c, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)  # (B, c)
        onehot = jax.nn.one_hot(jnp.maximum(l_c, 0), cfg.vocab, dtype=logits.dtype)
        gold = (logits * onehot).sum(-1)
        mask = (l_c >= 0).astype(jnp.float32)
        tot = tot + ((lse - gold) * mask).sum()
        cnt = cnt + mask.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body),  # recompute chunk logits in bwd: peak = ONE chunk
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ls, 1, 0)),
    )
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(
    params: Dict,
    cfg: ArchConfig,
    batch: Dict,
    ctx: Optional[MeshContext] = None,
    act_spec=None,
    remat_policy: Optional[str] = "minimal",
) -> jax.Array:
    hidden, _ = forward(
        params, cfg, batch, ctx, remat=True, act_spec=act_spec, remat_policy=remat_policy
    )
    return chunked_ce_loss(params, cfg, hidden, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: prefill + one-token decode
# ---------------------------------------------------------------------------
def prefill(
    params: Dict,
    cfg: ArchConfig,
    batch: Dict,
    ctx: Optional[MeshContext] = None,
    act_spec=None,
):
    """Process the prompt; returns (last-position logits f32 (B, V), state).

    state = (caches stacked over periods, kv_len (B,) int32).
    """
    hidden, caches = forward(
        params, cfg, batch, ctx, remat=False, collect_cache=True, act_spec=act_spec
    )
    logits = lm_head(params, cfg, hidden[:, -1:])[:, 0]
    B, S = hidden.shape[0], hidden.shape[1]
    kv_len = jnp.full((B,), S, jnp.int32)
    return logits, (caches, kv_len)


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int) -> Tuple[Dict, jax.Array]:
    """Empty decode state sized for a ``max_len`` context (cells: decode_32k,
    long_500k build this with max_len = seq_len)."""
    caches = {}
    for i, mixer in enumerate(cfg.period):
        if mixer == "attn":
            kv = jnp.zeros((cfg.n_periods, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.jnp_dtype)
            caches[f"pos{i}"] = {"k": kv, "v": kv}
        else:
            st, conv = ssm_empty_carry(cfg, batch)
            caches[f"pos{i}"] = {
                "state": jnp.zeros((cfg.n_periods, *st.shape), st.dtype),
                "conv": jnp.zeros((cfg.n_periods, *conv.shape), conv.dtype),
            }
    kv_len = jnp.zeros((batch,), jnp.int32)
    return caches, kv_len


def decode_step(
    params: Dict,
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, 1) int32
    state: Tuple[Dict, jax.Array],
    cache_pos: jax.Array,  # scalar int32: slot the new token occupies
    ctx: Optional[MeshContext] = None,
    act_spec=None,
):
    """One serving step: consume one token, emit next-token logits.

    Returns (logits f32 (B, V), new_state).
    """
    caches, kv_len = state
    x = params["embed"][tokens]  # (B, 1, d)
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
    new_kv_len = jnp.maximum(kv_len, cache_pos + 1)

    def body(carry, xs):
        pslice, cslice = xs
        y, new_c = _period_decode(pslice, cslice, carry, cfg, ctx, cache_pos, new_kv_len)
        return y, new_c

    x, new_caches = jax.lax.scan(body, x, (params["stack"], caches))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params, cfg, x)[:, 0]
    return logits, (new_caches, new_kv_len)
