"""Mamba-2 (SSD — state-space duality) token mixer.

Three equivalent computations of the same selective-SSM recurrence

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t x_t^T          (state: H, P, N)
    y_t = C_t . S_t + D_h * x_t

are provided:

  * ``ssd_reference``  — O(S^2) sequential scan oracle (tests only);
  * ``ssd_chunked``    — the paper's chunked algorithm: quadratic *within*
    length-Q chunks (MXU-friendly matmuls) + a linear inter-chunk state
    recurrence via ``lax.scan``.  This is the training/prefill path and the
    shape the Pallas kernel (kernels/ssd.py) tiles;
  * ``ssd_decode_step``— O(1)/token recurrent update used by the serving
    engine (this is what makes long_500k decode runnable for SSM/hybrid).

Shapes follow the Mamba-2 paper: x (B,S,H,P), dt (B,S,H), A (H,) scalar
per head, B/C (B,S,G,N) with heads grouped G | H.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import rms_norm


def ssd_reference(x, dt, A, Bm, Cm, D) -> jax.Array:
    """Sequential scan over time — the oracle. All args f32.

    x: (B,S,H,P) dt: (B,S,H) A: (H,) Bm/Cm: (B,S,G,N) D: (H,)
    """
    Bb, S, H, Pd = x.shape
    G = Bm.shape[2]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dt_t * A)  # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt_t, B_t, x_t
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, C_t)
        return state, y

    s0 = jnp.zeros((Bb, H, Pd, Bm.shape[-1]), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    _, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    return y + x * D[None, None, :, None]


def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32).

    Within each chunk the computation is a masked 'attention' matmul
    (C_i . B_j) * exp(a_i - a_j) * dt_j — pure MXU work; across chunks a
    (H,P,N) state is carried by a scan of length S/Q.

    The intra-chunk quadratic work happens INSIDE the scan body (checkpointed)
    so peak live memory is O(B·Q·Q·H) for ONE chunk — materializing all
    chunks at once costs B·S·Q·H·f32 per temporary, which blows past HBM for
    the train_4k cells.  This is also the structure the Pallas kernel tiles
    (grid over chunks, state carried in VMEM).
    """
    Bb, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xc = x.reshape(Bb, nc, chunk, H, Pd)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = Bm.reshape(Bb, nc, chunk, G, N)
    Cc = Cm.reshape(Bb, nc, chunk, G, N)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, :, :, None]  # (1,Qi,Qj,1)

    def body(S_prev, inp):
        x_j, dt_j, B_j, C_j = inp  # (B,Q,H,P),(B,Q,H),(B,Q,G,N),(B,Q,G,N)
        a = dt_j * A[None, None, :]  # (B,Q,H)
        a_cum = jnp.cumsum(a, axis=1)
        a_total = a_cum[:, -1, :]  # (B,H)
        # intra-chunk: L[i,j] = exp(a_i - a_j) (i>=j); scores (C_i . B_j)
        seg = a_cum[:, :, None, :] - a_cum[:, None, :, :]  # (B,Qi,Qj,H)
        L = jnp.where(causal, jnp.exp(seg), 0.0)
        cb = jnp.einsum(
            "bign,bjgn->bijg", C_j, B_j, preferred_element_type=jnp.float32
        )  # (B,Qi,Qj,G) — inputs may be bf16; accumulate f32
        cb = jnp.repeat(cb, rep, axis=-1)
        M = cb * L * dt_j[:, None, :, :].astype(jnp.float32)
        y = jnp.einsum("bijh,bjhp->bihp", M, x_j, preferred_element_type=jnp.float32)
        # inter-chunk: y_i += exp(a_cum[i]) C_i . S_entering
        Ch = jnp.repeat(C_j, rep, axis=2)  # (B,Q,H,N)
        y = y + jnp.einsum(
            "bqhn,bhpn->bqhp", Ch, S_prev, preferred_element_type=jnp.float32
        ) * jnp.exp(a_cum)[..., None]
        # state update: S_new = exp(a_total) S_prev + sum_j exp(a_total-a_j) dt_j B_j x_j
        w = jnp.exp(a_total[:, None, :] - a_cum) * dt_j.astype(jnp.float32)  # (B,Q,H)
        Bh = jnp.repeat(B_j, rep, axis=2)  # (B,Q,H,N)
        cs = jnp.einsum(
            "bqh,bqhn,bqhp->bhpn", w, Bh, x_j, preferred_element_type=jnp.float32
        )
        S_new = S_prev * jnp.exp(a_total)[..., None, None] + cs
        return S_new, y.astype(x_j.dtype)  # stream y in the model dtype

    s0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)
    final, ys = jax.lax.scan(
        jax.checkpoint(body),
        s0,
        (
            jnp.moveaxis(xc, 1, 0),
            jnp.moveaxis(dtc, 1, 0),
            jnp.moveaxis(Bc, 1, 0),
            jnp.moveaxis(Cc, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, Pd)
    return y + x * D[None, None, :, None], final


def ssd_decode_step(state, x, dt, A, Bm, Cm, D):
    """One-token recurrence. state (B,H,P,N); x (B,H,P); dt (B,H);
    Bm/Cm (B,G,N). Returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    decay = jnp.exp(dt * A)  # (B,H)
    state = state * decay[..., None, None] + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, x)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + x * D[None, :, None]
    return y, state


# ---------------------------------------------------------------------------
# The full Mamba-2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------
def ssm_param_shapes(cfg: ArchConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * G * N
    return {
        "in_proj": (d, 2 * di + 2 * G * N + H),
        "conv_w": (cfg.ssm_conv, conv_dim),
        "conv_b": (conv_dim,),
        "A_log": (H,),
        "D": (H,),
        "dt_bias": (H,),
        "gate_norm": (di,),
        "out_proj": (di, d),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    return jnp.split(zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xBC (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(out + b)


def ssm_block(
    params: dict, x: jax.Array, cfg: ArchConfig, *, state=None
) -> Tuple[jax.Array, object]:
    """Mamba-2 mixer over a full sequence (train/prefill).

    Returns (y (B,S,d), carry) where carry = (ssd_state, conv_tail) for
    handing off to incremental decode.
    """
    Bb, S, d = x.shape
    dt0 = x.dtype
    di, G, N, H, Pd = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum(
        "bsd,de->bse", x, params["in_proj"], preferred_element_type=jnp.float32
    )
    z, xr, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    # Activation streams (z, x, B, C) live in the model dtype; only the dt
    # path, the decay chain and the SSD state stay f32.
    z = z.astype(dt0)
    xBC = jnp.concatenate([xr, Bm, Cm], axis=-1).astype(dt0)
    xBC = _causal_conv(xBC, params["conv_w"].astype(jnp.float32), params["conv_b"].astype(jnp.float32))
    xBC = xBC.astype(dt0)
    xr, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    dtv = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xr.reshape(Bb, S, H, Pd)
    Bg = Bm.reshape(Bb, S, G, N)
    Cg = Cm.reshape(Bb, S, G, N)
    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:  # pad to a chunk multiple (prefill of odd lengths)
        padn = chunk - S % chunk
        xh = jnp.pad(xh, ((0, 0), (0, padn), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, padn), (0, 0)))
        Bg = jnp.pad(Bg, ((0, 0), (0, padn), (0, 0), (0, 0)))
        Cg = jnp.pad(Cg, ((0, 0), (0, padn), (0, 0), (0, 0)))
    # Stream x/B/C through the SSD in the model dtype (the decay chain a_cum
    # and the carried state stay f32 inside the scan body) — halves the
    # dominant HBM stream of the SSM cells and matches what the Pallas
    # kernel consumes on TPU.
    xh, Bg, Cg = xh.astype(dt0), Bg.astype(dt0), Cg.astype(dt0)
    if cfg.use_pallas:
        from repro.kernels.ops import ssd_scan  # lazy: no cycle

        y, ssd_state = ssd_scan(
            xh, dtv, A, Bg, Cg, params["D"].astype(jnp.float32), chunk=chunk
        )
        y = y.astype(jnp.float32)
    else:
        y, ssd_state = ssd_chunked(
            xh, dtv, A, Bg, Cg, params["D"].astype(jnp.float32), chunk=chunk
        )
    y = y[:, :S].reshape(Bb, S, di).astype(dt0)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum(
        "bse,ed->bsd", y, params["out_proj"], preferred_element_type=jnp.float32
    ).astype(dt0)
    # conv tail: last (K-1) *pre-conv* channel values, for incremental decode
    K = cfg.ssm_conv
    zxbcdt_tail = zxbcdt[:, -(K - 1) :, :]
    _, xr_t, Bm_t, Cm_t, _ = _split_proj(cfg, zxbcdt_tail)
    conv_tail = jnp.concatenate([xr_t, Bm_t, Cm_t], axis=-1)  # (B,K-1,conv_dim)
    return out, (ssd_state, conv_tail)


def ssm_block_decode(
    params: dict, x: jax.Array, cfg: ArchConfig, carry
) -> Tuple[jax.Array, object]:
    """One-token Mamba-2 step. x (B,1,d); carry (ssd_state, conv_tail)."""
    Bb, S, d = x.shape
    assert S == 1
    dt0 = x.dtype
    di, G, N, H, Pd = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    ssd_state, conv_tail = carry
    zxbcdt = jnp.einsum(
        "bsd,de->bse", x, params["in_proj"], preferred_element_type=jnp.float32
    )
    z, xr, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    xBC_new = jnp.concatenate([xr, Bm, Cm], axis=-1)  # (B,1,conv_dim)
    window = jnp.concatenate([conv_tail, xBC_new], axis=1)  # (B,K,conv_dim)
    w = params["conv_w"].astype(jnp.float32)
    out = (window * w[None, :, :]).sum(axis=1, keepdims=True)
    xBC = jax.nn.silu(out + params["conv_b"].astype(jnp.float32))
    xr2, Bm2, Cm2 = jnp.split(xBC[:, 0], [di, di + G * N], axis=-1)
    dtv = jax.nn.softplus(dt[:, 0] + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, ssd_state = ssd_decode_step(
        ssd_state,
        xr2.reshape(Bb, H, Pd),
        dtv,
        A,
        Bm2.reshape(Bb, G, N),
        Cm2.reshape(Bb, G, N),
        params["D"].astype(jnp.float32),
    )
    y = y.reshape(Bb, 1, di)
    y = rms_norm((y * jax.nn.silu(z)).astype(dt0), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum(
        "bse,ed->bsd", y, params["out_proj"], preferred_element_type=jnp.float32
    ).astype(dt0)
    new_tail = window[:, 1:, :]
    return out, (ssd_state, new_tail)


def ssm_empty_carry(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, G, N, H, Pd = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * G * N
    return (
        jnp.zeros((batch, H, Pd, N), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.float32),
    )
