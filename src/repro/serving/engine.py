"""Batched serving engine: padded-prompt batched prefill + one-token decode
steps against the model zoo's KV/SSM cache, with per-sequence lengths.

This is the engine the decode_32k / long_500k dry-run cells lower a single
step of; here it runs end-to-end on CPU for the reduced configs (examples +
integration tests).  Weights can also be streamed from a DELI pipeline
(cloud-bucket-resident checkpoints — the serverless scenario of paper §I).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ArchConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]  # generated ids per sequence
    prefill_s: float
    decode_s: float

    @property
    def total_new_tokens(self) -> int:
        return sum(len(t) for t in self.tokens)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Dict, max_len: int = 512):
        if cfg.is_encoder:
            raise ValueError("encoder-only models have no decode step")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, t, st, pos: M.decode_step(p, cfg, t, st, pos)
        )

    def generate(
        self,
        prompts: List[List[int]],
        max_new_tokens: int = 16,
        greedy: bool = True,
        seed: int = 0,
    ) -> GenerationResult:
        """Batched greedy/sampled generation (uniform prompt lengths — the
        continuous-batching scheduler that relaxes this is out of scope; the
        dry-run decode cells are uniform by construction)."""
        import time

        lens = {len(p) for p in prompts}
        if len(lens) != 1:
            raise ValueError("ServeEngine.generate requires uniform prompt lengths")
        toks = jnp.asarray(np.asarray(prompts, np.int32))
        B, L = toks.shape
        t0 = time.monotonic()
        logits, (caches, kv_len) = self._prefill(self.params, {"tokens": toks})
        # grow the KV caches so decode steps have slots to write into
        grow = max_new_tokens

        def pad_kv(sub):
            return {
                k: (
                    jnp.pad(v, ((0, 0), (0, 0), (0, grow), (0, 0), (0, 0)))
                    if k in ("k", "v")
                    else v
                )
                for k, v in sub.items()
            }

        caches = {pos: pad_kv(sub) for pos, sub in caches.items()}
        state = (caches, kv_len)
        prefill_s = time.monotonic() - t0

        key = jax.random.PRNGKey(seed)
        out: List[List[int]] = [[] for _ in range(B)]
        t1 = time.monotonic()
        current = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for i in range(B):
            out[i].append(int(current[i, 0]))
        start = L
        n_remaining = max_new_tokens - 1
        for step in range(n_remaining):
            pos = jnp.int32(start + step)
            logits, state = self._decode(self.params, current, state, pos)
            if greedy:
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits).astype(jnp.int32)
            current = nxt[:, None]
            for i in range(B):
                out[i].append(int(nxt[i]))
        decode_s = time.monotonic() - t1
        return GenerationResult(out, prefill_s, decode_s)
