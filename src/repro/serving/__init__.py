from repro.serving.engine import GenerationResult, ServeEngine
