"""Trace parity: the exact-``==`` discipline, extended to event streams.

``pipeline.parity`` proves both projections of a ``DataPlaneSpec`` agree on
*aggregate* accounting (tier hits, Class A/B, per-node-epoch waits).  This
module proves the far stronger event-level property (ISSUE 10): run each
projection with its own fresh :class:`repro.obs.events.TraceRecorder` and
the two canonical event streams — every demand read, fetch round, probe,
cache insert/eviction, compute span, barrier park/release — are equal with
``==``, no tolerances, at identical virtual times with identical
attributes.  The comparison is on :func:`repro.obs.events.canonical_stream`
(the order-canonical multiset form), because *global* emission order is an
engine detail while the events themselves are not.

Import note: this module imports ``repro.pipeline.spec`` and therefore
must not be imported from ``repro.obs.__init__`` (which ``repro.core``
imports) — import it directly, as tests and the CLI do.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.obs.events import TraceRecorder, canonical_stream


@dataclasses.dataclass
class TraceParityReport:
    """Side-by-side canonical event streams of one spec's two projections.

    ``exact`` is the property; ``describe()`` renders the first divergence
    (and the one-sided remainders) for assertion messages.
    """

    spec_label: str
    epochs: int
    sim_stream: Tuple[tuple, ...]
    runtime_stream: Tuple[tuple, ...]

    @property
    def exact(self) -> bool:
        return self.sim_stream == self.runtime_stream

    def first_divergence(self) -> Optional[Tuple[Optional[tuple], Optional[tuple]]]:
        """The first canonical position where the streams differ (an event
        pair, with ``None`` standing in past the shorter stream's end)."""
        if self.exact:
            return None
        for a, b in zip(self.sim_stream, self.runtime_stream):
            if a != b:
                return (a, b)
        if len(self.sim_stream) > len(self.runtime_stream):
            return (self.sim_stream[len(self.runtime_stream)], None)
        return (None, self.runtime_stream[len(self.sim_stream)])

    def describe(self) -> str:
        status = "EXACT" if self.exact else "DIVERGED"
        lines = [
            f"trace-parity[{self.spec_label}, {self.epochs} epochs]: {status}",
            f"  events  sim={len(self.sim_stream)} runtime={len(self.runtime_stream)}",
        ]
        diff = self.first_divergence()
        if diff is not None:
            lines.append(f"  first divergence sim={diff[0]}")
            lines.append(f"                   run={diff[1]}")
        return "\n".join(lines)


def run_trace_parity(spec, epochs: int = 2) -> TraceParityReport:
    """Run both projections of ``spec`` under fresh recorders and compare.

    The spec's own ``trace`` field is ignored (each projection gets its own
    recorder via ``dataclasses.replace``), so a caller can hand in any
    spec — traced or not — without aliasing one recorder across runs.
    """
    sim_rec, run_rec = TraceRecorder(), TraceRecorder()
    dataclasses.replace(spec, trace=sim_rec).build_sim().run(epochs=epochs)
    with dataclasses.replace(spec, trace=run_rec).build_runtime() as cluster:
        cluster.run(epochs=epochs)
    return TraceParityReport(
        spec_label=spec.label(),
        epochs=epochs,
        sim_stream=canonical_stream(sim_rec.events),
        runtime_stream=canonical_stream(run_rec.events),
    )


def assert_trace_parity(spec, epochs: int = 2) -> TraceParityReport:
    """Assert event-level ``==`` across the two projections; returns the
    report (whose streams callers can feed to the ledger or exporters)."""
    report = run_trace_parity(spec, epochs=epochs)
    assert report.exact, report.describe()
    return report
