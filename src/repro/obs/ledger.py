"""Per-request cost ledger: every Class A/B charge has an emitting event.

The store-level counters (``StoreStats.class_a_requests`` /
``class_b_requests``) are the repo's headline cost metric, but they are
aggregates — a total with no audit trail.  The flight recorder gives every
charge a witness:

* each ``issue`` event carries ``class_a`` (LIST-class round issue) and
  ``class_b`` (GET-class billed fetches in that round, retries included);
* each ``demand`` event carries ``class_b`` (1 iff the read went to the
  bucket tier and was billed as a demand GET).

:func:`build_ledger` rolls a trace into per-node ledger lines, and
:func:`reconcile` asserts the sum-of-ledger equals the counters **exactly**
(integer ``==``) — the ISSUE 10 invariant that no cost is ever charged
without an event and no event ever claims a cost that was not charged.

Stdlib-only; operates on any iterable of :class:`TraceEvent`.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Iterable, List, Tuple

from repro.obs.events import TraceEvent


@dataclasses.dataclass(frozen=True)
class LedgerLine:
    """One charge, attributed to the event that caused it."""

    node: int
    t: float
    kind: str  # "issue" or "demand"
    class_a: int
    class_b: int


def build_ledger(events: Iterable[TraceEvent]) -> List[LedgerLine]:
    """Extract every cost-bearing event as a ledger line, in (node, t) order."""
    lines: List[LedgerLine] = []
    for ev in events:
        if ev.kind not in ("issue", "demand"):
            continue
        attrs = dict(ev.attrs)
        a = int(attrs.get("class_a", 0))
        b = int(attrs.get("class_b", 0))
        if a or b:
            lines.append(LedgerLine(node=ev.node, t=ev.t, kind=ev.kind, class_a=a, class_b=b))
    lines.sort(key=lambda ln: (ln.node, ln.t, ln.kind))
    return lines


def ledger_totals(events: Iterable[TraceEvent]) -> Tuple[int, int]:
    """(class_a, class_b) summed over the whole trace."""
    a = b = 0
    for ln in build_ledger(events):
        a += ln.class_a
        b += ln.class_b
    return a, b


def per_node_totals(events: Iterable[TraceEvent]) -> Dict[int, Tuple[int, int]]:
    """(class_a, class_b) per emitting node (cluster planner = node -1)."""
    acc: Dict[int, List[int]] = defaultdict(lambda: [0, 0])
    for ln in build_ledger(events):
        acc[ln.node][0] += ln.class_a
        acc[ln.node][1] += ln.class_b
    return {node: (a, b) for node, (a, b) in sorted(acc.items())}


@dataclasses.dataclass
class LedgerReport:
    """Ledger sums next to the store counters they must reproduce."""

    ledger_class_a: int
    ledger_class_b: int
    store_class_a: int
    store_class_b: int
    n_lines: int

    @property
    def exact(self) -> bool:
        return (
            self.ledger_class_a == self.store_class_a
            and self.ledger_class_b == self.store_class_b
        )

    def describe(self) -> str:
        status = "RECONCILED" if self.exact else "MISMATCH"
        return (
            f"ledger[{self.n_lines} lines]: {status}\n"
            f"  class_a ledger={self.ledger_class_a} store={self.store_class_a}\n"
            f"  class_b ledger={self.ledger_class_b} store={self.store_class_b}"
        )


def reconcile(events: Iterable[TraceEvent], store_stats) -> LedgerReport:
    """Compare the trace's summed charges with a run's ``StoreStats``."""
    lines = build_ledger(events)
    return LedgerReport(
        ledger_class_a=sum(ln.class_a for ln in lines),
        ledger_class_b=sum(ln.class_b for ln in lines),
        store_class_a=int(store_stats.class_a_requests),
        store_class_b=int(store_stats.class_b_requests),
        n_lines=len(lines),
    )


def assert_reconciles(events: Iterable[TraceEvent], store_stats) -> LedgerReport:
    """Assert sum-of-ledger == counters (exact integers); returns the report."""
    report = reconcile(events, store_stats)
    assert report.exact, report.describe()
    return report
