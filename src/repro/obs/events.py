"""Virtual-time flight recorder: the event model and shared emit helpers.

The recorder is an *observer* of the lock-step schedule (ISSUE 10): both
projections — the discrete-event simulator and the lock-step runtime —
emit structured :class:`TraceEvent` rows at the same virtual times with
the same attributes, so the parity discipline extends from aggregate
counters to **event-level ``==``** (``repro.obs.parity``).

Observer purity (rule PL006, ``repro.analysis``): nothing in this package
may mutate scheduler, cache, or stats state.  Host code calls *into* the
recorder (``trace_emit`` / ``trace_sync`` / the ``CacheTracer`` callbacks);
the recorder never calls back into the data plane.  With ``trace=None``
every guarded emit helper is a no-op and the schedule is byte-identical to
an untraced run.

This module is deliberately stdlib-only and imports nothing from
``repro`` — ``repro.core.lockstep`` (the dependency root of the data
plane) imports it without cycles.

Event vocabulary (see docs/OBSERVABILITY.md for the full schema):

==================  ========================================================
kind                meaning
==================  ========================================================
``demand``          one training-loop sample read (tier-attributed span)
``issue``           one pre-fetch round issued (provenance + key partition)
``advance``         one pre-fetch round folded into the cache at its
                    completion time
``probe``           one service-side peer probe with its arrival-time
                    outcome
``insert``          a cache insert (demand fill or pre-fetch fold)
``evict``           a cache eviction (victim + policy)
``compute``         a training compute span (per batch, or per gradient
                    bucket under ``overlap="buckets"``)
``allreduce-wait``  time blocked at a gradient-sync barrier (skew)
``allreduce-comm``  time transferring gradient bytes (exposed comm)
``overlap-bucket``  one gradient bucket's allreduce transfer (hidden or not)
``park``            a rank parked at the batch barrier (driver event)
``release``         a barrier release (driver event, node ``-1``)
``epoch-barrier``   the end-of-epoch BSP barrier (driver event, node ``-1``)
==================  ========================================================
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, List, Optional, Tuple

#: Driver-level events (barrier machinery) are recorded on this pseudo-node.
CLUSTER_NODE = -1


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One structured event at a virtual time.

    ``attrs`` is a key-sorted tuple of ``(name, value)`` pairs; values are
    restricted by convention to ints, floats, strings and flat tuples so
    events stay hashable, comparable and JSON-renderable.  Payload bytes
    never enter an event: the runtime carries real sample bytes and the
    simulator carries sentinels, so payloads are exactly the thing trace
    parity must not see.
    """

    kind: str
    node: int
    t: float
    dur: float = 0.0
    attrs: Tuple[Tuple[str, Any], ...] = ()

    def attr(self, key: str, default: Any = None) -> Any:
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    def canon(self) -> tuple:
        """The canonical comparison tuple (see :func:`canonical_stream`)."""
        return (self.node, self.t, self.kind, self.dur, self.attrs)


class TraceRecorder:
    """Append-only event sink shared by every instrumented component.

    One recorder observes one projection of one run (all nodes).  The
    *pin* is the round-completion idiom: ``LockstepPrefetchService
    .advance_to`` folds finished rounds into caches while some *other*
    node's clock drives the fold, so cache-insert timestamps pin to the
    round's completion time instead of the caller's clock.
    """

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []
        self._pin: Optional[float] = None

    def emit(
        self, kind: str, node: int, t: float, dur: float = 0.0, **attrs: Any
    ) -> None:
        self.events.append(
            TraceEvent(kind, int(node), float(t), float(dur), tuple(sorted(attrs.items())))
        )

    # -- pinned time --------------------------------------------------------
    def pin(self, t: float) -> None:
        self._pin = float(t)

    def unpin(self) -> None:
        self._pin = None

    @property
    def pinned(self) -> Optional[float]:
        return self._pin

    def __len__(self) -> int:
        return len(self.events)


def canonical_stream(events: Iterable[TraceEvent]) -> Tuple[tuple, ...]:
    """The order-canonical form two streams are compared ``==`` on.

    Events are keyed ``(node, t, kind, dur, attrs)`` and sorted: within one
    node the virtual-time order is total, but *global* emission order is an
    engine detail (the vector engine commits whole segments at once), so
    the canonical form is a function of the event multiset only.  Ties
    break on the remaining tuple fields, which is deterministic because
    equal ``(node, t, kind)`` implies the same attribute keys.
    """
    return tuple(sorted(e.canon() for e in events))


class CacheTracer:
    """Observe one node's ``CappedCache`` through the dedicated trace
    listener slot (``CappedCache.set_trace_listener``).

    Timestamps come from the node's clock callable unless the recorder has
    a pinned time (pre-fetch folds).  The vector engine runs its cache
    walk *before* committing the time chain, so it switches the tracer to
    capture mode and flushes ``(op, index)`` rows with chain-derived
    timestamps at segment commit.
    """

    def __init__(
        self,
        trace: TraceRecorder,
        node: int,
        now: Callable[[], float],
        policy: str = "",
    ) -> None:
        self.trace = trace
        self.node = int(node)
        self.now = now
        self.policy = policy
        self._capture: Optional[List[Tuple[str, int]]] = None

    def _t(self) -> float:
        pin = self.trace.pinned
        return pin if pin is not None else self.now()

    # -- CappedCache trace-listener callbacks -------------------------------
    def on_insert(self, index: int) -> None:
        if self._capture is not None:
            self._capture.append(("insert", index))
            return
        self.trace.emit("insert", self.node, self._t(), idx=index)

    def on_evict(self, index: int) -> None:
        if self._capture is not None:
            self._capture.append(("evict", index))
            return
        self.trace.emit("evict", self.node, self._t(), victim=index, policy=self.policy)

    # -- vector-engine capture mode -----------------------------------------
    def begin_capture(self) -> List[Tuple[str, int]]:
        self._capture = []
        return self._capture

    def end_capture(self) -> List[Tuple[str, int]]:
        buf = self._capture if self._capture is not None else []
        self._capture = None
        return buf

    def flush(self, ops: Iterable[Tuple[str, int]], t: float) -> None:
        """Emit captured rows at the chain-derived time ``t``."""
        for op, index in ops:
            if op == "insert":
                self.trace.emit("insert", self.node, t, idx=index)
            else:
                self.trace.emit("evict", self.node, t, victim=index, policy=self.policy)


# -- guarded emit helpers (host-side; every call site is a no-op untraced) --
def trace_emit(
    trace: Optional[TraceRecorder],
    kind: str,
    node: int,
    t: float,
    dur: float = 0.0,
    **attrs: Any,
) -> None:
    """The generic guarded emit — one branch, zero cost when untraced."""
    if trace is not None:
        trace.emit(kind, node, t, dur, **attrs)


def trace_demand(
    trace: Optional[TraceRecorder],
    node: int,
    t0: float,
    dur: float,
    idx: int,
    tier: str,
    class_b: int = 0,
    components: Tuple[Tuple[str, float], ...] = (),
) -> None:
    """One tier-attributed demand read.

    ``dur`` is the exact float both projections add to
    ``EpochStats.data_wait_seconds`` for this sample; ``class_b`` is the
    number of Class B GETs the read billed (the ledger reconciles these
    against ``StoreStats``, docs/OBSERVABILITY.md).  ``components`` carries
    per-component substep timing when ``granularity="substep"``.
    """
    if trace is None:
        return
    if components:
        trace.emit(
            "demand", node, t0, dur,
            idx=idx, tier=tier, class_b=class_b, components=tuple(components),
        )
    else:
        trace.emit("demand", node, t0, dur, idx=idx, tier=tier, class_b=class_b)


def trace_sync(
    trace: Optional[TraceRecorder],
    node: int,
    end: float,
    wait: float,
    comm: float,
) -> None:
    """THE shared emit helper for the mirrored ``sync_to`` halves.

    Rule PL006 forbids raw recorder calls inside ``# parity-mirror``
    regions; the mirrored allreduce accounting instead makes this one
    call with the post-sync clock value (``end``), the barrier skew
    (``wait``) and the collective duration (``comm``) — all floats both
    halves already computed identically — and the spans are reconstructed
    here, outside the mirror, once.
    """
    if trace is None:
        return
    mark = end - comm if comm > 0 else end
    if wait > 0:
        trace.emit("allreduce-wait", node, mark - wait, wait)
    if comm > 0:
        trace.emit("allreduce-comm", node, mark, comm)
