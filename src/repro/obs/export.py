"""Exporters: Chrome trace-event JSON (Perfetto-loadable) and text views.

``chrome_trace`` maps a recorded stream to the Chrome trace-event format
(the ``{"traceEvents": [...]}`` object form): one *process* per rank
(``pid = node + 1``; the cluster pseudo-node ``-1`` becomes pid 0) with
fixed *thread* lanes per rank — data-wait, compute, allreduce, events —
so a trace opens in Perfetto / ``chrome://tracing`` with wait vs comm vs
compute visually separated per rank.  Virtual seconds map to microsecond
``ts``/``dur``; the exact float seconds also ride along in ``args`` so
:func:`events_from_chrome` can round-trip a file losslessly for the CLI.

``validate_chrome_trace`` is the schema check CI runs on a generated
trace: required keys per event, ``X`` events carry ``dur``, and ``ts`` is
monotone non-decreasing within every ``(pid, tid)`` track.

Stdlib-only (``json``); imports nothing from ``repro`` outside ``obs``.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.obs.events import TraceEvent

#: Fixed per-rank lanes (Chrome ``tid``).  Order is display order.
LANES: Tuple[Tuple[int, str], ...] = (
    (1, "data-wait"),
    (2, "compute"),
    (3, "allreduce"),
    (4, "events"),
)

#: Kinds rendered as duration spans (Chrome ``ph: "X"``); everything else
#: is an instant (``ph: "i"``, thread scope).
SPAN_KINDS = frozenset(
    ("demand", "compute", "allreduce-wait", "allreduce-comm",
     "overlap-bucket", "overlap-exposed")
)

_US = 1e6  # virtual seconds -> trace-event microseconds


def lane_of(kind: str) -> int:
    if kind == "demand":
        return 1
    if kind == "compute":
        return 2
    if kind.startswith("allreduce") or kind.startswith("overlap"):
        return 3
    return 4


def _jsonable(value: Any) -> Any:
    if isinstance(value, tuple):
        return [_jsonable(v) for v in value]
    return value


def chrome_trace(events: Iterable[TraceEvent]) -> Dict[str, Any]:
    """Render a stream as a Chrome trace-event JSON object."""
    rows: List[Dict[str, Any]] = []
    pids = sorted({e.node + 1 for e in events})
    for pid in pids:
        name = "cluster" if pid == 0 else f"rank {pid - 1}"
        rows.append({"name": "process_name", "ph": "M", "ts": 0, "pid": pid,
                     "tid": 0, "args": {"name": name}})
        rows.append({"name": "process_sort_index", "ph": "M", "ts": 0, "pid": pid,
                     "tid": 0, "args": {"sort_index": pid}})
        for tid, lane in LANES:
            rows.append({"name": "thread_name", "ph": "M", "ts": 0, "pid": pid,
                         "tid": tid, "args": {"name": lane}})
            rows.append({"name": "thread_sort_index", "ph": "M", "ts": 0, "pid": pid,
                         "tid": tid, "args": {"sort_index": tid}})
    spans: List[Dict[str, Any]] = []
    for e in events:
        args: Dict[str, Any] = {k: _jsonable(v) for k, v in e.attrs}
        args["vt"] = e.t       # exact virtual seconds (lossless round-trip)
        args["vdur"] = e.dur
        row: Dict[str, Any] = {
            "name": e.kind,
            "cat": e.kind,
            "ts": e.t * _US,
            "pid": e.node + 1,
            "tid": lane_of(e.kind),
            "args": args,
        }
        if e.kind in SPAN_KINDS:
            row["ph"] = "X"
            row["dur"] = e.dur * _US
        else:
            row["ph"] = "i"
            row["s"] = "t"
        spans.append(row)
    spans.sort(key=lambda r: (r["pid"], r["tid"], r["ts"], r["name"]))
    return {"traceEvents": rows + spans, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events: Iterable[TraceEvent]) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(chrome_trace(events), fh, indent=None, separators=(",", ":"))
        fh.write("\n")


def load_chrome_trace(path: str) -> Dict[str, Any]:
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema problems in a Chrome trace-event document (empty == valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        return ["document is not an object with a traceEvents list"]
    last_ts: Dict[Tuple[int, int], float] = {}
    for i, row in enumerate(doc["traceEvents"]):
        if not isinstance(row, dict):
            problems.append(f"traceEvents[{i}]: not an object")
            continue
        missing = [k for k in ("name", "ph", "ts", "pid", "tid") if k not in row]
        if missing:
            problems.append(f"traceEvents[{i}]: missing {missing}")
            continue
        if row["ph"] == "M":
            continue
        if row["ph"] == "X" and "dur" not in row:
            problems.append(f"traceEvents[{i}]: X event without dur")
        track = (row["pid"], row["tid"])
        ts = float(row["ts"])
        if ts < last_ts.get(track, float("-inf")):
            problems.append(
                f"traceEvents[{i}]: ts {ts} not monotone on track {track}"
            )
        last_ts[track] = ts
    return problems


def _tupled(value: Any) -> Any:
    if isinstance(value, list):
        return tuple(_tupled(v) for v in value)
    return value


def events_from_chrome(doc: Dict[str, Any]) -> List[TraceEvent]:
    """Reconstruct the event stream from an exported document (the exact
    virtual times come from the ``vt``/``vdur`` args)."""
    out: List[TraceEvent] = []
    for row in doc.get("traceEvents", ()):
        if row.get("ph") == "M":
            continue
        args = dict(row.get("args", {}))
        t = float(args.pop("vt", row["ts"] / _US))
        dur = float(args.pop("vdur", row.get("dur", 0.0) / _US))
        attrs = tuple(sorted((k, _tupled(v)) for k, v in args.items()))
        out.append(TraceEvent(row["name"], int(row["pid"]) - 1, t, dur, attrs))
    return out


# -- text rendering ----------------------------------------------------------
def _fmt_node(node: int) -> str:
    return "cluster" if node < 0 else f"rank{node}"


def text_timeline(events: Iterable[TraceEvent], limit: Optional[int] = None) -> str:
    """A plain-text event log in virtual-time order."""
    ordered = sorted(events, key=lambda e: (e.t, e.node, e.kind, e.attrs))
    if limit is not None:
        ordered = ordered[:limit]
    lines = []
    for e in ordered:
        attrs = " ".join(f"{k}={v}" for k, v in e.attrs if k != "keys")
        dur = f" dur={e.dur:.6f}" if e.dur else ""
        lines.append(f"t={e.t:>12.6f}  {_fmt_node(e.node):>8}  {e.kind:<15}{dur}"
                     + (f"  {attrs}" if attrs else ""))
    return "\n".join(lines)


def decomposition(events: Iterable[TraceEvent]) -> Dict[int, Dict[str, float]]:
    """Per-rank wall-time decomposition summed straight off the spans.

    The four columns are exactly the four ``EpochStats`` time fields: each
    span's ``dur`` is the float the instrumented code added to the
    matching counter, so per rank ``data_wait + compute + allreduce_wait +
    allreduce_comm`` reproduces ``EpochStats.wall_seconds`` (tests assert
    this exactly).  Under ``overlap="buckets"`` the exposed comm tail is
    charged by ``overlap-exposed`` events (``overlap-bucket`` spans are
    the per-bucket transfers, hidden or not — informational), so those
    count toward the comm column alongside ``allreduce-comm``.
    """
    acc: Dict[int, Dict[str, float]] = defaultdict(
        lambda: {"data_wait": 0.0, "compute": 0.0,
                 "allreduce_wait": 0.0, "allreduce_comm": 0.0}
    )
    for e in events:
        if e.node < 0:
            continue
        if e.kind == "demand":
            acc[e.node]["data_wait"] += e.dur
        elif e.kind == "compute":
            acc[e.node]["compute"] += e.dur
        elif e.kind == "allreduce-wait":
            acc[e.node]["allreduce_wait"] += e.dur
        elif e.kind in ("allreduce-comm", "overlap-exposed"):
            acc[e.node]["allreduce_comm"] += e.dur
    return {node: dict(cols) for node, cols in sorted(acc.items())}


def decomposition_table(events: Iterable[TraceEvent]) -> str:
    """The CLI's wall-time decomposition table."""
    cols = ("data_wait", "compute", "allreduce_wait", "allreduce_comm")
    header = f"{'rank':>6} " + " ".join(f"{c:>15}" for c in cols) + f" {'wall':>15}"
    lines = [header, "-" * len(header)]
    for node, d in decomposition(events).items():
        wall = sum(d[c] for c in cols)
        lines.append(
            f"{node:>6} " + " ".join(f"{d[c]:>15.6f}" for c in cols) + f" {wall:>15.6f}"
        )
    return "\n".join(lines)
