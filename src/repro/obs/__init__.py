"""``repro.obs`` — the virtual-time flight recorder (ISSUE 10).

Event model and emit helpers live in :mod:`repro.obs.events`; the cost
ledger (:mod:`repro.obs.ledger`) and exporters (:mod:`repro.obs.export`)
are re-exported here too.  All of those are stdlib-only — this package
``__init__`` is imported by ``repro.core.lockstep``, so it must stay free
of any ``repro`` import outside ``obs`` to keep the dependency root
cycle-free.  The one exception imports its home directly:

* ``repro.obs.parity`` — ``assert_trace_parity`` / ``run_trace_parity``
  (exact ``==`` on canonical event streams across both projections);
  pulls in ``repro.pipeline``, so it is deliberately NOT re-exported.
"""
from repro.obs.events import (
    CLUSTER_NODE,
    CacheTracer,
    TraceEvent,
    TraceRecorder,
    canonical_stream,
    trace_demand,
    trace_emit,
    trace_sync,
)
from repro.obs.export import (
    chrome_trace,
    decomposition,
    decomposition_table,
    events_from_chrome,
    load_chrome_trace,
    text_timeline,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.obs.ledger import (
    LedgerLine,
    LedgerReport,
    assert_reconciles,
    build_ledger,
    reconcile,
)

__all__ = [
    "CLUSTER_NODE",
    "CacheTracer",
    "LedgerLine",
    "LedgerReport",
    "TraceEvent",
    "TraceRecorder",
    "assert_reconciles",
    "build_ledger",
    "canonical_stream",
    "chrome_trace",
    "decomposition",
    "decomposition_table",
    "events_from_chrome",
    "load_chrome_trace",
    "reconcile",
    "text_timeline",
    "trace_demand",
    "trace_emit",
    "trace_sync",
    "validate_chrome_trace",
    "write_chrome_trace",
]
