"""``python -m repro.obs`` — inspect an exported flight-recorder trace.

Reads a Chrome trace-event JSON file written by
``repro.obs.export.write_chrome_trace`` (e.g. via ``benchmarks/run.py
--trace-dir``) and renders a text timeline plus the per-rank wall-time
decomposition table; ``--validate`` runs the schema check instead and
exits non-zero on problems.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.export import (
    decomposition_table,
    events_from_chrome,
    load_chrome_trace,
    text_timeline,
    validate_chrome_trace,
)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Render or validate a flight-recorder Chrome trace.",
    )
    parser.add_argument("trace", help="path to a Chrome trace-event JSON file")
    parser.add_argument(
        "--limit", type=int, default=60, metavar="N",
        help="timeline rows to print (0 = all; default %(default)s)",
    )
    parser.add_argument(
        "--no-timeline", action="store_true",
        help="print only the wall-time decomposition table",
    )
    parser.add_argument(
        "--validate", action="store_true",
        help="validate against the Chrome trace-event schema and exit",
    )
    args = parser.parse_args(argv)

    doc = load_chrome_trace(args.trace)
    if args.validate:
        problems = validate_chrome_trace(doc)
        if problems:
            for p in problems:
                print(p, file=sys.stderr)
            return 1
        n = sum(1 for r in doc["traceEvents"] if r.get("ph") != "M")
        print(f"OK: {args.trace} valid Chrome trace ({n} events)")
        return 0

    events = events_from_chrome(doc)
    print(f"{args.trace}: {len(events)} events")
    print()
    print("wall-time decomposition (virtual seconds):")
    print(decomposition_table(events))
    if not args.no_timeline:
        limit = None if args.limit == 0 else args.limit
        print()
        shown = len(events) if limit is None else min(limit, len(events))
        print(f"timeline (first {shown} of {len(events)} events):")
        print(text_timeline(events, limit=limit))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
