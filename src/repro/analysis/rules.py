"""Rules PL002–PL005: clock discipline, float determinism, no-tolerance
tests, shared-state discipline.

Each rule is a function taking ``(tree, relpath, source)`` and returning
``Finding`` objects; ``run_rules_on_source`` dispatches by the file's
repo-relative path (sim-domain rules vs test rules).  The rules are
deliberately syntactic — they flag *idioms*, not proven bugs, and the
committed baseline is the pressure valve for the few accepted exceptions.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, List, Optional, Sequence

from repro.analysis.findings import Finding

#: Sim-domain package prefixes (repo-relative, under src/).
SIM_DOMAIN_PREFIXES = (
    "src/repro/core/",
    "src/repro/oracle/",
    "src/repro/engine/",
    "src/repro/pipeline/",
)

#: PL002 allowlist: the wall-clock abstraction itself, the threaded
#: free-running service (real sleeps by design), and the dry-run launcher.
CLOCK_ALLOWLIST = (
    "src/repro/core/clock.py",
    "src/repro/core/prefetcher.py",
    "src/repro/launch/dryrun.py",
)

#: time-module attributes that read or consume wall time.
_WALL_TIME_ATTRS = {
    "time",
    "time_ns",
    "monotonic",
    "monotonic_ns",
    "perf_counter",
    "perf_counter_ns",
    "process_time",
    "process_time_ns",
    "sleep",
}
_DATETIME_NOW_ATTRS = {"now", "utcnow", "today"}
#: module-level random functions are nondeterministic across runs; seeded
#: ``random.Random(seed)`` / ``SystemRandom`` construction stays legal.
_RANDOM_OK = {"Random", "SystemRandom"}

#: names that smell like a float time/stats chain (PL003).
FLOAT_PAT = re.compile(r"(seconds|wait|rate|duration|elapsed|_s\b|_t\b)", re.I)


class _SymbolStack(ast.NodeVisitor):
    """Visitor base that tracks the enclosing function/class name."""

    def __init__(self, relpath: str):
        self.relpath = relpath
        self.stack: List[str] = []
        self.findings: List[Finding] = []

    @property
    def symbol(self) -> str:
        return ".".join(self.stack)

    def _push_visit(self, node: ast.AST) -> None:
        self.stack.append(node.name)  # type: ignore[attr-defined]
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _push_visit
    visit_AsyncFunctionDef = _push_visit
    visit_ClassDef = _push_visit

    def emit(self, rule: str, node: ast.AST, key: str, message: str, hint: str) -> None:
        self.findings.append(
            Finding(
                rule=rule,
                path=self.relpath,
                line=getattr(node, "lineno", 0),
                symbol=self.symbol,
                key=key,
                message=message,
                hint=hint,
            )
        )


# -- PL002 clock-discipline --------------------------------------------------
class _ClockDiscipline(_SymbolStack):
    """Flag wall-clock reads and module-level ``random.*`` calls in
    sim-domain code.  Tracks both ``import time`` attribute access and
    ``from time import perf_counter`` style aliases."""

    def __init__(self, relpath: str):
        super().__init__(relpath)
        # local alias -> ("time"|"datetime"|"random", original attr name)
        self.from_aliases: dict = {}
        # local alias -> module ("time"/"datetime"/"random")
        self.module_aliases: dict = {}

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            root = alias.name.split(".")[0]
            if root in ("time", "datetime", "random"):
                self.module_aliases[alias.asname or root] = root
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = (node.module or "").split(".")[0]
        if mod in ("time", "datetime", "random"):
            for alias in node.names:
                self.from_aliases[alias.asname or alias.name] = (mod, alias.name)
        self.generic_visit(node)

    def _flag(self, node: ast.AST, mod: str, attr: str) -> None:
        if mod == "time" and attr in _WALL_TIME_ATTRS:
            self.emit(
                "clock-discipline",
                node,
                f"time.{attr}",
                f"wall-clock call time.{attr} in sim-domain module",
                "sim-domain code takes time from a Clock (core/clock.py): "
                "use clock.now()/clock.sleep() so both projections share "
                "one virtual timeline",
            )
        elif mod == "datetime" and attr in _DATETIME_NOW_ATTRS:
            self.emit(
                "clock-discipline",
                node,
                f"datetime.{attr}",
                f"wall-clock call datetime.{attr} in sim-domain module",
                "sim-domain code takes time from a Clock (core/clock.py), "
                "never the host calendar",
            )
        elif mod == "random" and attr not in _RANDOM_OK:
            self.emit(
                "clock-discipline",
                node,
                f"random.{attr}",
                f"module-level random.{attr} in sim-domain module "
                "(shared hidden RNG state)",
                "construct a seeded random.Random(seed) instance "
                "(see core/store.py) so replays are deterministic",
            )

    def visit_Attribute(self, node: ast.Attribute) -> None:
        value = node.value
        if isinstance(value, ast.Name) and value.id in self.module_aliases:
            self._flag(node, self.module_aliases[value.id], node.attr)
        elif (
            # datetime.datetime.now() — class attribute chain.
            isinstance(value, ast.Attribute)
            and isinstance(value.value, ast.Name)
            and self.module_aliases.get(value.value.id) == "datetime"
            and node.attr in _DATETIME_NOW_ATTRS
        ):
            self._flag(node, "datetime", node.attr)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load) and node.id in self.from_aliases:
            mod, attr = self.from_aliases[node.id]
            self._flag(node, mod, attr)


def check_clock_discipline(tree: ast.AST, relpath: str) -> List[Finding]:
    v = _ClockDiscipline(relpath)
    v.visit(tree)
    return v.findings


# -- PL003 float-determinism -------------------------------------------------
_NP_NAMES = {"np", "numpy"}

_SUM_HINT = (
    "order-sensitive float accumulation must be sequential left-to-right: "
    "use an explicit loop or np.cumsum(xs)[-1] (engine/vector.py's "
    "cumsum-not-pairwise rule) so simulator and runtime round identically"
)
_SET_HINT = (
    "iterating a set yields hash order, which is not stable across "
    "processes; iterate a sorted() or insertion-ordered sequence before "
    "accumulating floats or recording stats"
)


def _looks_floaty(text: str) -> bool:
    return bool(FLOAT_PAT.search(text))


class _FloatDeterminism(_SymbolStack):
    def visit_Assign(self, node: ast.Assign) -> None:
        self._check_sum_assign(node.targets, node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_sum_assign([node.target], node.value)
        self.generic_visit(node)

    def visit_Return(self, node: ast.Return) -> None:
        if node.value is not None:
            for call in self._sum_calls(node.value):
                self._flag_sum(call, force=self._floaty_call(call))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # np.sum is pairwise summation: always wrong in a sim-domain
        # float chain, flagged regardless of name heuristics.
        fn = node.func
        if (
            isinstance(fn, ast.Attribute)
            and fn.attr in ("sum", "nansum")
            and isinstance(fn.value, ast.Name)
            and fn.value.id in _NP_NAMES
        ):
            self.emit(
                "float-determinism",
                node,
                f"{fn.value.id}.{fn.attr}",
                f"{fn.value.id}.{fn.attr} uses pairwise summation — "
                "rounding depends on block size, not arrival order",
                _SUM_HINT,
            )
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self._is_set_expr(node.iter) and self._accumulates_floats(node.body):
            self.emit(
                "float-determinism",
                node,
                "set-iteration",
                "iteration over an unordered set feeds float/stats "
                "accumulation",
                _SET_HINT,
            )
        self.generic_visit(node)

    # helpers ---------------------------------------------------------------
    def _check_sum_assign(self, targets: Sequence[ast.expr], value: ast.expr) -> None:
        floaty_target = any(_looks_floaty(ast.unparse(t)) for t in targets)
        for call in self._sum_calls(value):
            self._flag_sum(call, force=floaty_target or self._floaty_call(call))

    @staticmethod
    def _sum_calls(expr: ast.expr) -> Iterator[ast.Call]:
        for node in ast.walk(expr):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "sum"
            ):
                yield node

    @staticmethod
    def _floaty_call(call: ast.Call) -> bool:
        return any(_looks_floaty(ast.unparse(a)) for a in call.args)

    def _flag_sum(self, call: ast.Call, force: bool) -> None:
        if not force:
            return
        self.emit(
            "float-determinism",
            call,
            "sum",
            "builtin sum() over a float time/stats chain — fold order is "
            "an implementation detail the parity contract cannot lean on",
            _SUM_HINT,
        )

    @staticmethod
    def _is_set_expr(expr: ast.expr) -> bool:
        if isinstance(expr, (ast.Set, ast.SetComp)):
            return True
        if (
            isinstance(expr, ast.Call)
            and isinstance(expr.func, ast.Name)
            and expr.func.id in ("set", "frozenset")
        ):
            return True
        return False

    @staticmethod
    def _accumulates_floats(body: Sequence[ast.stmt]) -> bool:
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.AugAssign) and _looks_floaty(
                    ast.unparse(node.target)
                ):
                    return True
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("record", "observe", "add_sample")
                ):
                    return True
        return False


def check_float_determinism(tree: ast.AST, relpath: str) -> List[Finding]:
    v = _FloatDeterminism(relpath)
    v.visit(tree)
    return v.findings


# -- PL004 no-tolerance ------------------------------------------------------
_TOLERANCE_HINT = (
    "parity comparisons are exact == by policy (docs/PARITY.md): compare "
    "with assert_parity / == and fix the float chain, never widen the "
    "assertion; if this is a closed-form cost-model pin, add a baselined "
    "exception with a reason instead"
)
_EPS_NAME = re.compile(r"(eps|tol)", re.I)


def is_parity_test_file(relpath: str, source: str) -> bool:
    """PL004 scope: tests that import assert_parity or carry parity naming."""
    name = relpath.rsplit("/", 1)[-1]
    if "parity" in name:
        return True
    return bool(re.search(r"\bassert_parity\b", source))


class _NoTolerance(_SymbolStack):
    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        label: Optional[str] = None
        if isinstance(fn, ast.Attribute):
            if fn.attr == "approx":
                label = "pytest.approx"
            elif fn.attr == "isclose":
                label = "math.isclose"
            elif fn.attr in ("allclose", "assert_allclose", "assert_almost_equal"):
                label = f"np.{fn.attr}"
        elif isinstance(fn, ast.Name):
            if fn.id == "approx":
                label = "pytest.approx"
            elif fn.id == "isclose":
                label = "math.isclose"
        if label is not None:
            self.emit(
                "no-tolerance",
                node,
                label,
                f"{label} in a parity test — tolerance comparisons are "
                "banned where the contract is exact ==",
                _TOLERANCE_HINT,
            )
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        # abs(a - b) < eps
        if (
            isinstance(node.left, ast.Call)
            and isinstance(node.left.func, ast.Name)
            and node.left.func.id == "abs"
            and len(node.ops) == 1
            and isinstance(node.ops[0], (ast.Lt, ast.LtE))
            and self._is_epsilon(node.comparators[0])
        ):
            self.emit(
                "no-tolerance",
                node,
                "abs<eps",
                "abs(...) < eps comparison in a parity test — this is a "
                "tolerance in disguise",
                _TOLERANCE_HINT,
            )
        self.generic_visit(node)

    @staticmethod
    def _is_epsilon(expr: ast.expr) -> bool:
        if isinstance(expr, ast.Constant) and isinstance(expr.value, float):
            return abs(expr.value) < 1e-2
        return bool(_EPS_NAME.search(ast.unparse(expr)))


def check_no_tolerance(tree: ast.AST, relpath: str) -> List[Finding]:
    v = _NoTolerance(relpath)
    v.visit(tree)
    return v.findings


# -- PL005 shared-state ------------------------------------------------------
#: the one module allowed to mutate cross-rank placement state.
SHARED_STATE_HOME = "src/repro/core/lockstep.py"
_MUTATORS = {"add", "discard", "update", "remove", "clear", "pop"}
_SHARED_PAT = re.compile(r"in_flight", re.I)
_SHARED_HINT = (
    "cross-rank mutable state is mutated only inside "
    "core/lockstep.py (LockstepPrefetchService) so both projections see "
    "mutations at bit-identical virtual times; route this through the "
    "shared service instead of touching the set directly"
)


def _names_shared_state(expr: ast.expr) -> bool:
    try:
        return bool(_SHARED_PAT.search(ast.unparse(expr)))
    except Exception:
        return False


class _SharedState(_SymbolStack):
    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if (
            isinstance(fn, ast.Attribute)
            and fn.attr in _MUTATORS
            and _names_shared_state(fn.value)
        ):
            self.emit(
                "shared-state",
                node,
                f".{fn.attr}",
                f"in-flight set mutated via .{fn.attr}() outside "
                "core/lockstep.py",
                _SHARED_HINT,
            )
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if _names_shared_state(node.target):
            self.emit(
                "shared-state",
                node,
                "augassign",
                "in-flight set mutated via augmented assignment outside "
                "core/lockstep.py",
                _SHARED_HINT,
            )
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        if any(_names_shared_state(t) for t in node.targets):
            self.emit(
                "shared-state",
                node,
                "delete",
                "in-flight state deleted outside core/lockstep.py",
                _SHARED_HINT,
            )
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # Plain rebinding (wiring) is allowed anywhere; subscript
        # assignment into the shared structure is a mutation.
        for t in node.targets:
            if isinstance(t, ast.Subscript) and _names_shared_state(t.value):
                self.emit(
                    "shared-state",
                    node,
                    "subscript-assign",
                    "in-flight state written by subscript outside "
                    "core/lockstep.py",
                    _SHARED_HINT,
                )
        self.generic_visit(node)


def check_shared_state(tree: ast.AST, relpath: str) -> List[Finding]:
    v = _SharedState(relpath)
    v.visit(tree)
    return v.findings


# -- PL006 observer-purity ---------------------------------------------------
#: The flight-recorder package: may observe everything, mutate nothing.
OBS_PREFIX = "src/repro/obs/"

#: Data-plane mutators the recorder must never call (ISSUE 10: with
#: ``trace=None`` every stat, schedule and parity fingerprint is
#: byte-identical — impossible if observer code can reach these).
_OBS_MUTATORS = {
    "put",
    "record",
    "advance_to",
    "advance",
    "sleep",
    "issue",
    "request",
    "set_placement",
    "set_residency_listener",
    "set_trace_listener",
    "fold_inserts_until",
    "bill_demand_gets",
    "note_miss",
}
#: Stats-object fields observer code must not accumulate into.
_STAT_FIELD_RE = re.compile(
    r"(_seconds|_requests)$|^(samples|hits|misses|evictions|bytes_read)$"
)
_OBS_HINT = (
    "code under src/repro/obs/ is an observer of the lock-step schedule: "
    "it may read state and emit events, never drive clocks, caches, "
    "services or stats — move the mutation to the host component and "
    "have it call into the recorder instead"
)


class _ObserverPurity(_SymbolStack):
    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in _OBS_MUTATORS:
            self.emit(
                "observer-purity",
                node,
                f".{fn.attr}",
                f"recorder-side call to data-plane mutator .{fn.attr}() — "
                "the flight recorder is observe-only",
                _OBS_HINT,
            )
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.target, ast.Attribute) and _STAT_FIELD_RE.search(
            node.target.attr
        ):
            self.emit(
                "observer-purity",
                node,
                f"augassign:{node.target.attr}",
                f"recorder-side accumulation into stats field "
                f".{node.target.attr} — the flight recorder is observe-only",
                _OBS_HINT,
            )
        self.generic_visit(node)


def check_observer_purity(tree: ast.AST, relpath: str) -> List[Finding]:
    v = _ObserverPurity(relpath)
    v.visit(tree)
    return v.findings


#: Recorder entry points banned inside mirror regions (the one sanctioned
#: helper is ``trace_sync`` — reconstruction happens outside the mirror).
_MIRROR_BANNED_HELPERS = {"trace_emit", "trace_demand"}
_MIRROR_EMIT_HINT = (
    "mirrored regions must stay textually identical under role "
    "normalization; raw recorder calls drag projection-specific spellings "
    "into the mirror — route the emission through the ONE shared helper "
    "(trace_sync in repro.obs.events) or move it outside the region"
)


def _mirror_spans(source: str):
    """(begin_line, end_line, name) for every marked region, tolerant of
    marker errors (those are PL001 findings, not ours)."""
    from repro.analysis.mirrors import _marker_lines

    spans = []
    open_marker = None  # (line, name)
    markers = _marker_lines(source)
    for lineno in sorted(markers):
        m = markers[lineno]
        if m.group("kind") == "begin":
            open_marker = (lineno, m.group("name"))
        elif open_marker is not None:
            spans.append((open_marker[0], lineno, open_marker[1]))
            open_marker = None
    return spans


def check_mirror_region_emits(
    tree: ast.AST, relpath: str, source: str
) -> List[Finding]:
    spans = _mirror_spans(source)
    if not spans:
        return []
    findings: List[Finding] = []

    def region_of(lineno: int) -> Optional[str]:
        for lo, hi, name in spans:
            if lo < lineno < hi:
                return name
        return None

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = region_of(getattr(node, "lineno", 0))
        if name is None:
            continue
        fn = node.func
        key: Optional[str] = None
        if isinstance(fn, ast.Attribute) and fn.attr == "emit":
            key = ".emit"
        elif isinstance(fn, ast.Name) and fn.id in _MIRROR_BANNED_HELPERS:
            key = fn.id
        if key is not None:
            findings.append(
                Finding(
                    rule="observer-purity",
                    path=relpath,
                    line=node.lineno,
                    symbol=name,
                    key=key,
                    message=f"raw recorder call {key} inside parity-mirror "
                    f"region {name!r}",
                    hint=_MIRROR_EMIT_HINT,
                )
            )
    return findings


# -- dispatch ---------------------------------------------------------------
def run_rules_on_source(relpath: str, source: str) -> List[Finding]:
    """All path-scoped rules (PL002–PL006) for one file.

    PL001 needs cross-file pairing and runs separately (``mirrors``).
    """
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [
            Finding(
                rule="clock-discipline",
                path=relpath,
                line=exc.lineno or 0,
                symbol="",
                key="syntax-error",
                message=f"file does not parse: {exc.msg}",
                hint="fix the syntax error so the parity linter can scan it",
            )
        ]
    findings: List[Finding] = []
    in_sim_domain = relpath.startswith(SIM_DOMAIN_PREFIXES)
    if in_sim_domain and relpath not in CLOCK_ALLOWLIST:
        findings += check_clock_discipline(tree, relpath)
    if in_sim_domain:
        findings += check_float_determinism(tree, relpath)
    if relpath.startswith("tests/") and is_parity_test_file(relpath, source):
        findings += check_no_tolerance(tree, relpath)
    if relpath.startswith("src/repro/") and relpath != SHARED_STATE_HOME:
        findings += check_shared_state(tree, relpath)
    if relpath.startswith(OBS_PREFIX):
        findings += check_observer_purity(tree, relpath)
    if relpath.startswith("src/repro/"):
        findings += check_mirror_region_emits(tree, relpath, source)
    return findings
