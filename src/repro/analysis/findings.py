"""Structured findings + the committed-baseline mechanism.

A ``Finding`` is one rule violation at one source location: rule slug +
code, repo-relative path, line, enclosing symbol, a short stable ``key``
(what was matched, e.g. ``pytest.approx`` or ``np.sum``), a message and a
fix hint.

The baseline (``tools/parity_lint_baseline.json``) holds *accepted
pre-existing exceptions* so the CI gate fails only on NEW findings.
Entries match findings by fingerprint — ``(rule, path, symbol, key)``,
deliberately *excluding* the line number so unrelated edits above a
baselined site do not churn the file — with a ``count`` bounding how many
occurrences of that fingerprint are accepted and a mandatory human
``reason``.  A finding beyond its baselined count (or with no entry) fails
the gate; a baseline entry no new scan reproduces is reported as stale so
dead exceptions get pruned.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from collections import Counter
from typing import Dict, Iterable, List, Tuple

#: rule slug -> stable code (sorted report order).
RULE_CODES = {
    "mirror-drift": "PL001",
    "clock-discipline": "PL002",
    "float-determinism": "PL003",
    "no-tolerance": "PL004",
    "shared-state": "PL005",
    "observer-purity": "PL006",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str  # slug, a RULE_CODES key
    path: str  # repo-relative posix path
    line: int  # 1-based
    symbol: str  # enclosing function/class (or mirror name), "" at module level
    key: str  # short stable token of what matched (baseline fingerprint part)
    message: str
    hint: str

    @property
    def code(self) -> str:
        return RULE_CODES[self.rule]

    @property
    def fingerprint(self) -> Tuple[str, str, str, str]:
        """Baseline identity: line numbers excluded on purpose (edits above
        a baselined site must not invalidate its entry)."""
        return (self.rule, self.path, self.symbol, self.key)

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (
            f"{self.path}:{self.line}: {self.code} {self.rule}{sym} "
            f"{self.message}\n    hint: {self.hint}"
        )


class Baseline:
    """Accepted-exception ledger, loaded from/saved to JSON."""

    def __init__(self, entries: Iterable[dict] = ()):  # entries: raw dicts
        self.entries: List[dict] = [dict(e) for e in entries]
        for e in self.entries:
            for field in ("rule", "path", "symbol", "key", "count", "reason"):
                if field not in e:
                    raise ValueError(f"baseline entry missing {field!r}: {e}")
            if e["rule"] not in RULE_CODES:
                raise ValueError(f"baseline entry has unknown rule: {e}")

    @classmethod
    def load(cls, path: pathlib.Path) -> "Baseline":
        data = json.loads(path.read_text(encoding="utf-8"))
        return cls(data.get("entries", []))

    @classmethod
    def from_findings(cls, findings: Iterable[Finding], reason: str) -> "Baseline":
        counts = Counter(f.fingerprint for f in findings)
        return cls(
            {
                "rule": rule,
                "path": p,
                "symbol": sym,
                "key": key,
                "count": n,
                "reason": reason,
            }
            for (rule, p, sym, key), n in sorted(counts.items())
        )

    def save(self, path: pathlib.Path) -> None:
        payload = {
            "version": 1,
            "note": (
                "Accepted pre-existing parity-lint exceptions; every entry "
                "needs a reason.  Matching ignores line numbers (fingerprint "
                "= rule/path/symbol/key).  Regenerate candidates with "
                "python -m repro.analysis --write-baseline."
            ),
            "entries": self.entries,
        }
        path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")

    def allowed(self) -> Dict[Tuple[str, str, str, str], int]:
        out: Counter = Counter()
        for e in self.entries:
            out[(e["rule"], e["path"], e["symbol"], e["key"])] += int(e["count"])
        return dict(out)

    def filter(
        self, findings: Iterable[Finding]
    ) -> Tuple[List[Finding], List[dict]]:
        """Split ``findings`` against the baseline.

        Returns ``(new, stale)``: findings NOT covered by the baseline
        (gate failures), and baseline entries whose fingerprint matched
        fewer findings than their count (stale — prune candidates).
        Within one fingerprint, the accepted budget covers occurrences in
        source order; the overflow is new.
        """
        budget = Counter(
            {fp: n for fp, n in self.allowed().items()}
        )
        new: List[Finding] = []
        for f in sorted(findings, key=lambda f: (f.path, f.line)):
            if budget.get(f.fingerprint, 0) > 0:
                budget[f.fingerprint] -= 1
            else:
                new.append(f)
        stale = [
            {
                "rule": fp[0],
                "path": fp[1],
                "symbol": fp[2],
                "key": fp[3],
                "unused": n,
            }
            for fp, n in sorted(budget.items())
            if n > 0
        ]
        return new, stale
