"""``python -m repro.analysis`` — run the parity linter over the repo.

Exit status is 0 iff every finding is covered by the baseline
(``--baseline tools/parity_lint_baseline.json`` in CI); stale baseline
entries are reported as notes, never failures, so pruning stays a chore
rather than an emergency.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List, Optional, Sequence, Tuple

from repro.analysis.findings import Baseline, Finding, RULE_CODES
from repro.analysis.mirrors import MirrorRegion, check_mirrors, scan_mirror_regions
from repro.analysis.rules import run_rules_on_source

#: directories scanned for python sources (repo-relative).
SCAN_ROOTS = ("src", "tests", "tools")
_SKIP_PARTS = {"__pycache__", ".git", ".pytest_cache"}


def _iter_py_files(root: pathlib.Path) -> List[Tuple[pathlib.Path, str]]:
    out: List[Tuple[pathlib.Path, str]] = []
    for sub in SCAN_ROOTS:
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if _SKIP_PARTS.intersection(path.parts):
                continue
            out.append((path, path.relative_to(root).as_posix()))
    return out


def run_analysis(root: pathlib.Path) -> List[Finding]:
    """Scan the tree under ``root``; returns all findings, sorted."""
    findings: List[Finding] = []
    regions: List[MirrorRegion] = []
    for path, relpath in _iter_py_files(root):
        source = path.read_text(encoding="utf-8")
        file_regions, marker_findings = scan_mirror_regions(path, relpath)
        regions += file_regions
        findings += marker_findings
        findings += run_rules_on_source(relpath, source)
    findings += check_mirrors(regions)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="parity linter: mirror/clock/float/tolerance/"
        "shared-state invariants as an AST pass (rules "
        + ", ".join(f"{code} {slug}" for slug, code in RULE_CODES.items())
        + ")",
    )
    parser.add_argument(
        "--root",
        type=pathlib.Path,
        default=pathlib.Path.cwd(),
        help="repo root to scan (default: cwd)",
    )
    parser.add_argument(
        "--baseline",
        type=pathlib.Path,
        default=None,
        help="accepted-exception ledger (tools/parity_lint_baseline.json)",
    )
    parser.add_argument(
        "--json", action="store_true", help="machine-readable findings on stdout"
    )
    parser.add_argument(
        "--write-baseline",
        action="store_true",
        help="write all current findings to --baseline as entries with "
        "reason=TODO (candidates for human review, not an auto-accept)",
    )
    args = parser.parse_args(argv)

    findings = run_analysis(args.root.resolve())

    if args.write_baseline:
        if args.baseline is None:
            parser.error("--write-baseline requires --baseline")
        Baseline.from_findings(
            findings, reason="TODO: justify or fix"
        ).save(args.baseline)
        print(
            f"wrote {len(findings)} finding(s) to {args.baseline} — "
            "review every reason before committing"
        )
        return 0

    baseline = Baseline() if args.baseline is None else Baseline.load(args.baseline)
    new, stale = baseline.filter(findings)

    if args.json:
        print(
            json.dumps(
                {
                    "findings": [
                        {
                            "rule": f.rule,
                            "code": f.code,
                            "path": f.path,
                            "line": f.line,
                            "symbol": f.symbol,
                            "key": f.key,
                            "message": f.message,
                            "hint": f.hint,
                            "baselined": f not in set(new),
                        }
                        for f in findings
                    ],
                    "new": len(new),
                    "stale": stale,
                },
                indent=2,
            )
        )
    else:
        for f in new:
            print(f.render())
        for entry in stale:
            print(
                f"note: stale baseline entry ({entry['rule']} {entry['path']} "
                f"{entry['symbol']} {entry['key']}): {entry['unused']} unused "
                "count(s) — prune it"
            )
        baselined = len(findings) - len(new)
        print(
            f"parity-lint: {len(findings)} finding(s), {baselined} baselined, "
            f"{len(new)} new"
        )
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
