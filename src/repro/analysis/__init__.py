"""Parity linter: the mirror/shared-implementation/no-tolerance discipline
as a machine-checked AST analysis pass (ISSUE 9 tentpole).

Every exact-``==`` parity claim in docs/PARITY.md rests on source-level
conventions: mirrored driver lines between ``NodeSimulator`` and
``DeliLoader``, ONE shared implementation for every decision procedure,
virtual-clock-only time in the simulation domain, sequential-``cumsum``
float chains, and a strict no-tolerance rule in parity tests.  Reviewer
vigilance does not scale with the codebase; this package turns each
convention into a rule that fails CI when it drifts:

``mirror-drift`` (PL001)
    Mirrored regions are *declared in source* via paired
    ``# parity-mirror: <name> begin/end`` markers; the checker verifies
    normalized-AST equivalence between the two halves.  Normalization is
    rename-insensitive for the declared clock/time variable (``self.t``
    on the simulator is the same operation as ``self.clock.sleep`` on the
    loader) and for explicitly-declared role aliases, otherwise exact.
    ``mode=call-shape`` regions (the two ``SubstepAccess`` /
    ``BucketedBatchComm`` instantiation sites) compare the constructor's
    keyword surface instead — operands are per-projection wiring by
    design, but a keyword added on one side only is drift.

``clock-discipline`` (PL002)
    Sim-domain modules (``core/``, ``oracle/``, ``engine/``,
    ``pipeline/``) must not read wall clocks (``time.time`` /
    ``perf_counter`` / ``datetime.now``) or call module-level ``random``
    functions — virtual clocks and seeded ``random.Random`` instances
    only.  The wall-clock abstraction itself (``core/clock.py``), the
    threaded free-running service (``core/prefetcher.py``) and
    ``launch/dryrun.py`` are the explicit allowlist.

``float-determinism`` (PL003)
    No ``np.sum`` (pairwise summation) in sim-domain float chains, no
    built-in ``sum()`` feeding time/stats accumulators, no unordered
    set-iteration feeding float accumulation — the
    ``np.cumsum``-not-pairwise rule from ``repro/engine/vector.py``,
    enforced.

``no-tolerance`` (PL004)
    Test files that import ``assert_parity`` (or are named as parity
    tests) must not use ``pytest.approx`` / ``math.isclose`` /
    ``abs(...) < eps`` comparisons.  Closed-form cost-model pins that
    genuinely need a relative bound live in the committed baseline with a
    stated reason — visible exceptions, never silent ones.

``shared-state`` (PL005)
    Cross-rank mutable state (the cluster placement in-flight set) may
    only be *mutated* inside ``core/lockstep.py`` — the shared
    ``LockstepPrefetchService`` is what keeps both projections' mutations
    at bit-identical virtual times.  Wiring assignments are fine; a new
    ``.add``/``.discard``/``.update`` site anywhere else is flagged.

``observer-purity`` (PL006)
    The flight recorder (``repro/obs/``, ISSUE 10) observes the lock-step
    schedule and must never perturb it: obs-package code may not call
    data-plane mutators (``.put``/``.record``/``.advance_to``/…) or
    accumulate into stats fields, and mirrored ``# parity-mirror``
    regions may not contain raw recorder calls (``.emit`` /
    ``trace_emit`` / ``trace_demand``) — the ONE sanctioned in-mirror
    emission is the shared ``trace_sync`` helper, whose span
    reconstruction lives outside the mirror.  This is what makes
    ``trace=None`` byte-identical to an untraced run.

Run it: ``python -m repro.analysis [--baseline tools/parity_lint_baseline
.json]`` — exit 0 when every finding is baselined, 1 otherwise.  CI runs
it as the named ``parity-lint`` step in ``.github/workflows/smoke.yml``.
"""
from repro.analysis.findings import Baseline, Finding
from repro.analysis.mirrors import MirrorRegion, check_mirrors, scan_mirror_regions
from repro.analysis.cli import main, run_analysis

__all__ = [
    "Baseline",
    "Finding",
    "MirrorRegion",
    "check_mirrors",
    "scan_mirror_regions",
    "main",
    "run_analysis",
]
