"""Rule PL001 ``mirror-drift``: declared mirrored regions stay AST-equal.

The parity story leans on *mirrored driver lines*: ``NodeSimulator.sync_to``
and ``DeliLoader.sync_to`` must perform the identical float operations in
the identical order, the oracle-cursor advance must sit at the same point
of both epoch drivers, the placement install must wire the shared service
identically (docs/PARITY.md).  Historically "mirrored" was a code comment;
this module makes it a declaration the CI gate enforces.

Declaring a mirror
------------------

Wrap each half in paired markers::

    # parity-mirror: sync-to begin clock=self.t stats=self._stats
    ...the mirrored lines...
    # parity-mirror: sync-to end

A mirror name must appear as exactly TWO begin/end regions across the
scanned tree.  The region body (lines strictly between the markers) is
dedented, parsed, normalized, and compared by ``ast.dump`` equality.

Normalization — rename-insensitive for the clock/time variable, otherwise
exact:

* every ``key=expr`` token on the begin marker (except the reserved
  ``clock`` and the call-shape keys) declares a *role alias*: each
  occurrence of that exact expression subtree is replaced by the
  placeholder name ``__key__``, so ``self._stats`` on one side and
  ``self._active_stats`` on the other both normalize to ``__stats__`` —
  the aliasing is explicit and auditable in source, never guessed;
* the reserved ``clock=expr`` role canonicalizes the *time idiom*: the
  simulator spells virtual time as a float attribute (``self.t``), the
  lock-step loader as a ``VirtualClock`` object (``self.clock``), and the
  same operation has two spellings —

  ====================  =========================  =====================
  operation             float-attr spelling        clock-object spelling
  ====================  =========================  =====================
  read now              ``self.t``                 ``clock.now()``
  jump to barrier       ``self.t = x``             ``clock.advance_to(x)``
  charge/sleep          ``self.t += x``            ``clock.sleep(x)``
  now as callable       ``lambda: self.t``         ``clock.now``
  ====================  =========================  =====================

  both spellings canonicalize to the same ``__clock_now__`` /
  ``__clock_set__`` / ``__clock_add__`` forms.  Everything else must match
  exactly — a reordered statement, a changed operand, an extra guard is
  drift.

``mode=call-shape`` (the constructor-site mirrors)
--------------------------------------------------

The two ``SubstepAccess`` / ``BucketedBatchComm`` instantiation sites wire
per-projection operands by design (sentinel payloads vs real bytes, bucket
billing routed differently), so operand equality is the wrong check.  What
must NOT drift is the *surface*: ``mode=call-shape callee=<Name>`` regions
must each contain exactly one call to ``<Name>``, and the two calls must
agree on positional-argument count and the exact ordered tuple of keyword
names — a keyword added or renamed on one side only is exactly the silent
drift this rule exists to catch.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import textwrap
import tokenize
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

MARKER_RE = re.compile(
    r"#\s*parity-mirror:\s*(?P<name>[A-Za-z0-9_.\-]+)\s+(?P<kind>begin|end)\b(?P<rest>[^\n]*)"
)

_HINT = (
    "mirrored halves must stay AST-equivalent (rename-insensitive for the "
    "declared clock/roles); re-mirror the lines or update both halves "
    "together — see docs/PARITY.md 'Enforced by machine'"
)


@dataclasses.dataclass
class MirrorRegion:
    """One declared half of a mirror pair."""

    name: str
    path: str  # repo-relative posix
    line: int  # line of the begin marker (1-based)
    body: str  # dedented source between the markers
    mode: str = "exact"  # "exact" | "call-shape"
    callee: Optional[str] = None  # call-shape: the constructor name
    roles: Dict[str, str] = dataclasses.field(default_factory=dict)


def _parse_marker_rest(rest: str) -> Dict[str, str]:
    """``key=expr`` tokens (space separated, exprs space-free)."""
    out: Dict[str, str] = {}
    for tok in rest.split():
        if "=" not in tok:
            raise ValueError(f"bad parity-mirror token {tok!r} (want key=expr)")
        key, expr = tok.split("=", 1)
        if not key.isidentifier():
            raise ValueError(f"bad parity-mirror role name {tok!r}")
        out[key] = expr
    return out


def _marker_lines(source: str) -> Dict[int, "re.Match"]:
    """Line numbers of real ``# parity-mirror:`` comments.

    Tokenized so marker text quoted inside a docstring or string literal
    (e.g. this module's own examples) is never mistaken for a marker;
    falls back to raw line scanning if the file does not tokenize.
    """
    out: Dict[int, re.Match] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                m = MARKER_RE.search(tok.string)
                if m is not None:
                    out[tok.start[0]] = m
    except (tokenize.TokenError, IndentationError, SyntaxError):
        for lineno, text in enumerate(source.splitlines(), start=1):
            m = MARKER_RE.search(text)
            if m is not None:
                out[lineno] = m
    return out


def scan_mirror_regions(
    path: pathlib.Path, relpath: str
) -> Tuple[List[MirrorRegion], List[Finding]]:
    """Extract every marked region of one file; marker errors (unpaired
    begin/end, bad tokens, nesting) are PL001 findings themselves."""
    regions: List[MirrorRegion] = []
    findings: List[Finding] = []
    open_region: Optional[Tuple[MirrorRegion, List[str]]] = None
    source = path.read_text(encoding="utf-8")
    markers = _marker_lines(source)
    lines = source.splitlines(keepends=True)
    for lineno, text in enumerate(lines, start=1):
        m = markers.get(lineno)
        if m is None:
            if open_region is not None:
                open_region[1].append(text)
            continue
        name, kind, rest = m.group("name"), m.group("kind"), m.group("rest")
        if kind == "begin":
            if open_region is not None:
                findings.append(
                    Finding(
                        rule="mirror-drift",
                        path=relpath,
                        line=lineno,
                        symbol=name,
                        key=f"marker:{name}",
                        message=(
                            f"parity-mirror {name!r} begins inside the still-open "
                            f"region {open_region[0].name!r} (markers do not nest)"
                        ),
                        hint="close the previous region with its end marker first",
                    )
                )
                continue
            try:
                tokens = _parse_marker_rest(rest)
            except ValueError as exc:
                findings.append(
                    Finding(
                        rule="mirror-drift",
                        path=relpath,
                        line=lineno,
                        symbol=name,
                        key=f"marker:{name}",
                        message=str(exc),
                        hint="marker syntax: # parity-mirror: <name> begin [mode=call-shape] [callee=Name] [role=expr ...]",
                    )
                )
                continue
            mode = tokens.pop("mode", "exact")
            callee = tokens.pop("callee", None)
            if mode not in ("exact", "call-shape"):
                findings.append(
                    Finding(
                        rule="mirror-drift",
                        path=relpath,
                        line=lineno,
                        symbol=name,
                        key=f"marker:{name}",
                        message=f"unknown parity-mirror mode {mode!r}",
                        hint="use mode=call-shape or omit mode (exact)",
                    )
                )
                continue
            open_region = (
                MirrorRegion(
                    name=name,
                    path=relpath,
                    line=lineno,
                    body="",
                    mode=mode,
                    callee=callee,
                    roles=tokens,
                ),
                [],
            )
        else:  # end
            if open_region is None or open_region[0].name != name:
                findings.append(
                    Finding(
                        rule="mirror-drift",
                        path=relpath,
                        line=lineno,
                        symbol=name,
                        key=f"marker:{name}",
                        message=f"parity-mirror {name!r} end without matching begin",
                        hint="every end marker closes the begin marker of the same name",
                    )
                )
                continue
            region, body_lines = open_region
            region.body = textwrap.dedent("".join(body_lines))
            regions.append(region)
            open_region = None
    if open_region is not None:
        findings.append(
            Finding(
                rule="mirror-drift",
                path=relpath,
                line=open_region[0].line,
                symbol=open_region[0].name,
                key=f"marker:{open_region[0].name}",
                message=f"parity-mirror {open_region[0].name!r} begin without end",
                hint="close the region with # parity-mirror: <name> end",
            )
        )
    return regions, findings


# -- normalization -----------------------------------------------------------
def _expr_eq(node: ast.AST, pattern_src: str) -> bool:
    """Subtree equality against a declared role/clock expression.

    Compared by ``ast.unparse`` so Load/Store context never matters —
    ``self.t`` as an assignment target is the same clock as ``self.t``
    read."""
    if not isinstance(node, ast.expr):
        return False
    try:
        return ast.unparse(node) == pattern_src
    except Exception:
        return False


class _RoleSubst(ast.NodeTransformer):
    """Replace every occurrence of a declared role expression with the
    placeholder name ``__role__``."""

    def __init__(self, role: str, pattern: ast.expr):
        self.role = role
        self.pattern_src = ast.unparse(pattern)

    def visit(self, node: ast.AST) -> ast.AST:
        if _expr_eq(node, self.pattern_src):
            ctx = getattr(node, "ctx", ast.Load())
            return ast.copy_location(ast.Name(id=f"__{self.role}__", ctx=ctx), node)
        return super().generic_visit(node)


class _ClockCanon(ast.NodeTransformer):
    """Canonicalize the two spellings of virtual-time operations (see the
    module docstring's table) against the declared clock expression."""

    _CALL_MAP = {"now": "__clock_now__", "advance_to": "__clock_set__", "sleep": "__clock_add__"}
    _REF_MAP = {
        "now": "__clock_now_ref__",
        "advance_to": "__clock_set_ref__",
        "sleep": "__clock_add_ref__",
    }

    def __init__(self, clock: ast.expr):
        self.clock_src = ast.unparse(clock)

    def _is_clock(self, node: ast.AST) -> bool:
        return _expr_eq(node, self.clock_src)

    @staticmethod
    def _call(fn: str, args: Sequence[ast.expr]) -> ast.Call:
        return ast.Call(func=ast.Name(id=fn, ctx=ast.Load()), args=list(args), keywords=[])

    def visit_Assign(self, node: ast.Assign) -> ast.AST:
        if len(node.targets) == 1 and self._is_clock(node.targets[0]):
            value = self.visit(node.value)
            return ast.copy_location(
                ast.Expr(value=self._call("__clock_set__", [value])), node
            )
        return self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> ast.AST:
        if self._is_clock(node.target) and isinstance(node.op, ast.Add):
            value = self.visit(node.value)
            return ast.copy_location(
                ast.Expr(value=self._call("__clock_add__", [value])), node
            )
        return self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> ast.AST:
        fn = node.func
        if (
            isinstance(fn, ast.Attribute)
            and fn.attr in self._CALL_MAP
            and self._is_clock(fn.value)
        ):
            return ast.copy_location(
                self._call(
                    self._CALL_MAP[fn.attr], [self.visit(a) for a in node.args]
                ),
                node,
            )
        return self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> ast.AST:
        # Bare (uncalled) clock method reference: clock.now as a callable.
        if fn := self._REF_MAP.get(node.attr):
            if self._is_clock(node.value):
                return ast.copy_location(ast.Name(id=fn, ctx=ast.Load()), node)
        # The clock expression itself in a load position reads "now".
        if self._is_clock(node) and isinstance(node.ctx, ast.Load):
            return ast.copy_location(self._call("__clock_now__", []), node)
        return self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> ast.AST:
        if self._is_clock(node) and isinstance(node.ctx, ast.Load):
            return ast.copy_location(self._call("__clock_now__", []), node)
        return node

    def visit_Lambda(self, node: ast.Lambda) -> ast.AST:
        node = self.generic_visit(node)  # canonicalize the body first
        a = node.args
        if (
            not (a.args or a.posonlyargs or a.kwonlyargs or a.vararg or a.kwarg)
            and isinstance(node.body, ast.Call)
            and isinstance(node.body.func, ast.Name)
            and node.body.func.id == "__clock_now__"
            and not node.body.args
        ):
            # ``lambda: self.t`` is the float-attr spelling of the
            # clock-object's bare ``clock.now`` callable.
            return ast.copy_location(
                ast.Name(id="__clock_now_ref__", ctx=ast.Load()), node
            )
        return node


def _parse_region(body: str) -> ast.Module:
    """Parse a region body; bodies lifted from inside a function may
    contain ``return``, so fall back to wrapping in a throwaway def and
    unwrapping its statements."""
    try:
        return ast.parse(body)
    except SyntaxError:
        wrapped = "def __region__():\n" + textwrap.indent(body or "pass\n", "    ")
        tree = ast.parse(wrapped)
        fn = tree.body[0]
        assert isinstance(fn, ast.FunctionDef)
        return ast.Module(body=fn.body, type_ignores=[])


def normalize_region(region: MirrorRegion) -> str:
    """Parse + normalize one region body; returns the comparable dump."""
    tree = _parse_region(region.body)
    for role, expr_src in sorted(region.roles.items()):
        if role == "clock":
            continue
        pattern = ast.parse(expr_src, mode="eval").body
        tree = _RoleSubst(role, pattern).visit(tree)
    if "clock" in region.roles:
        clock = ast.parse(region.roles["clock"], mode="eval").body
        tree = _ClockCanon(clock).visit(tree)
    return ast.dump(tree)


def _call_shape(region: MirrorRegion) -> Tuple[int, Tuple[str, ...]]:
    """(n positional args, ordered keyword names) of the single declared
    constructor call in a call-shape region."""
    if not region.callee:
        raise ValueError(f"call-shape mirror {region.name!r} needs callee=<Name>")
    tree = _parse_region(region.body)
    calls = [
        node
        for node in ast.walk(tree)
        if isinstance(node, ast.Call)
        and (
            (isinstance(node.func, ast.Name) and node.func.id == region.callee)
            or (isinstance(node.func, ast.Attribute) and node.func.attr == region.callee)
        )
    ]
    if len(calls) != 1:
        raise ValueError(
            f"call-shape mirror {region.name!r} needs exactly one "
            f"{region.callee}(...) call in the region, found {len(calls)}"
        )
    call = calls[0]
    kw_names = tuple(kw.arg if kw.arg is not None else "**" for kw in call.keywords)
    return len(call.args), kw_names


def check_mirrors(regions: Sequence[MirrorRegion]) -> List[Finding]:
    """Pairing + equivalence findings over all scanned regions."""
    findings: List[Finding] = []
    by_name: Dict[str, List[MirrorRegion]] = {}
    for r in regions:
        by_name.setdefault(r.name, []).append(r)
    for name, halves in sorted(by_name.items()):
        if len(halves) != 2:
            for r in halves:
                findings.append(
                    Finding(
                        rule="mirror-drift",
                        path=r.path,
                        line=r.line,
                        symbol=name,
                        key=f"pairing:{name}",
                        message=(
                            f"parity-mirror {name!r} has {len(halves)} region(s); "
                            "a mirror is exactly two halves"
                        ),
                        hint="declare the partner region (or remove the orphan marker)",
                    )
                )
            continue
        a, b = halves
        if a.mode != b.mode or (a.mode == "call-shape" and a.callee != b.callee):
            findings.append(_mismatch(name, a, b, "the two halves declare different modes"))
            continue
        try:
            if a.mode == "call-shape":
                shape_a, shape_b = _call_shape(a), _call_shape(b)
                if shape_a != shape_b:
                    findings.append(
                        _mismatch(
                            name,
                            a,
                            b,
                            f"constructor surface drifted: {a.callee} takes "
                            f"{shape_a[0]} positional + keywords {list(shape_a[1])} "
                            f"vs {shape_b[0]} positional + keywords {list(shape_b[1])}",
                        )
                    )
            else:
                dump_a, dump_b = normalize_region(a), normalize_region(b)
                if dump_a != dump_b:
                    findings.append(
                        _mismatch(name, a, b, _first_divergence(dump_a, dump_b))
                    )
        except (SyntaxError, ValueError) as exc:
            findings.append(_mismatch(name, a, b, f"region not checkable: {exc}"))
    return findings


def _mismatch(name: str, a: MirrorRegion, b: MirrorRegion, detail: str) -> Finding:
    return Finding(
        rule="mirror-drift",
        path=a.path,
        line=a.line,
        symbol=name,
        key=f"mirror:{name}",
        message=(
            f"mirror {name!r} drifted between {a.path}:{a.line} and "
            f"{b.path}:{b.line}: {detail}"
        ),
        hint=_HINT,
    )


def _first_divergence(dump_a: str, dump_b: str, context: int = 40) -> str:
    """A human-aimable pointer into two normalized dumps."""
    n = min(len(dump_a), len(dump_b))
    i = next((j for j in range(n) if dump_a[j] != dump_b[j]), n)
    lo = max(0, i - context)
    return (
        "normalized ASTs differ near "
        f"...{dump_a[lo:i + context]!r} vs ...{dump_b[lo:i + context]!r}"
    )
