"""The composable read-tier stack: the paper's layered data plane, explicit.

The paper's §IV design is a *layered* read path — node-local cache, peer
caches (PR 1's cooperative tier), object-store bucket — but until this
module the layers were implicit: every component duck-typed its neighbours
(``getattr(store, "get_with_origin")``, ``getattr(store, "clock")``) and
hit attribution was a pile of ad-hoc booleans.  Hoard (Pinto et al.) showed
tiered caches want an explicit tier interface; this module provides it:

  * ``TierResult`` — one read's full attribution: payload, which tier
    served it, Class B requests billed, bytes moved, seconds spent.
  * ``ReadTier``   — the protocol: ``lookup(index) -> Optional[TierResult]``
    (None = this tier does not hold the sample; the next tier is consulted).
    A tier that misses may still charge time (e.g. a failed peer probe pays
    the lookup RTT on the tier's clock).
  * ``RamTier`` / ``DiskTier``  — the two halves of a ``CappedCache``
    (in-memory entries vs spill files), reported separately so the explicit
    RAM-tier measurement from the seed (``EpochStats.ram_hits``) survives.
  * ``PeerTier``   — PR 1's cooperative peer-cache tier over a ``PeerStore``.
  * ``BucketTier`` — the authoritative source (any ``SampleStore``); always
    hits or raises ``StoreError``.
  * ``TierStack``  — an ordered composition; ``fetch`` walks tiers until one
    serves the read.

``tiers_for_store`` maps a store object onto its remote tiers (peer tier +
wrapped bucket for a ``PeerStore``, plain bucket otherwise) — one explicit
``isinstance``, replacing scattered ``getattr`` probes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence, runtime_checkable

from repro.core.cache import CappedCache
from repro.core.store import SampleStore, StoreError

# Single source of truth lives in repro.core.types (the dependency root,
# where EpochStats derives hits/misses from it); re-exported here as part
# of the tier API: tiers whose hits are *local-cache* hits — everything
# else (peer, bucket) is a miss of the local cache even when it avoids the
# bucket.
from repro.core.types import LOCAL_TIERS  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class TierResult:
    """Full attribution for one served read."""

    payload: bytes
    tier: str  # "ram" | "disk" | "peer" | "bucket" | ...
    class_b: int = 0  # Class B GETs billed serving this read
    nbytes: int = 0  # payload bytes moved across the tier boundary
    seconds: float = 0.0  # time spent inside the tier (virtual or wall)

    @property
    def local_hit(self) -> bool:
        return self.tier in LOCAL_TIERS


@runtime_checkable
class ReadTier(Protocol):
    """One layer of the data plane's read path."""

    name: str

    def lookup(self, index: int) -> Optional[TierResult]:
        """Serve ``index`` from this tier, or return None (not resident).

        A miss may still charge time to the tier's clock (failed probes);
        it must never raise for mere non-residency.
        """
        ...


class RamTier:
    """In-memory half of a ``CappedCache`` (the paper's WiredTiger RAM set)."""

    name = "ram"

    def __init__(self, cache: CappedCache):
        self.cache = cache

    def lookup(self, index: int) -> Optional[TierResult]:
        payload = self.cache.probe_ram(index)
        if payload is None:
            return None
        return TierResult(payload, self.name, nbytes=len(payload))


class DiskTier:
    """Spill-file half of a ``CappedCache`` (entries beyond ``ram_items``)."""

    name = "disk"

    def __init__(self, cache: CappedCache):
        self.cache = cache

    def lookup(self, index: int) -> Optional[TierResult]:
        payload = self.cache.probe_disk(index)
        if payload is None:
            return None
        return TierResult(payload, self.name, nbytes=len(payload))


class PeerTier:
    """Cooperative peer-cache tier: another node's cache over the network.

    Wraps a ``repro.distributed.PeerStore`` (constructed for it by
    ``tiers_for_store``), whose ``peer_lookup`` owns the registry probe,
    the modelled transfer time and the peer-traffic accounting — so
    ``PeerStore.peer_hits`` keeps counting physical peer reads no matter
    which path (demand or pre-fetch) performed them.
    """

    name = "peer"

    def __init__(self, store: "SampleStore"):
        # A PeerStore; typed loosely to keep this module import-light.
        self.store = store

    def lookup(self, index: int) -> Optional[TierResult]:
        return self.store.peer_lookup(index)


class BucketTier:
    """The authoritative source: any ``SampleStore`` (always serves)."""

    name = "bucket"

    def __init__(self, store: SampleStore):
        self.store = store

    def lookup(self, index: int) -> Optional[TierResult]:
        t0 = self.store.clock.now()
        payload = self.store.get(index)
        dt = self.store.clock.now() - t0
        return TierResult(
            payload, self.name, class_b=1, nbytes=len(payload), seconds=dt
        )


class DiskSourceTier:
    """The paper's local-disk *source* baseline (not the cache spill tier).

    Wraps a ``FileSystemStore`` holding the materialized dataset.  Reads
    are attributed to tier ``"disk-source"`` — deliberately outside
    ``LOCAL_TIERS``, because the disk baseline has no cache at all: every
    access counts as a miss (miss rate 1.0), matching the simulator's
    disk-source accounting.  No Class B request is billed (local disk is
    not object storage)."""

    name = "disk-source"

    def __init__(self, store: SampleStore):
        self.store = store

    def lookup(self, index: int) -> Optional[TierResult]:
        t0 = self.store.clock.now()
        payload = self.store.get(index)
        dt = self.store.clock.now() - t0
        return TierResult(
            payload, self.name, class_b=0, nbytes=len(payload), seconds=dt
        )


class TierStack:
    """Ordered composition of read tiers — the node's whole read path."""

    def __init__(self, tiers: Sequence[ReadTier]):
        if not tiers:
            raise ValueError("a TierStack needs at least one tier")
        self.tiers: List[ReadTier] = list(tiers)

    def names(self) -> List[str]:
        return [t.name for t in self.tiers]

    def lookup(self, index: int) -> Optional[TierResult]:
        for tier in self.tiers:
            result = tier.lookup(index)
            if result is not None:
                return result
        return None

    def fetch(self, index: int) -> TierResult:
        """Walk the stack; the last tier is expected to be authoritative."""
        result = self.lookup(index)
        if result is None:
            raise StoreError(f"no tier in {self.names()} holds object {index}")
        return result


def tiers_for_store(store: SampleStore) -> List[ReadTier]:
    """The *remote* tiers behind a store object (everything past the local
    cache): ``[PeerTier, BucketTier]`` for a ``PeerStore``, else
    ``[BucketTier]``.  This one explicit dispatch replaces the
    ``getattr(store, "get_with_origin")`` duck-typing the seed used."""
    from repro.distributed.peer_cache import PeerStore  # leaf module; no cycle

    if isinstance(store, PeerStore):
        return [PeerTier(store), BucketTier(store.inner)]
    return [BucketTier(store)]


def local_tiers_for_cache(cache: Optional[CappedCache]) -> List[ReadTier]:
    """The node-local tiers over a cache (empty stack for cache-less modes)."""
    if cache is None:
        return []
    return [RamTier(cache), DiskTier(cache)]
