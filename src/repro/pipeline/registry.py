"""Named-component registry: declare benchmark conditions by name.

Benchmarks and examples used to hand-assemble every experimental condition;
with the registry a condition is a *name* plus overrides:

    from repro.pipeline import condition
    spec = condition("cache+peer", MNIST.scaled(0.05), cache_items=512)

Registered names cover the paper's figures (disk / gcp-direct / cache /
fifty-fifty / full-fetch) and the beyond-paper tiers (cache+peer,
cache+peer+repl, locality).  Third parties extend via
``@register_condition("my-condition")``.

Every factory passes ``**overrides`` through to ``DataPlaneSpec``, so
cross-cutting spec knobs ride along with any named condition — e.g.
``engine="vector"`` (ISSUE 6) selects the vectorized segment engine for
the simulator projection with bit-identical results, and the ISSUE 4
schedule knobs (``sync``, ``granularity``, ``nodes``) compose the same
way.

Samplers are registered the same way so ``DataPlaneSpec.sampler`` stays a
plain string:

  * ``"partition"``      — the paper's DistributedSampler semantics (a new
    seeded global permutation per epoch, strided slice per node);
  * ``"locality"``       — cache-aware partitioning (beyond-paper);
  * ``"shared-shuffle"`` — every node streams the full dataset in its own
    order (the Hoard-style regime where *same-epoch* cross-node cache
    visibility matters; exercised by the interleaved-scheduler tests).
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.core.bandwidth import (
    CollectiveModel,
    mnist_cnn_gradient_bytes,
    straggler_profiles,
)
from repro.core.policy import PrefetchConfig
from repro.core.sampler import (
    DistributedPartitionSampler,
    LocalityAwareSampler,
    Sampler,
    SharedShuffleSampler,
)
from repro.core.workloads import WorkloadSpec
from repro.pipeline.spec import DataPlaneSpec

# ---------------------------------------------------------------------------
# Samplers.
# ---------------------------------------------------------------------------
_SAMPLERS: Dict[str, Callable[..., Sampler]] = {}


def register_sampler(name: str, factory: Callable[..., Sampler]) -> None:
    if name in _SAMPLERS:
        raise ValueError(f"sampler {name!r} already registered")
    _SAMPLERS[name] = factory


def make_sampler(
    name: str, *, n_samples: int, rank: int, world: int, seed: int, peer_aware: bool
) -> Sampler:
    try:
        factory = _SAMPLERS[name]
    except KeyError:
        raise ValueError(
            f"unknown sampler {name!r}; registered: {sorted(_SAMPLERS)}"
        ) from None
    return factory(
        n_samples=n_samples, rank=rank, world=world, seed=seed, peer_aware=peer_aware
    )


def list_samplers() -> List[str]:
    return sorted(_SAMPLERS)


register_sampler(
    "partition",
    lambda *, n_samples, rank, world, seed, peer_aware: DistributedPartitionSampler(
        n_samples, rank, world, seed=seed
    ),
)
register_sampler(
    "locality",
    lambda *, n_samples, rank, world, seed, peer_aware: LocalityAwareSampler(
        n_samples, rank, world, seed=seed, peer_aware=peer_aware
    ),
)
register_sampler(
    "shared-shuffle",
    lambda *, n_samples, rank, world, seed, peer_aware: SharedShuffleSampler(
        n_samples, rank, world, seed=seed
    ),
)

# ---------------------------------------------------------------------------
# Conditions.
# ---------------------------------------------------------------------------
_CONDITIONS: Dict[str, Callable[..., DataPlaneSpec]] = {}


def register_condition(name: str) -> Callable:
    """Decorator: register a ``(workload, **overrides) -> DataPlaneSpec``."""

    def deco(fn: Callable[..., DataPlaneSpec]) -> Callable[..., DataPlaneSpec]:
        if name in _CONDITIONS:
            raise ValueError(f"condition {name!r} already registered")
        _CONDITIONS[name] = fn
        return fn

    return deco


def condition(name: str, workload: WorkloadSpec, **overrides) -> DataPlaneSpec:
    """Build a named condition's spec for ``workload``."""
    try:
        factory = _CONDITIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown condition {name!r}; registered: {sorted(_CONDITIONS)}"
        ) from None
    return factory(workload, **overrides)


def list_conditions() -> List[str]:
    return sorted(_CONDITIONS)


@register_condition("disk")
def _disk(workload: WorkloadSpec, **kw) -> DataPlaneSpec:
    """The paper's local-disk baseline (simulator-only source)."""
    return DataPlaneSpec(workload=workload, source="disk", **kw)


@register_condition("gcp-direct")
def _gcp_direct(workload: WorkloadSpec, **kw) -> DataPlaneSpec:
    """Direct bucket reads, no cache (the paper's worst case)."""
    return DataPlaneSpec(workload=workload, cache_items=None, **kw)


@register_condition("cache")
def _cache(workload: WorkloadSpec, cache_items: int = -1, **kw) -> DataPlaneSpec:
    """Node-local capped cache, no pre-fetch (paper §IV-B)."""
    return DataPlaneSpec(workload=workload, cache_items=cache_items, **kw)


@register_condition("cache+peer")
def _cache_peer(workload: WorkloadSpec, cache_items: int = -1, **kw) -> DataPlaneSpec:
    """PR 1's cooperative peer-cache tier on top of the local cache."""
    return DataPlaneSpec(
        workload=workload, cache_items=cache_items, peer_cache=True, **kw
    )


@register_condition("cache+peer+repl")
def _cache_peer_repl(
    workload: WorkloadSpec, cache_items: int = -1, **kw
) -> DataPlaneSpec:
    """Peer tier + Hoard-style replication-aware eviction."""
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        peer_cache=True,
        replication_aware_eviction=True,
        **kw,
    )


@register_condition("fifty-fifty")
def _fifty_fifty(workload: WorkloadSpec, cache_items: int = 2048, **kw) -> DataPlaneSpec:
    """The paper's best configuration: f = T = cache/2 (§V-B)."""
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        prefetch=PrefetchConfig.fifty_fifty(cache_items),
        **kw,
    )


@register_condition("full-fetch")
def _full_fetch(workload: WorkloadSpec, fetch_size: int = 2048, **kw) -> DataPlaneSpec:
    """'Full Fetch': cache == fetch size, threshold 0 (Fig. 9 baseline)."""
    return DataPlaneSpec(
        workload=workload,
        cache_items=fetch_size,
        prefetch=PrefetchConfig.full_fetch(fetch_size),
        **kw,
    )


@register_condition("locality")
def _locality(workload: WorkloadSpec, cache_items: int = -1, **kw) -> DataPlaneSpec:
    """Cache-aware partitioning (beyond-paper, Yang & Cong '19 direction)."""
    return DataPlaneSpec(
        workload=workload, cache_items=cache_items, sampler="locality", **kw
    )


@register_condition("lm")
def _lm(
    workload: WorkloadSpec,
    seq_len: int = 128,
    vocab: int = 512,
    cache_items: int = 2048,
    **kw,
) -> DataPlaneSpec:
    """Synthetic LM pre-training shards over the DELI pipeline (ROADMAP:
    ``make_lm_pipeline`` folded into the spec layer).  One sample = one
    packed ``seq_len + 1``-token int32 sequence.  Delegates to
    ``repro.data.make_lm_spec`` — ONE home for the LM defaults
    (fast-forwarded bucket, 50/50 policy, token payload factory) — taking
    the dataset/cluster/batch shape from ``workload``."""
    import dataclasses as _dc

    from repro.data.synthetic import make_lm_spec

    spec = make_lm_spec(
        n_samples=workload.n_samples,
        seq_len=seq_len,
        vocab=vocab,
        batch_size=workload.batch_size,
        cache_items=cache_items,
        world=workload.n_nodes,
        policy=kw.pop("prefetch", None),
        bucket_model=kw.pop("bucket", None),
        seed=kw.pop("seed", 0),
    )
    return _dc.replace(spec, **kw) if kw else spec


@register_condition("belady-only")
def _belady_only(workload: WorkloadSpec, cache_items: int = 2048, **kw) -> DataPlaneSpec:
    """Belady (farthest-future-use) eviction on the demand path, no
    pre-fetch service (ISSUE 5): isolates what clairvoyant *eviction* alone
    buys over the capped-collection FIFO order at equal capacity."""
    return DataPlaneSpec(
        workload=workload, cache_items=cache_items, eviction="belady", **kw
    )


@register_condition("oracle")
def _oracle(workload: WorkloadSpec, cache_items: int = 2048, **kw) -> DataPlaneSpec:
    """The full oracle data plane (ISSUE 5): clairvoyant prefetch rounds
    (deadline-ordered, capacity-windowed, residency-filtered — no
    fetch_size/threshold knobs) + Belady eviction.  Clairvoyance subsumes
    the paper prototype's per-round re-listing — the oracle already holds
    the full key list — so the condition defaults to the listing cache
    (``list_every_fetch=False``; one initial Class A listing is still
    billed).  The optimality reference the heuristic conditions are
    measured against (``benchmarks/fig12_oracle_gap.py``)."""
    kw.setdefault("list_every_fetch", False)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        prefetch_policy="oracle",
        eviction="belady",
        **kw,
    )


@register_condition("oracle+peer")
def _oracle_peer(workload: WorkloadSpec, cache_items: int = 2048, **kw) -> DataPlaneSpec:
    """Oracle data plane + the cooperative peer tier: cluster-resident keys
    are pulled over the inter-node network at round issue (the shared
    ``LockstepPrefetchService`` peer partition) and never billed to
    Class B — Hoard-style placement compounding the clairvoyant win."""
    kw.setdefault("list_every_fetch", False)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        prefetch_policy="oracle",
        eviction="belady",
        peer_cache=True,
        **kw,
    )


@register_condition("oracle-cost")
def _oracle_cost(workload: WorkloadSpec, cache_items: int = 2048, **kw) -> DataPlaneSpec:
    """Oracle data plane with cost-aware round sizing (ISSUE 7 satellite):
    round sizes are solved from the calibrated bandwidth models against
    next-use deadlines (``repro.oracle.RoundCostModel``) instead of the
    doubling ramp.  Everything else matches the ``"oracle"`` condition."""
    kw.setdefault("list_every_fetch", False)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        prefetch_policy="oracle",
        eviction="belady",
        round_sizing="cost",
        **kw,
    )


@register_condition("cluster-oracle")
def _cluster_oracle(
    workload: WorkloadSpec, cache_items: int = 2048, **kw
) -> DataPlaneSpec:
    """Cluster clairvoyant placement (ISSUE 7 tentpole): ONE cross-rank
    plan partitions the union of epoch orders so each key is bucket-fetched
    by exactly one owner rank ahead of its cluster-wide first use and
    served to every other rank over the peer tier — Hoard's placement idea
    driven by NoPFS's clairvoyance.  Per-rank scheduling (deadline order,
    capacity window, residency filter) is unchanged from ``"oracle+peer"``;
    only the bucket/peer/defer partition of each round differs.  Quantified
    by ``benchmarks/fig14_cluster_placement.py``."""
    kw.setdefault("list_every_fetch", False)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        prefetch_policy="cluster-oracle",
        eviction="belady",
        peer_cache=True,
        **kw,
    )


@register_condition("cluster-oracle+peer-capped")
def _cluster_oracle_capped(
    workload: WorkloadSpec, cache_frac: float = 0.5, **kw
) -> DataPlaneSpec:
    """Cluster placement under capacity pressure: each node's cache holds
    only ``cache_frac`` of its per-rank shard, so the ownership plan must
    survive evictions and deferral retries (the graceful-degradation regime
    the placement tests sweep)."""
    cache_items = max(2, int(workload.partition_size * cache_frac))
    kw.setdefault("list_every_fetch", False)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        prefetch_policy="cluster-oracle",
        eviction="belady",
        peer_cache=True,
        **kw,
    )


@register_condition("batch-sync")
def _batch_sync(workload: WorkloadSpec, cache_items: int = -1, **kw) -> DataPlaneSpec:
    """Per-batch allreduce barriers (data-parallel SGD schedule, ISSUE 4):
    nodes synchronize gradients after every batch instead of only at epoch
    boundaries; blocked time lands in ``EpochStats.allreduce_wait_seconds``."""
    return DataPlaneSpec(
        workload=workload, cache_items=cache_items, sync="batch", **kw
    )


@register_condition("straggler")
def _straggler(
    workload: WorkloadSpec,
    cache_items: int = -1,
    compute: float = 2.0,
    bandwidth: float = 2.0,
    slow_ranks: tuple = (0,),
    **kw,
) -> DataPlaneSpec:
    """The canonical straggler scenario (``benchmarks/fig11_stragglers.py``):
    a cooperative peer-cache cluster under the per-batch allreduce schedule
    with ``slow_ranks`` slowed by the given compute/bandwidth factors."""
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        peer_cache=True,
        sync="batch",
        nodes=straggler_profiles(
            workload.n_nodes, slow_ranks=slow_ranks, compute=compute, bandwidth=bandwidth
        ),
        **kw,
    )


def _default_collective(kw: dict, gradient_bytes: int = 0) -> CollectiveModel:
    """Pop/auto-build the collective for the ISSUE 8 conditions: callers may
    pass ``collective=CollectiveModel(...)`` or just ``gradient_bytes=...``;
    the default is the paper's MNIST CNN gradient over the ring algorithm."""
    collective = kw.pop("collective", None)
    if collective is None:
        collective = CollectiveModel(
            gradient_bytes=kw.pop("gradient_bytes", gradient_bytes)
            or mnist_cnn_gradient_bytes()
        )
    else:
        kw.pop("gradient_bytes", None)
    return collective


@register_condition("bsync-cost")
def _bsync_cost(workload: WorkloadSpec, cache_items: int = -1, **kw) -> DataPlaneSpec:
    """Per-batch allreduce barriers *with a priced collective* (ISSUE 8):
    the barrier carries the ring-allreduce transfer duration of the
    gradient (default: the paper's MNIST CNN, ~1.8 MB fp32), split into
    ``allreduce_wait_seconds`` (skew) + ``allreduce_comm_seconds``
    (transfer)."""
    collective = _default_collective(kw)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        sync="batch",
        collective=collective,
        **kw,
    )


@register_condition("overlap")
def _overlap(workload: WorkloadSpec, cache_items: int = -1, **kw) -> DataPlaneSpec:
    """``bsync-cost`` + gradient-bucket communication/compute overlap: the
    allreduce issues per-bucket, pipelined against the remaining backprop
    spans, so only the exposed comm tail is charged
    (``benchmarks/fig15_comm_overlap.py`` measures the hidden fraction)."""
    collective = _default_collective(kw)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        sync="batch",
        collective=collective,
        overlap="buckets",
        **kw,
    )


@register_condition("backup-1")
def _backup_1(
    workload: WorkloadSpec,
    cache_items: int = -1,
    backup_workers: int = 1,
    compute: float = 2.0,
    bandwidth: float = 2.0,
    slow_ranks: tuple = (0,),
    **kw,
) -> DataPlaneSpec:
    """Backup-worker mitigation over the canonical straggler cluster: each
    priced barrier releases once ``n - k`` ranks arrive; the slowest ``k``
    drop their partial gradient and skip the wait entirely."""
    collective = _default_collective(kw)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        sync="batch",
        collective=collective,
        backup_workers=backup_workers,
        nodes=straggler_profiles(
            workload.n_nodes, slow_ranks=slow_ranks, compute=compute, bandwidth=bandwidth
        ),
        **kw,
    )


@register_condition("stale-2")
def _stale_2(
    workload: WorkloadSpec,
    cache_items: int = -1,
    staleness_bound: int = 2,
    **kw,
) -> DataPlaneSpec:
    """Bounded-staleness mitigation: a rank may run up to ``s`` gradient
    batches ahead of the last released barrier before parking (stale-
    synchronous parallel on the priced schedule)."""
    collective = _default_collective(kw)
    return DataPlaneSpec(
        workload=workload,
        cache_items=cache_items,
        sync="batch",
        collective=collective,
        staleness_bound=staleness_bound,
        **kw,
    )
