"""Sim-vs-runtime parity harness.

The whole point of ``DataPlaneSpec`` is that the discrete-event simulator
and the threaded runtime are projections of one description.  For
*deterministic* specs — no asynchronous pre-fetch service racing the
training loop — the two projections must agree **exactly** on everything
that is a pure function of cache-state evolution:

  * per-tier hit counts (ram / peer / bucket), aggregated over the run;
  * total Class B requests issued to the bucket;
  * per-(epoch, node) sample counts.

``assert_parity`` checks exactly that on a ``VirtualClock``.  Specs with
prefetching enabled are rejected: the threaded service's completion times
depend on OS scheduling, so agreement there is *statistical* (covered by
``tests/test_core_sim_and_cost.py::test_sim_vs_threaded_runtime_miss_rate_agreement``),
not exact — refusing loudly beats a flaky assertion.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.clock import VirtualClock
from repro.core.types import aggregate_tier_hits
from repro.pipeline.spec import DataPlaneSpec


@dataclasses.dataclass
class ParityReport:
    spec_label: str
    epochs: int
    sim_tiers: Dict[str, int]
    runtime_tiers: Dict[str, int]
    sim_class_b: int
    runtime_class_b: int
    sim_samples: List[Tuple[int, int, int]]  # (epoch, node, samples)
    runtime_samples: List[Tuple[int, int, int]]

    @property
    def exact(self) -> bool:
        return (
            self.sim_tiers == self.runtime_tiers
            and self.sim_class_b == self.runtime_class_b
            and self.sim_samples == self.runtime_samples
        )

    def describe(self) -> str:
        status = "EXACT" if self.exact else "DIVERGED"
        return (
            f"parity[{self.spec_label}, {self.epochs} epochs]: {status}\n"
            f"  tiers   sim={self.sim_tiers} runtime={self.runtime_tiers}\n"
            f"  class B sim={self.sim_class_b} runtime={self.runtime_class_b}"
        )


def run_parity(spec: DataPlaneSpec, epochs: int = 2) -> ParityReport:
    """Build both projections of ``spec`` and compare their accounting."""
    if spec.prefetch is not None and spec.prefetch.enabled:
        raise ValueError(
            "exact parity is defined for deterministic specs only; disable "
            "prefetching (the async service races the loop by design — use "
            "the statistical agreement test for prefetch-enabled specs)"
        )
    sim_stats, sim_store = spec.build_sim().run(epochs=epochs)
    with spec.build_runtime(clock=VirtualClock()) as cluster:
        run_stats, run_store = cluster.run(epochs=epochs)
    return ParityReport(
        spec_label=spec.label(),
        epochs=epochs,
        sim_tiers=aggregate_tier_hits(sim_stats),
        runtime_tiers=aggregate_tier_hits(run_stats),
        sim_class_b=sim_store.class_b_requests,
        runtime_class_b=run_store.class_b_requests,
        sim_samples=[(s.epoch, s.node, s.samples) for s in sim_stats],
        runtime_samples=[(s.epoch, s.node, s.samples) for s in run_stats],
    )


def assert_parity(spec: DataPlaneSpec, epochs: int = 2) -> ParityReport:
    report = run_parity(spec, epochs=epochs)
    assert report.exact, report.describe()
    return report
