"""Sim-vs-runtime parity harness: exact by construction, never by tolerance.

The whole point of ``DataPlaneSpec`` is that the discrete-event simulator
and the lock-step runtime are projections of one description.  Both walk
the same ``CappedCache``/``PrefetchPlanner``/``PeerCacheRegistry`` state
machines, share the literal ``repro.core.lockstep`` pre-fetch event code,
and advance virtual time through the same float operations in the same
order — so everything that is a function of cache-state evolution must
agree **exactly**:

  * per-tier hit counts (ram / disk / peer / bucket / disk-source),
    aggregated over the run;
  * total Class A (listing) and Class B (GET) requests billed;
  * per-(epoch, node) sample counts, **data-wait seconds** and — since the
    per-batch allreduce schedule (ISSUE 4) — **allreduce-wait seconds**,
    plus (ISSUE 8) **allreduce-comm seconds** (the collective's transfer
    time, bucketed-overlap exposed tails included) — bit-equal floats, not
    approximately-equal ones.

Since ISSUE 4 the parity domain additionally covers ``sync="batch"``
(per-batch allreduce barriers), ``granularity="substep"`` (per-component
scheduler events) and heterogeneous ``nodes`` profiles (stragglers): the
barrier arithmetic lives once in ``repro.core.lockstep`` and straggler
scaling rebuilds the calibrated models through the same ``NodeProfile``
methods on both sides.

Since ISSUE 5 the oracle data plane is in scope too:
``eviction="belady"`` and ``prefetch_policy="oracle"`` specs stay exact
because the clairvoyant machinery is, again, ONE implementation —
``repro.oracle``'s ``NodeAccessView`` cursor is advanced by mirrored
driver lines, ``BeladyEviction`` is a pure function of cache state +
``next_use``, and both projections build their epoch planner through the
same ``repro.oracle.planner.planner_for`` call — composed with every
schedule knob above (batch sync, sub-step events, stragglers).

Since ISSUE 6 the same discipline covers the simulator's two *execution
engines*: ``engine="vector"`` (``repro.engine.vector``) batches each
node's between-interaction segment into numpy array ops yet agrees with
the scalar stepper bit-for-bit — one cost kernel
(``repro.engine.kernels.DemandKernel``), sequential ``np.cumsum``
accumulation (the same rounding as repeated ``t += x``), segments cut at
exactly the points where scalar state can change.  The parity runs here
always compare the simulator against the lock-step runtime at whatever
engine the spec declares (the runtime builds loaders, not engines);
scalar-vs-vector equivalence itself is enforced by
``tests/test_engine_equivalence.py`` with the same ``==``-only policy.

``assert_parity`` checks exactly that, driving ``build_runtime()`` in its
default lock-step mode.  Since the lock-step scheduler landed, specs with
**prefetching enabled are in scope**: service completions are virtual-time
events drained at defined barriers on both projections, so the old
"the async service races the loop" escape hatch is gone — and so is the
temptation to paper over drift with tolerances.  A tolerance would turn
every future scheduling bug into a silently absorbed error; refusing to
have one keeps the parity suite a tripwire (docs/PARITY.md tells the whole
story).

Statistical agreement between the simulator and the *free-running threaded*
runtime (real worker threads, OS scheduling) remains a separate, weaker
property, covered by
``tests/test_core_sim_and_cost.py::test_sim_vs_threaded_runtime_miss_rate_agreement``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.types import aggregate_tier_hits
from repro.pipeline.spec import DataPlaneSpec


@dataclasses.dataclass
class ParityReport:
    """Side-by-side accounting of one spec's two projections.

    ``exact`` is the parity property; ``describe()`` renders the
    comparison for assertion messages and docs."""

    spec_label: str
    epochs: int
    sim_tiers: Dict[str, int]
    runtime_tiers: Dict[str, int]
    sim_class_a: int
    runtime_class_a: int
    sim_class_b: int
    runtime_class_b: int
    # (epoch, node, samples, data_wait_s, allreduce_wait_s,
    #  allreduce_comm_s) per node-epoch.  Comm appended as the 6th element
    # (ISSUE 8) so existing row[4] consumers keep reading the wait.
    sim_samples: List[Tuple[int, int, int, float, float, float]]
    runtime_samples: List[Tuple[int, int, int, float, float, float]]

    @property
    def exact(self) -> bool:
        return (
            self.sim_tiers == self.runtime_tiers
            and self.sim_class_a == self.runtime_class_a
            and self.sim_class_b == self.runtime_class_b
            and self.sim_samples == self.runtime_samples
        )

    def describe(self) -> str:
        status = "EXACT" if self.exact else "DIVERGED"
        lines = [
            f"parity[{self.spec_label}, {self.epochs} epochs]: {status}",
            f"  tiers   sim={self.sim_tiers} runtime={self.runtime_tiers}",
            f"  class A sim={self.sim_class_a} runtime={self.runtime_class_a}",
            f"  class B sim={self.sim_class_b} runtime={self.runtime_class_b}",
        ]
        if self.sim_samples != self.runtime_samples:
            for s, r in zip(self.sim_samples, self.runtime_samples):
                if s != r:
                    lines.append(f"  node-epoch sim={s} runtime={r}")
        return "\n".join(lines)


def run_parity(spec: DataPlaneSpec, epochs: int = 2) -> ParityReport:
    """Build both projections of ``spec`` and compare their accounting.

    Prefetch-enabled specs are fully supported: the runtime is the
    lock-step projection (``build_runtime()`` with no clock), whose
    pre-fetch completions are deterministic virtual-time events."""
    sim_stats, sim_store = spec.build_sim().run(epochs=epochs)
    with spec.build_runtime() as cluster:
        run_stats, run_store = cluster.run(epochs=epochs)
    return ParityReport(
        spec_label=spec.label(),
        epochs=epochs,
        sim_tiers=aggregate_tier_hits(sim_stats),
        runtime_tiers=aggregate_tier_hits(run_stats),
        sim_class_a=sim_store.class_a_requests,
        runtime_class_a=run_store.class_a_requests,
        sim_class_b=sim_store.class_b_requests,
        runtime_class_b=run_store.class_b_requests,
        sim_samples=[
            (
                s.epoch,
                s.node,
                s.samples,
                s.data_wait_seconds,
                s.allreduce_wait_seconds,
                s.allreduce_comm_seconds,
            )
            for s in sim_stats
        ],
        runtime_samples=[
            (
                s.epoch,
                s.node,
                s.samples,
                s.data_wait_seconds,
                s.allreduce_wait_seconds,
                s.allreduce_comm_seconds,
            )
            for s in run_stats
        ],
    )


def assert_parity(spec: DataPlaneSpec, epochs: int = 2) -> ParityReport:
    """Assert the two projections agree exactly; returns the report."""
    report = run_parity(spec, epochs=epochs)
    assert report.exact, report.describe()
    return report
