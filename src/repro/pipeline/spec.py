"""DataPlaneSpec: one declarative description of the DELI data plane.

The paper's §IV pipeline — bucket, node-local capped cache, pre-fetch
service, (PR 1's) cooperative peer tier — existed in this repo twice: once
as the discrete-event ``NodeSimulator`` and once as the threaded
``DeliLoader`` assembly, each hand-wired by every benchmark and example.
NoPFS (Dryden et al., "Clairvoyant Prefetching") demonstrates the right
shape: one pipeline description drives both the performance *model* and the
*execution*.  ``DataPlaneSpec`` is that description:

    spec = DataPlaneSpec(workload=MNIST.scaled(0.05), cache_items=512,
                         peer_cache=True)
    sim_stats, sim_store = spec.build_sim().run(epochs=2)
    with spec.build_runtime() as cluster:
        run_stats, run_store = cluster.run(epochs=2)

Both projections share the spec's sampler seeds, tier sizes, policy object
and calibrated models.  ``build_runtime()`` (no clock argument) assembles
the **lock-step runtime**: per-node virtual clocks, the deterministic
``repro.core.lockstep`` pre-fetch service, and an event-interleaved driver
that mirrors the simulator's cluster schedule step for step — so
``pipeline.parity.assert_parity`` proves the two projections agree
*exactly* (per-tier hits, Class A/B totals, per-sample data-wait), with
prefetching **enabled or not**.  Pass ``clock=RealClock(scale=...)`` for
the free-running threaded runtime (real worker threads racing the loop —
timing experiments, statistical agreement only).

See ``docs/ARCHITECTURE.md`` for the layer map and
``docs/PARITY.md`` for why parity is exact-by-construction.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import warnings as _warnings
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.bandwidth import (
    DEFAULT_BUCKET,
    DEFAULT_DISK,
    DEFAULT_NETWORK,
    DEFAULT_PIPELINE,
    DEFAULT_PROFILE,
    BucketModel,
    CollectiveModel,
    DiskModel,
    NetworkModel,
    NodeProfile,
    PipelineCostModel,
)
from repro.core.cache import CappedCache
from repro.core.clock import Clock, VirtualClock
from repro.core.dataset import CachingDataset
from repro.core.loader import DeliLoader
from repro.core.lockstep import (
    STEP_DONE,
    BucketedBatchComm,
    LockstepPrefetchService,
    SubstepAccess,
    drive_interleaved_epoch,
    peer_probe_payload,
)
from repro.core.policy import PrefetchConfig, validate_config_against_cache
from repro.core.prefetcher import PrefetchService
from repro.core.simulator import SimConfig, simulate_cluster
from repro.core.store import (
    FileSystemStore,
    SimulatedBucketStore,
    make_synthetic_payloads,
)
from repro.core.types import EpochStats, StoreStats
from repro.core.workloads import WorkloadSpec
from repro.distributed.peer_cache import PeerCacheRegistry, PeerStore
from repro.engine.kernels import DemandKernel
from repro.obs.events import CacheTracer, TraceRecorder
from repro.oracle import (
    AccessOracle,
    BeladyEviction,
    ClusterPlacementPlanner,
    RoundCostModel,
    make_planner_factory,
)
from repro.pipeline.tiers import DiskSourceTier


class DataPlaneConfigWarning(UserWarning):
    """A spec is internally consistent but encodes a configuration the
    paper's findings flag as wasteful (``repro.core.policy.
    validate_config_against_cache``) — surfaced at construction so spec
    users see it, instead of the warnings living unreachably in the pure
    logic layer (ISSUE 5 satellite)."""


@dataclasses.dataclass(frozen=True)
class DataPlaneSpec:
    """One experimental condition, declaratively.

    Core fields
    -----------
    workload: the dataset/cluster shape (``repro.core.workloads``).
    source: ``"bucket"`` (simulated GCS) or ``"disk"`` (the paper's
        local-disk baseline; materialized through ``FileSystemStore`` on
        the runtime path).
    cache_items: node-local capped cache size in samples; ``None`` = no
        cache, ``-1`` = unlimited.
    prefetch: a ``PrefetchConfig`` (``None`` = no pre-fetch service).
    sampler: a name resolved through ``repro.pipeline.registry``
        ("partition" = the paper's DistributedSampler semantics,
        "locality" = the cache-aware partitioner, "shared-shuffle" = every
        node streams the full dataset in its own order), so benchmark
        conditions can be declared entirely by name.
    peer_cache / replication_aware_eviction: PR 1's cooperative peer tier
        and its Hoard-style eviction guard.
    interleaved: cluster schedule fidelity.  ``True`` (default) runs both
        projections event-interleaved — peer lookups observe *mid-epoch*
        cache state; ``False`` keeps the legacy sequential node schedule
        (epoch-boundary snapshots) for A/B comparisons.
    sync: cluster synchronization schedule (ISSUE 4).  ``"epoch"``
        (default) barriers only at epoch boundaries; ``"batch"`` adds an
        allreduce barrier after every gradient batch — the data-parallel
        SGD schedule — with per-node blocked time accounted in
        ``EpochStats.allreduce_wait_seconds``.  Requires ``interleaved``.
    granularity: scheduler event unit.  ``"step"`` (default) = one event
        per sample access, probes observing cluster state at the step's
        start; ``"substep"`` = every virtual-time component is its own
        event, so peer probes evaluate at *arrival* time and prefetch
        rounds complete inside long bucket GETs.  Requires ``interleaved``.
    nodes: optional per-rank ``NodeProfile`` tuple (straggler scenarios):
        multiplicative compute/bandwidth slowdowns folded into each node's
        calibrated models on BOTH projections, so heterogeneous clusters
        stay inside the exact-parity domain.
    eviction: cache victim selection (ISSUE 5).  ``"fifo"`` (default) is
        the paper's capped-collection order; ``"belady"`` plugs
        farthest-future-use eviction (``repro.oracle.BeladyEviction``) —
        the offline-optimal policy, implementable because the seeded
        sampler's future order is known.  Needs a cache, bucket source.
    prefetch_policy: fetch-round planning (ISSUE 5).  ``"paper"`` (default)
        uses the ``prefetch`` knobs; ``"oracle"`` replaces them with the
        clairvoyant ``OraclePrefetchPlanner`` (deadline-ordered,
        capacity-windowed, residency-filtered rounds — leave
        ``prefetch=None``).  ``"cluster-oracle"`` (ISSUE 7) adds the
        cross-rank placement plan: one ``ClusterPlacementPlanner``
        partitions the union of access orders so each key is bucket-fetched
        by exactly ONE owner rank and served to everyone else over the peer
        tier (requires ``peer_cache`` and a replayable sampler).  All need
        a cache, bucket source, and the lock-step runtime (a free-running
        threaded service has no deterministic cursor for the oracle to
        trust).
    round_sizing: clairvoyant round sizing (ISSUE 7 satellite).  ``"ramp"``
        (default) = the historical doubling ramp, pinned byte-for-byte;
        ``"cost"`` = sizes solved against next-use deadlines from the
        calibrated bandwidth models (``repro.oracle.RoundCostModel``).

    Construction warns (``DataPlaneConfigWarning``) when the prefetch knobs
    are inconsistent with the cache size per the paper's findings —
    ``validate_config_against_cache`` surfaced at the spec layer.

    Construction helpers: ``from_sim_config`` lifts a legacy ``SimConfig``;
    ``repro.pipeline.condition(name, workload)`` builds registered
    conditions by name.
    """

    workload: WorkloadSpec
    source: str = "bucket"  # "bucket" | "disk"
    cache_items: Optional[int] = None  # None = no cache; -1 = unlimited
    prefetch: Optional[PrefetchConfig] = None  # None = no prefetching
    n_connections: int = 16
    streaming_insert: bool = False
    list_every_fetch: bool = True
    sampler: str = "partition"
    peer_cache: bool = False
    replication_aware_eviction: bool = False
    interleaved: bool = True
    sync: str = "epoch"  # "epoch" | "batch" (per-batch allreduce barriers)
    # Allreduce cost model (ISSUE 8): a CollectiveModel prices the
    # per-batch barrier's gradient transfer (ring/tree over the calibrated
    # NetworkModel, profile-scaled per rank) into
    # EpochStats.allreduce_comm_seconds.  None = instantaneous barrier.
    collective: Optional[CollectiveModel] = None
    # "none" charges the whole allreduce at the barrier; "buckets"
    # pipelines per-bucket allreduces against the remaining backprop spans
    # (the shared BucketedBatchComm generator) so only the exposed tail is
    # charged.  Needs `collective`.
    overlap: str = "none"  # "none" | "buckets"
    # Straggler mitigation (ISSUE 8): release barriers after n-k ranks
    # (slowest k drop their partial gradient), or let ranks run <= s
    # batches ahead (stale-synchronous).  Mutually exclusive; both need
    # sync="batch".  Validated once in SimConfig.__post_init__.
    backup_workers: int = 0
    staleness_bound: int = 0
    granularity: str = "step"  # "step" | "substep" (event decomposition)
    nodes: Optional[Tuple[NodeProfile, ...]] = None  # per-rank straggler profiles
    eviction: str = "fifo"  # "fifo" | "belady" (clairvoyant, ISSUE 5)
    prefetch_policy: str = "paper"  # "paper" | "oracle" | "cluster-oracle"
    round_sizing: str = "ramp"  # "ramp" | "cost" (clairvoyant sizing, ISSUE 7)
    # Execution engine (ISSUE 6): "scalar" = one-event-per-sample stepping;
    # "vector" = repro.engine.vector's segment batcher (numpy array ops
    # between cross-node interaction points; exact ``==`` results).
    # Validated ONCE in SimConfig.__post_init__ (rides the to_sim_config()
    # call below); the free-running threaded runtime rejects "vector"
    # loudly in RuntimeCluster.__init__.
    engine: str = "scalar"  # "scalar" | "vector"
    seed: int = 0
    # Calibrated models (Table I defaults; override for fast-forwarded runs).
    bucket: BucketModel = DEFAULT_BUCKET
    disk: DiskModel = DEFAULT_DISK
    pipeline_model: PipelineCostModel = DEFAULT_PIPELINE
    network: NetworkModel = DEFAULT_NETWORK
    # Runtime payload source; None = index-tagged synthetic bytes of the
    # workload's sample size.  (The simulator never materializes payloads.)
    payload_factory: Optional[Callable[["DataPlaneSpec"], Dict[int, bytes]]] = None
    # Flight recorder (ISSUE 10): a TraceRecorder observing whichever
    # projection is built from this spec.  Observe-only — ``None`` (the
    # default) leaves every stat, schedule and parity fingerprint
    # byte-identical — and excluded from ``label()``: tracing is not an
    # experimental condition.  Lock-step only (virtual time); the
    # free-running threaded runtime rejects it loudly.
    trace: Optional[TraceRecorder] = None

    def __post_init__(self) -> None:
        if self.source not in ("bucket", "disk"):
            raise ValueError(f"unknown source {self.source!r}")
        if self.peer_cache and self.cache_items is None:
            raise ValueError("peer_cache requires a local cache (cache_items)")
        if self.replication_aware_eviction and not self.peer_cache:
            raise ValueError("replication_aware_eviction requires peer_cache")
        if self.cache_items is not None and self.cache_items != -1 and self.cache_items <= 0:
            raise ValueError("cache_items must be positive, -1 (unlimited) or None")
        if self.sync not in ("epoch", "batch"):
            raise ValueError(f"unknown sync {self.sync!r}")
        if self.granularity not in ("step", "substep"):
            raise ValueError(f"unknown granularity {self.granularity!r}")
        if self.sync == "batch" and not self.interleaved:
            raise ValueError("sync='batch' requires the interleaved schedule")
        if self.granularity == "substep" and not self.interleaved:
            raise ValueError("granularity='substep' requires the interleaved schedule")
        # Eviction / prefetch-policy rules (unknown values, belady/oracle
        # need a cache and the bucket source, the oracle has no knobs) live
        # ONCE in SimConfig.__post_init__; constructing the sim projection
        # validates them here too, so the two surfaces cannot drift.
        self.to_sim_config()
        # ISSUE 5 satellite: the pure-logic configuration lint
        # (core/policy.py) fires at spec construction, where users actually
        # are.  The spec's cache_items is authoritative for the check.
        if self.prefetch is not None and self.prefetch.enabled:
            check_cfg = self.prefetch
            if isinstance(self.cache_items, int) and self.cache_items > 0:
                check_cfg = dataclasses.replace(
                    check_cfg, cache_items=self.cache_items
                )
            for msg in validate_config_against_cache(check_cfg):
                _warnings.warn(msg, DataPlaneConfigWarning, stacklevel=3)
        if self.nodes is not None:
            if not isinstance(self.nodes, tuple):
                object.__setattr__(self, "nodes", tuple(self.nodes))
            if len(self.nodes) != self.workload.n_nodes:
                raise ValueError(
                    f"nodes has {len(self.nodes)} profiles for "
                    f"{self.workload.n_nodes} ranks"
                )

    def profile(self, rank: int) -> NodeProfile:
        """Rank ``rank``'s heterogeneity profile (default: homogeneous)."""
        return self.nodes[rank] if self.nodes is not None else DEFAULT_PROFILE

    # -- naming ---------------------------------------------------------------
    def label(self) -> str:
        """Human-readable condition label (same scheme as ``SimConfig``)."""
        base = self.to_sim_config().label()
        if self.nodes is not None and any(p != DEFAULT_PROFILE for p in self.nodes):
            base += "+straggler"
        return base

    # -- projections ----------------------------------------------------------
    def to_sim_config(self) -> SimConfig:
        """The simulator's view of this spec."""
        return SimConfig(
            source=self.source,
            cache_items=self.cache_items,
            prefetch=self.prefetch,
            n_connections=self.n_connections,
            streaming_insert=self.streaming_insert,
            list_every_fetch=self.list_every_fetch,
            locality_aware=self.sampler == "locality",
            peer_cache=self.peer_cache,
            replication_aware_eviction=self.replication_aware_eviction,
            sync=self.sync,
            collective=self.collective,
            overlap=self.overlap,
            backup_workers=self.backup_workers,
            staleness_bound=self.staleness_bound,
            granularity=self.granularity,
            eviction=self.eviction,
            prefetch_policy=self.prefetch_policy,
            round_sizing=self.round_sizing,
            engine=self.engine,
            trace=self.trace,
        )

    @classmethod
    def from_sim_config(
        cls, workload: WorkloadSpec, cfg: SimConfig, seed: int = 0, **overrides
    ) -> "DataPlaneSpec":
        """Lift a legacy ``SimConfig`` into a spec (benchmark migration)."""
        return cls(
            workload=workload,
            source=cfg.source,
            cache_items=cfg.cache_items,
            prefetch=cfg.prefetch,
            n_connections=cfg.n_connections,
            streaming_insert=cfg.streaming_insert,
            list_every_fetch=cfg.list_every_fetch,
            sampler="locality" if cfg.locality_aware else "partition",
            peer_cache=cfg.peer_cache,
            replication_aware_eviction=cfg.replication_aware_eviction,
            sync=cfg.sync,
            collective=cfg.collective,
            overlap=cfg.overlap,
            backup_workers=cfg.backup_workers,
            staleness_bound=cfg.staleness_bound,
            granularity=cfg.granularity,
            eviction=cfg.eviction,
            prefetch_policy=cfg.prefetch_policy,
            round_sizing=cfg.round_sizing,
            engine=cfg.engine,
            seed=seed,
            **{"trace": cfg.trace, **overrides},
        )

    def build_samplers(self) -> List:
        """One registry-built sampler per rank — the *same* construction on
        both projections, so sample orders agree verbatim."""
        from repro.pipeline.registry import make_sampler  # lazy: registry imports spec

        w = self.workload
        return [
            make_sampler(
                self.sampler,
                n_samples=w.n_samples,
                rank=rank,
                world=w.n_nodes,
                seed=self.seed,
                peer_aware=self.peer_cache,
            )
            for rank in range(w.n_nodes)
        ]

    def build_sim(self) -> "SimCluster":
        """The discrete-event projection (virtual time, no threads)."""
        return SimCluster(self)

    def build_runtime(self, clock: Optional[Clock] = None) -> "RuntimeCluster":
        """The runtime projection (real stores, loaders, services).

        With no ``clock`` (default) this is the **lock-step runtime**:
        per-node ``VirtualClock``s, the deterministic lock-step pre-fetch
        service, and modelled training-loop costs — exactly parity-
        comparable to ``build_sim()``.  Pass a clock (e.g.
        ``RealClock(scale=...)``) for the free-running threaded runtime
        (one shared clock, real worker threads, timing races).
        """
        return RuntimeCluster(self, clock=clock)

    def build_payloads(self) -> Dict[int, bytes]:
        """The runtime's payload map (synthetic unless ``payload_factory``)."""
        if self.payload_factory is not None:
            return self.payload_factory(self)
        return make_synthetic_payloads(
            self.workload.n_samples, self.workload.sample_bytes, seed=self.seed
        )


class SimCluster:
    """``DataPlaneSpec`` -> discrete-event cluster simulation."""

    def __init__(self, spec: DataPlaneSpec):
        self.spec = spec
        self.config = spec.to_sim_config()

    def run(self, epochs: int = 2) -> Tuple[List[EpochStats], StoreStats]:
        """Simulate every node for N epochs; returns per-node per-epoch
        stats (rank order within each epoch) + aggregate store accounting."""
        return simulate_cluster(
            self.spec.workload,
            self.config,
            epochs=epochs,
            seed=self.spec.seed,
            bucket=self.spec.bucket,
            disk=self.spec.disk,
            pipeline=self.spec.pipeline_model,
            network=self.spec.network,
            interleaved=self.spec.interleaved,
            samplers=self.spec.build_samplers(),
            profiles=[self.spec.profile(r) for r in range(self.spec.workload.n_nodes)],
        )


class RuntimeCluster:
    """``DataPlaneSpec`` -> per-node real pipelines over one dataset.

    Mirrors ``simulate_cluster``'s structure: one (store, cache, dataset,
    sampler, loader[, service]) per node, all caches joined to one
    ``PeerCacheRegistry`` when the spec asks for the peer tier.

    Two modes:

    * **Lock-step** (``clock=None``, the default): each node gets its own
      ``VirtualClock`` and — when prefetching — a deterministic
      ``LockstepPrefetchService`` whose completions are virtual-time
      events.  ``run`` drives the loaders sample-by-sample with the same
      event-interleaved schedule (or the legacy sequential schedule, per
      ``spec.interleaved``) and the same modelled loop costs as the
      simulator, so both projections produce *identical* accounting
      (``pipeline.parity``).
    * **Free-running** (explicit ``clock``): the original threaded
      assembly — a shared clock, a real ``PrefetchService`` worker thread
      per node, epochs driven rank-by-rank.  Timing races are real;
      agreement with the simulator is statistical.

    The disk source materializes the dataset into a temporary directory
    through ``FileSystemStore`` (cleaned up by ``close``); disk conditions
    have no cache/prefetch/peer tier on either projection, mirroring the
    paper's baseline.
    """

    def __init__(self, spec: DataPlaneSpec, clock: Optional[Clock] = None):
        self.spec = spec
        self.lockstep = clock is None
        if not self.lockstep and (spec.sync != "epoch" or spec.granularity != "step"):
            # Restrict the domain loudly (docs/PARITY.md policy): a
            # free-running threaded cluster has no deterministic event
            # order to park at a batch barrier or to split into sub-steps —
            # silently ignoring the knobs would report allreduce_wait == 0
            # for a schedule the caller explicitly asked for.
            raise ValueError(
                "sync='batch' / granularity='substep' need the lock-step "
                "runtime (build_runtime() with no clock); the free-running "
                "threaded mode cannot implement them"
            )
        if not self.lockstep and (
            spec.eviction == "belady" or spec.prefetch_policy != "paper"
        ):
            # Same policy for the oracle data plane: the clairvoyant cursor
            # advances with the deterministic event schedule; a worker
            # thread racing the loop would make Belady/oracle decisions
            # nondeterministic — restrict loudly rather than approximate.
            raise ValueError(
                "eviction='belady' / prefetch_policy='oracle' need the "
                "lock-step runtime (build_runtime() with no clock)"
            )
        if not self.lockstep and spec.engine == "vector":
            # Same loud-restriction policy (ISSUE 6): the vector engine
            # batches virtual-time segments; a free-running threaded
            # cluster has no virtual segments to batch, and silently
            # running scalar would misreport which engine produced the
            # numbers.
            raise ValueError(
                "engine='vector' is a simulator/lock-step engine; the "
                "free-running threaded runtime (explicit clock) cannot use "
                "it — pass engine='scalar' or drop the clock"
            )
        if not self.lockstep and spec.trace is not None:
            # The flight recorder records *virtual* times; a free-running
            # threaded cluster has only wall-clock races to offer, and a
            # silently wall-clock trace would masquerade as comparable to
            # the simulator's (docs/OBSERVABILITY.md).
            raise ValueError(
                "trace= needs the lock-step runtime (build_runtime() with "
                "no clock); the free-running threaded mode has no virtual "
                "timeline to record"
            )
        self.trace = spec.trace
        w = spec.workload
        # Per-node clocks: fresh VirtualClocks in lock-step mode, the one
        # shared clock in free-running mode.
        self.clock: Optional[Clock] = clock
        self.clocks: List[Clock] = [
            VirtualClock() if self.lockstep else clock for _ in range(w.n_nodes)
        ]
        payloads = spec.build_payloads()
        self._payloads = payloads
        self._disk_root: Optional[str] = None
        prefetch_on = spec.source == "bucket" and (
            (spec.prefetch is not None and spec.prefetch.enabled)
            or spec.prefetch_policy in ("oracle", "cluster-oracle")
        )
        self.registry: Optional[PeerCacheRegistry] = (
            PeerCacheRegistry(replication_aware=spec.replication_aware_eviction)
            if spec.peer_cache and spec.source == "bucket"
            else None
        )
        self.buckets: List[SimulatedBucketStore] = []
        self.disks: List[FileSystemStore] = []
        self.caches: List[Optional[CappedCache]] = []
        self.samplers: List = spec.build_samplers()
        # Clairvoyant views (ISSUE 5): the same AccessOracle construction
        # simulate_cluster performs over its identically-built samplers.
        self.oracle: Optional[AccessOracle] = (
            AccessOracle(self.samplers)
            if spec.eviction == "belady"
            or spec.prefetch_policy in ("oracle", "cluster-oracle")
            else None
        )
        # The cross-rank ownership plan (ISSUE 7): ONE planner over these
        # samplers, mirroring simulate_cluster's construction over its
        # identically-built samplers — the partitions match exactly.
        self.placement: Optional[ClusterPlacementPlanner] = (
            ClusterPlacementPlanner(self.samplers)
            if spec.prefetch_policy == "cluster-oracle"
            else None
        )
        self.services: List = []
        self.loaders: List[DeliLoader] = []
        # Per-node straggler-scaled models and modelled loop costs: the same
        # NodeProfile methods the simulator applies, over the same base
        # models, so heterogeneous timelines stay bit-identical.
        self.pipelines: List[PipelineCostModel] = []
        self.computes: List[float] = []
        self.substeps: List[Optional[SubstepAccess]] = []
        # Allreduce cost (ISSUE 8): per-rank full-gradient durations over
        # the profile-scaled networks, and the per-rank bucketed overlap
        # pipelines — the same construction NodeSimulator.__init__ performs
        # from its identically-scaled models.
        self.allreduces: List[float] = []
        self.overlaps: List[Optional[BucketedBatchComm]] = []
        if spec.source == "disk":
            # Materialize the dataset once; every node reads the same files
            # (the paper's disk baseline: data staged on each VM's disk).
            self._disk_root = tempfile.mkdtemp(prefix="deli-disk-")
            FileSystemStore.write_dataset(self._disk_root, payloads)
        for rank in range(w.n_nodes):
            node_clock = self.clocks[rank]
            prof = spec.profile(rank)
            node_bucket_model = prof.scale_bucket(spec.bucket)
            node_network = prof.scale_network(spec.network)
            node_pipeline = prof.scale_pipeline(spec.pipeline_model)
            self.pipelines.append(node_pipeline)
            self.computes.append(prof.batch_compute_s(w.compute_per_batch_s))
            allreduce_s = 0.0
            overlap_pipe: Optional[BucketedBatchComm] = None
            if spec.collective is not None:
                allreduce_s = spec.collective.allreduce_seconds(
                    node_network, w.n_nodes
                )
                if spec.overlap == "buckets":
                    # parity-mirror: overlap-build begin mode=call-shape callee=BucketedBatchComm
                    overlap_pipe = BucketedBatchComm(
                        now=node_clock.now,
                        charge=node_clock.sleep,
                        compute_span_s=self.computes[rank]
                        / spec.collective.n_buckets,
                        bucket_comm_s=spec.collective.bucket_seconds(
                            node_network, w.n_nodes
                        ),
                        n_buckets=spec.collective.n_buckets,
                        node=rank,
                        trace=self.trace,
                    )
                    # parity-mirror: overlap-build end
            self.allreduces.append(allreduce_s)
            self.overlaps.append(overlap_pipe)
            bucket: Optional[SimulatedBucketStore] = None
            if spec.source == "disk":
                disk_store = FileSystemStore(
                    self._disk_root,
                    model=prof.scale_disk(spec.disk),
                    clock=node_clock,
                    simulate_timing=True,
                )
                self.disks.append(disk_store)
                # Disk baseline: no cache tier at all (mirrors the
                # simulator), so the stack is the bare disk-source tier.
                dataset = CachingDataset(
                    disk_store, None, tiers=[DiskSourceTier(disk_store)]
                )
                cache = None
                service = None
            else:
                bucket = SimulatedBucketStore(
                    payloads, model=node_bucket_model, clock=node_clock
                )
                self.buckets.append(bucket)
                cache = None
                if spec.cache_items is not None:
                    max_items = None if spec.cache_items == -1 else spec.cache_items
                    cache = CappedCache(
                        max_items=max_items,
                        eviction_policy=(
                            BeladyEviction(self.oracle.view(rank))
                            if spec.eviction == "belady"
                            else None
                        ),
                    )
                    if self.trace is not None:
                        # Dedicated trace-listener slot: inserts/evictions
                        # recorded at this rank's clock (or the pinned
                        # round-completion time during pre-fetch folds) —
                        # the same wiring NodeSimulator.__init__ performs.
                        tracer = CacheTracer(
                            self.trace,
                            rank,
                            now=node_clock.now,
                            policy=cache.eviction_policy.name,
                        )
                        cache.set_trace_listener(tracer.on_insert, tracer.on_evict)
                store = bucket
                if self.registry is not None:
                    assert cache is not None  # enforced by spec validation
                    self.registry.register(rank, cache)
                    store = PeerStore(
                        bucket,
                        self.registry,
                        node=rank,
                        network=node_network,
                        clock=node_clock,
                    )
                dataset = CachingDataset(store, cache, insert_on_miss=not prefetch_on)
                service = None
                if prefetch_on:
                    if cache is None:
                        raise ValueError("prefetching requires a cache (cache_items)")
                    if self.lockstep:
                        service = LockstepPrefetchService(
                            cache,
                            sample_bytes=w.sample_bytes,
                            n_samples=w.n_samples,
                            bucket=node_bucket_model,
                            network=node_network,
                            store_stats=bucket.stats,
                            n_connections=spec.n_connections,
                            list_every_fetch=spec.list_every_fetch,
                            streaming_insert=spec.streaming_insert,
                            payload_for=payloads.__getitem__,
                            clock=node_clock,
                            registry=self.registry,
                            node_id=rank,
                            trace=self.trace,
                        )
                    else:
                        service = PrefetchService(
                            store,
                            cache,
                            n_connections=spec.n_connections,
                            clock=node_clock,
                            list_every_fetch=spec.list_every_fetch,
                            streaming_insert=spec.streaming_insert,
                        )
            planner_factory = None
            if prefetch_on and spec.prefetch_policy in ("oracle", "cluster-oracle"):
                assert cache is not None  # enforced by spec validation
                # THE shared planner construction (repro.oracle.planner) —
                # NodeSimulator.begin_epoch builds through the same call,
                # including the cost model (same profile-scaled inputs) and
                # the shared placement plan.
                planner_factory = make_planner_factory(
                    policy=spec.prefetch_policy,
                    config=None,
                    capacity=spec.cache_items,
                    resident=cache.contains,
                    sizing=spec.round_sizing,
                    cost_model=(
                        RoundCostModel.from_models(
                            bucket=node_bucket_model,
                            pipeline=node_pipeline,
                            sample_bytes=w.sample_bytes,
                            n_connections=spec.n_connections,
                        )
                        if spec.round_sizing == "cost"
                        else None
                    ),
                    placement=self.placement,
                    rank=rank,
                )
            loader = DeliLoader(
                dataset,
                self.samplers[rank],
                batch_size=w.batch_size,
                config=(
                    spec.prefetch
                    if prefetch_on and spec.prefetch is not None
                    else PrefetchConfig.disabled()
                ),
                service=service,
                clock=node_clock,
                node=rank,
                planner_factory=planner_factory,
                oracle_view=(
                    self.oracle.view(rank) if self.oracle is not None else None
                ),
                trace=self.trace,
            )
            self.caches.append(cache)
            self.services.append(service)
            self.loaders.append(loader)
            self.substeps.append(
                self._build_substep(
                    rank,
                    cache,
                    service,
                    bucket,
                    node_clock,
                    node_bucket_model,
                    node_network,
                    node_pipeline,
                    insert_on_miss=not prefetch_on,
                )
                if self.lockstep
                else None
            )

    def _build_substep(
        self,
        rank: int,
        cache: Optional[CappedCache],
        service,
        bucket: Optional[SimulatedBucketStore],
        clock: Clock,
        bucket_model: BucketModel,
        network: NetworkModel,
        pipeline: PipelineCostModel,
        insert_on_miss: bool,
    ) -> Optional[SubstepAccess]:
        """This node's sub-step demand-read machine (``granularity=
        "substep"``), mirroring ``NodeSimulator._build_substep`` closure
        for closure — with real payload bytes and billing routed to the
        node's bucket store.  Cache-less and disk-source modes keep the
        step schedule (nothing a peer could observe mid-access)."""
        if (
            self.spec.granularity != "substep"
            or self.spec.source == "disk"
            or cache is None
        ):
            return None
        assert bucket is not None

        def bucket_read(idx: int) -> bytes:
            # The demand-path Class B GET, billed at issue; the GET's
            # duration is charged by the shared machine so the payload
            # lands — and the insert event fires — at its true virtual
            # time instead of atomically with the probe.
            payload = self._payloads[idx]
            bucket._account(b=1, nbytes=len(payload))
            return payload

        fold_own = (
            (lambda: service.advance_to(clock.now()))
            if service is not None
            else (lambda: None)
        )
        peer_lookup = None
        if self.registry is not None:
            peer_lookup = lambda idx: peer_probe_payload(  # noqa: E731
                self.registry, rank, idx
            )
        # parity-mirror: substep-build begin mode=call-shape callee=SubstepAccess
        return SubstepAccess(
            now=clock.now,
            charge=clock.sleep,
            fold_own=fold_own,
            local_lookup=cache.get,
            peer_lookup=peer_lookup,
            bucket_read=bucket_read,
            insert=cache.put,
            # The same kernel construction NodeSimulator performs from ITS
            # profile-scaled models — same inputs, same precomputed floats.
            kernel=DemandKernel.from_models(
                bucket=bucket_model,
                network=network,
                pipeline=pipeline,
                sample_bytes=self.spec.workload.sample_bytes,
            ),
            insert_on_miss=insert_on_miss,
            node=rank,
            trace=self.trace,
        )
        # parity-mirror: substep-build end

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        for svc in self.services:
            if svc is not None:
                svc.close()
        if self._disk_root is not None:
            shutil.rmtree(self._disk_root, ignore_errors=True)
            self._disk_root = None

    def __enter__(self) -> "RuntimeCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- driving --------------------------------------------------------------
    def _update_locality_views(self) -> None:
        if self.spec.sampler != "locality":
            return
        if self.registry is not None:
            views = self.registry.cache_views()
        else:
            views = [c.keys() if c else [] for c in self.caches]
        for s in self.samplers:
            s.update_cache_views(views)

    def _run_lockstep(self, epochs: int) -> List[EpochStats]:
        """Event-granular deterministic drive, mirroring the simulator's
        cluster schedule exactly: the same event heap (interleaved) or the
        same rank-sequential order, the same fold-before-step completion
        barriers, the same per-batch allreduce barriers (``sync="batch"``),
        the same BSP epoch barrier."""
        w = self.spec.workload
        all_stats: List[EpochStats] = []
        for e in range(epochs):
            self._update_locality_views()
            steppers = []
            for rank, loader in enumerate(self.loaders):
                loader.set_epoch(e)
                steppers.append(
                    loader.step_epoch(
                        pipeline_model=self.pipelines[rank],
                        compute_per_batch_s=self.computes[rank],
                        substep=self.substeps[rank],
                        overlap=self.overlaps[rank],
                    )
                )
            if self.spec.interleaved:
                # The one shared schedule implementation
                # (repro.core.lockstep.drive_interleaved_epoch) — the same
                # heap/fold/barrier code the simulator runs.

                def _fold_all(t: float) -> None:
                    for svc in self.services:  # completion events <= t are
                        if svc is not None:  # visible to every node
                            svc.advance_to(t)

                def _barrier(t: float) -> None:
                    for rank, c in enumerate(self.clocks):
                        if self.spec.sync == "batch":
                            # Epoch-end allreduce: wait accounted, exactly
                            # like NodeSimulator.sync_to.
                            self.loaders[rank].sync_to(t)
                        else:
                            c.advance_to(t)

                def _batch_barrier(t: float, ranks: Tuple[int, ...]) -> None:
                    # Mirror of simulate_cluster's barrier: with a
                    # collective model and no overlap, the barrier carries
                    # the slowest participant's transfer duration; overlap
                    # specs charged their exposed comm inside the batch.
                    comm = 0.0
                    if (
                        self.spec.collective is not None
                        and self.spec.overlap == "none"
                    ):
                        comm = max(self.allreduces[r] for r in ranks)
                    for r in ranks:
                        self.loaders[r].sync_to(t, comm)

                drive_interleaved_epoch(
                    w.n_nodes,
                    now=lambda rank: self.clocks[rank].now(),
                    fold_all=_fold_all,
                    step=lambda rank: next(steppers[rank], STEP_DONE),
                    barrier=_barrier,
                    sync=self.spec.sync,
                    batch_barrier=(
                        _batch_barrier if self.spec.sync == "batch" else None
                    ),
                    backup_workers=self.spec.backup_workers,
                    staleness_bound=self.spec.staleness_bound,
                    trace=self.trace,
                )
            else:
                for stepper in steppers:
                    for _ in stepper:
                        pass
            for loader in self.loaders:
                assert loader.last_epoch_stats is not None
                all_stats.append(loader.last_epoch_stats)
        return all_stats

    def _run_threaded(self, epochs: int, compute: bool) -> List[EpochStats]:
        """Free-running drive (epoch-outer, rank-inner, real services)."""
        all_stats: List[EpochStats] = []
        for e in range(epochs):
            self._update_locality_views()
            for rank, loader in enumerate(self.loaders):
                loader.set_epoch(e)
                for _ in loader:
                    if compute:
                        assert self.clock is not None
                        self.clock.sleep(self.computes[rank])
                assert loader.last_epoch_stats is not None
                all_stats.append(loader.last_epoch_stats)
            for svc in self.services:
                if svc is not None:
                    svc.drain()
        return all_stats

    def run(
        self, epochs: int = 2, compute: bool = False
    ) -> Tuple[List[EpochStats], StoreStats]:
        """Drive every node for N epochs; returns per-node per-epoch stats
        (rank order within each epoch) plus the aggregate bucket request
        accounting.

        Lock-step mode always models per-batch compute and loop overheads
        (they shape the event schedule); free-running mode sleeps compute
        only when ``compute=True`` (legacy behaviour).
        """
        if self.lockstep:
            stats = self._run_lockstep(epochs)
        else:
            stats = self._run_threaded(epochs, compute)
        return stats, self.store_stats()

    def store_stats(self) -> StoreStats:
        """Aggregate *bucket* accounting (Class A/B, bytes).  Disk-source
        runs return zeros — local disk reads are not object-store requests
        (matching the simulator's disk baseline)."""
        agg = StoreStats()
        for bucket in self.buckets:
            agg = agg.merge(bucket.stats)
        return agg
