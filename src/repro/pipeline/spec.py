"""DataPlaneSpec: one declarative description of the DELI data plane.

The paper's §IV pipeline — bucket, node-local capped cache, pre-fetch
service, (PR 1's) cooperative peer tier — existed in this repo twice: once
as the discrete-event ``NodeSimulator`` and once as the threaded
``DeliLoader`` assembly, each hand-wired by every benchmark and example.
NoPFS (Dryden et al., "Clairvoyant Prefetching") demonstrates the right
shape: one pipeline description drives both the performance *model* and the
*execution*.  ``DataPlaneSpec`` is that description:

    spec = DataPlaneSpec(workload=MNIST.scaled(0.05), cache_items=512,
                         peer_cache=True)
    sim_stats, sim_store = spec.build_sim().run(epochs=2)
    with spec.build_runtime() as cluster:
        run_stats, run_store = cluster.run(epochs=2)

Both projections share the spec's sampler seeds, tier sizes, policy object
and calibrated models, so the parity harness (``repro.pipeline.parity``)
can assert they agree on a deterministic clock — the drift the ROADMAP's
"concurrent-node simulation" item warns about becomes a tested property
instead of a hope.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.bandwidth import (
    DEFAULT_BUCKET,
    DEFAULT_DISK,
    DEFAULT_NETWORK,
    DEFAULT_PIPELINE,
    BucketModel,
    DiskModel,
    NetworkModel,
    PipelineCostModel,
)
from repro.core.cache import CappedCache
from repro.core.clock import Clock, VirtualClock
from repro.core.dataset import CachingDataset
from repro.core.loader import DeliLoader
from repro.core.policy import PrefetchConfig
from repro.core.prefetcher import PrefetchService
from repro.core.simulator import SimConfig, simulate_cluster
from repro.core.store import SimulatedBucketStore, make_synthetic_payloads
from repro.core.types import EpochStats, StoreStats
from repro.core.workloads import WorkloadSpec
from repro.distributed.peer_cache import PeerCacheRegistry, PeerStore


@dataclasses.dataclass(frozen=True)
class DataPlaneSpec:
    """One experimental condition, declaratively.

    ``sampler`` is a name resolved through ``repro.pipeline.registry``
    ("partition" = the paper's DistributedSampler semantics, "locality" =
    the beyond-paper cache-aware partitioner), so benchmark conditions can
    be declared entirely by name.
    """

    workload: WorkloadSpec
    source: str = "bucket"  # "bucket" | "disk"
    cache_items: Optional[int] = None  # None = no cache; -1 = unlimited
    prefetch: Optional[PrefetchConfig] = None  # None = no prefetching
    n_connections: int = 16
    streaming_insert: bool = False
    list_every_fetch: bool = True
    sampler: str = "partition"
    peer_cache: bool = False
    replication_aware_eviction: bool = False
    seed: int = 0
    # Calibrated models (Table I defaults; override for fast-forwarded runs).
    bucket: BucketModel = DEFAULT_BUCKET
    disk: DiskModel = DEFAULT_DISK
    pipeline_model: PipelineCostModel = DEFAULT_PIPELINE
    network: NetworkModel = DEFAULT_NETWORK
    # Runtime payload source; None = index-tagged synthetic bytes of the
    # workload's sample size.  (The simulator never materializes payloads.)
    payload_factory: Optional[Callable[["DataPlaneSpec"], Dict[int, bytes]]] = None

    def __post_init__(self) -> None:
        if self.source not in ("bucket", "disk"):
            raise ValueError(f"unknown source {self.source!r}")
        if self.peer_cache and self.cache_items is None:
            raise ValueError("peer_cache requires a local cache (cache_items)")
        if self.replication_aware_eviction and not self.peer_cache:
            raise ValueError("replication_aware_eviction requires peer_cache")
        if self.cache_items is not None and self.cache_items != -1 and self.cache_items <= 0:
            raise ValueError("cache_items must be positive, -1 (unlimited) or None")

    # -- naming ---------------------------------------------------------------
    def label(self) -> str:
        return self.to_sim_config().label()

    # -- projections ----------------------------------------------------------
    def to_sim_config(self) -> SimConfig:
        """The simulator's view of this spec."""
        return SimConfig(
            source=self.source,
            cache_items=self.cache_items,
            prefetch=self.prefetch,
            n_connections=self.n_connections,
            streaming_insert=self.streaming_insert,
            list_every_fetch=self.list_every_fetch,
            locality_aware=self.sampler == "locality",
            peer_cache=self.peer_cache,
            replication_aware_eviction=self.replication_aware_eviction,
        )

    @classmethod
    def from_sim_config(
        cls, workload: WorkloadSpec, cfg: SimConfig, seed: int = 0, **overrides
    ) -> "DataPlaneSpec":
        """Lift a legacy ``SimConfig`` into a spec (benchmark migration)."""
        return cls(
            workload=workload,
            source=cfg.source,
            cache_items=cfg.cache_items,
            prefetch=cfg.prefetch,
            n_connections=cfg.n_connections,
            streaming_insert=cfg.streaming_insert,
            list_every_fetch=cfg.list_every_fetch,
            sampler="locality" if cfg.locality_aware else "partition",
            peer_cache=cfg.peer_cache,
            replication_aware_eviction=cfg.replication_aware_eviction,
            seed=seed,
            **overrides,
        )

    def build_sim(self) -> "SimCluster":
        """The discrete-event projection (virtual time, no threads)."""
        return SimCluster(self)

    def build_runtime(self, clock: Optional[Clock] = None) -> "RuntimeCluster":
        """The threaded-runtime projection (real stores, loaders, services).

        Default clock is a ``VirtualClock`` so modelled I/O costs no wall
        time; pass ``RealClock(scale=...)`` for timing-race experiments.
        """
        return RuntimeCluster(self, clock=clock)

    def build_payloads(self) -> Dict[int, bytes]:
        if self.payload_factory is not None:
            return self.payload_factory(self)
        return make_synthetic_payloads(
            self.workload.n_samples, self.workload.sample_bytes, seed=self.seed
        )


class SimCluster:
    """``DataPlaneSpec`` -> discrete-event cluster simulation."""

    def __init__(self, spec: DataPlaneSpec):
        self.spec = spec
        self.config = spec.to_sim_config()

    def run(self, epochs: int = 2) -> Tuple[List[EpochStats], StoreStats]:
        return simulate_cluster(
            self.spec.workload,
            self.config,
            epochs=epochs,
            seed=self.spec.seed,
            bucket=self.spec.bucket,
            disk=self.spec.disk,
            pipeline=self.spec.pipeline_model,
            network=self.spec.network,
        )


class RuntimeCluster:
    """``DataPlaneSpec`` -> per-node threaded pipelines over one dataset.

    Mirrors ``simulate_cluster``'s structure: one (store, cache, dataset,
    sampler, loader[, service]) per node, all caches joined to one
    ``PeerCacheRegistry`` when the spec asks for the peer tier.  ``run``
    drives nodes' epochs in the same (epoch-outer, rank-inner) order as the
    simulator so cache/peer visibility matches and parity is well-defined.
    """

    def __init__(self, spec: DataPlaneSpec, clock: Optional[Clock] = None):
        if spec.source != "bucket":
            raise ValueError(
                "build_runtime supports the bucket source; the disk baseline "
                "is simulator-only (no local dataset files in this container)"
            )
        from repro.pipeline.registry import make_sampler  # lazy: registry imports spec

        self.spec = spec
        self.clock: Clock = clock if clock is not None else VirtualClock()
        w = spec.workload
        payloads = spec.build_payloads()
        prefetch_on = spec.prefetch is not None and spec.prefetch.enabled
        self.registry: Optional[PeerCacheRegistry] = (
            PeerCacheRegistry(replication_aware=spec.replication_aware_eviction)
            if spec.peer_cache
            else None
        )
        self.buckets: List[SimulatedBucketStore] = []
        self.caches: List[Optional[CappedCache]] = []
        self.samplers: List = []
        self.services: List[Optional[PrefetchService]] = []
        self.loaders: List[DeliLoader] = []
        for rank in range(w.n_nodes):
            bucket = SimulatedBucketStore(payloads, model=spec.bucket, clock=self.clock)
            cache: Optional[CappedCache] = None
            if spec.cache_items is not None:
                max_items = None if spec.cache_items == -1 else spec.cache_items
                cache = CappedCache(max_items=max_items)
            store = bucket
            if self.registry is not None:
                assert cache is not None  # enforced by spec validation
                self.registry.register(rank, cache)
                store = PeerStore(
                    bucket, self.registry, node=rank, network=spec.network, clock=self.clock
                )
            dataset = CachingDataset(store, cache, insert_on_miss=not prefetch_on)
            service = None
            if prefetch_on:
                if cache is None:
                    raise ValueError("prefetching requires a cache (cache_items)")
                service = PrefetchService(
                    store,
                    cache,
                    n_connections=spec.n_connections,
                    clock=self.clock,
                    list_every_fetch=spec.list_every_fetch,
                    streaming_insert=spec.streaming_insert,
                )
            sampler = make_sampler(
                spec.sampler,
                n_samples=w.n_samples,
                rank=rank,
                world=w.n_nodes,
                seed=spec.seed,
                peer_aware=spec.peer_cache,
            )
            loader = DeliLoader(
                dataset,
                sampler,
                batch_size=w.batch_size,
                config=spec.prefetch if prefetch_on else PrefetchConfig.disabled(),
                service=service,
                clock=self.clock,
                node=rank,
            )
            self.buckets.append(bucket)
            self.caches.append(cache)
            self.samplers.append(sampler)
            self.services.append(service)
            self.loaders.append(loader)

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        for svc in self.services:
            if svc is not None:
                svc.close()

    def __enter__(self) -> "RuntimeCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- driving --------------------------------------------------------------
    def _update_locality_views(self) -> None:
        if self.spec.sampler != "locality":
            return
        if self.registry is not None:
            views = self.registry.cache_views()
        else:
            views = [c.keys() if c else [] for c in self.caches]
        for s in self.samplers:
            s.update_cache_views(views)

    def run(
        self, epochs: int = 2, compute: bool = False
    ) -> Tuple[List[EpochStats], StoreStats]:
        """Drive every node for N epochs (epoch-outer, rank-inner, exactly
        like ``simulate_cluster``); returns per-node per-epoch stats plus
        the aggregate bucket request accounting."""
        w = self.spec.workload
        all_stats: List[EpochStats] = []
        for e in range(epochs):
            self._update_locality_views()
            for loader in self.loaders:
                loader.set_epoch(e)
                for _ in loader:
                    if compute:
                        self.clock.sleep(w.compute_per_batch_s)
                assert loader.last_epoch_stats is not None
                all_stats.append(loader.last_epoch_stats)
            for svc in self.services:
                if svc is not None:
                    svc.drain()
        return all_stats, self.store_stats()

    def store_stats(self) -> StoreStats:
        agg = StoreStats()
        for bucket in self.buckets:
            agg = agg.merge(bucket.stats)
        return agg
