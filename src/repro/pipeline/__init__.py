"""repro.pipeline — the declarative data-plane layer.

Two abstractions:

  * ``tiers``    — the composable read-tier stack (``ReadTier`` protocol,
    ``RamTier``/``DiskTier``/``PeerTier``/``BucketTier``/``DiskSourceTier``,
    ``TierStack``): the explicit form of the paper's layered read path,
    with per-tier attribution (``TierResult``) replacing ad-hoc
    duck-typing.
  * ``spec``     — ``DataPlaneSpec``: one declarative description of a data
    plane (store backend, tier sizes, prefetch policy, sampler, peer cache,
    cluster schedule) with ``build_sim()`` and ``build_runtime()``, so the
    discrete-event simulator and the (lock-step or threaded) runtime are
    two projections of the same object instead of two hand-synchronized
    assemblies.

Plus ``registry`` (named benchmark conditions / samplers) and ``parity``
(the sim-vs-runtime **exact** agreement harness — per-tier hits, Class A/B
totals and data-wait compared with ``==``; prefetch-enabled specs
included, see docs/PARITY.md).

Migrating from the seed-era constructors — old manual wiring vs the spec::

    old (hand-assembled)                      new (DataPlaneSpec)
    ----------------------------------------  -------------------------------
    SimulatedBucketStore(payloads, model,     spec = DataPlaneSpec(workload=,
        clock=...)                                bucket=model,
    CappedCache(max_items=N)                      cache_items=N,
    PrefetchConfig.fifty_fifty(N)                 prefetch=PrefetchConfig
    PrefetchService(store, cache, ...)                .fifty_fifty(N),
    CachingDataset(store, cache,                  payload_factory=...)
        insert_on_miss=...)
    DistributedPartitionSampler(n, r, w)      cluster = spec.build_runtime()
    DeliLoader(dataset, sampler, batch,       loader = cluster.loaders[rank]
        cfg, service, clock)
    # simulator: SimConfig(...) +             stats, store = spec.build_sim()
    #   simulate_cluster(spec, cfg)               .run(epochs=2)
    # peer tier: PeerCacheRegistry +          DataPlaneSpec(peer_cache=True)
    #   PeerStore(bucket, reg, node)
    # named conditions:                       pipeline.condition("cache+peer",
    #   (hand-rolled per benchmark)               workload, cache_items=512)

The old constructors still work (they are thin shims over the tier stack);
new code should declare a spec.  ``examples/quickstart.py`` is the
runnable version of this table.

``tiers`` is imported eagerly (it is a dependency of ``repro.core``'s
dataset/prefetcher); the spec layer is exposed lazily (PEP 562) because it
imports ``repro.core`` back — eager import here would cycle during
``repro.core`` initialization.
"""
from repro.pipeline.tiers import (  # noqa: F401
    LOCAL_TIERS,
    BucketTier,
    DiskSourceTier,
    DiskTier,
    PeerTier,
    RamTier,
    ReadTier,
    TierResult,
    TierStack,
    local_tiers_for_cache,
    tiers_for_store,
)

_SPEC_EXPORTS = ("DataPlaneSpec", "SimCluster", "RuntimeCluster")
_REGISTRY_EXPORTS = (
    "condition",
    "register_condition",
    "list_conditions",
    "make_sampler",
    "register_sampler",
    "list_samplers",
)
_PARITY_EXPORTS = ("ParityReport", "run_parity", "assert_parity")

__all__ = [
    "LOCAL_TIERS",
    "BucketTier",
    "DiskSourceTier",
    "DiskTier",
    "PeerTier",
    "RamTier",
    "ReadTier",
    "TierResult",
    "TierStack",
    "local_tiers_for_cache",
    "tiers_for_store",
    *_SPEC_EXPORTS,
    *_REGISTRY_EXPORTS,
    *_PARITY_EXPORTS,
]


def __getattr__(name):
    if name in _SPEC_EXPORTS:
        from repro.pipeline import spec

        return getattr(spec, name)
    if name in _REGISTRY_EXPORTS:
        from repro.pipeline import registry

        return getattr(registry, name)
    if name in _PARITY_EXPORTS:
        from repro.pipeline import parity

        return getattr(parity, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
