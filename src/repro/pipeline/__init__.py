"""repro.pipeline — the declarative data-plane layer.

Two abstractions (ISSUE 2 tentpole):

  * ``tiers``    — the composable read-tier stack (``ReadTier`` protocol,
    ``RamTier``/``DiskTier``/``PeerTier``/``BucketTier``, ``TierStack``):
    the explicit form of the paper's layered read path, with per-tier
    attribution (``TierResult``) replacing ad-hoc duck-typing.
  * ``spec``     — ``DataPlaneSpec``: one declarative description of a data
    plane (store backend, tier sizes, prefetch policy, sampler, peer cache,
    cluster shape) with ``build_sim()`` and ``build_runtime()``, so the
    discrete-event simulator and the threaded runtime are two projections
    of the same object instead of two hand-synchronized assemblies.

Plus ``registry`` (named benchmark conditions / samplers) and ``parity``
(the sim-vs-runtime agreement harness).

``tiers`` is imported eagerly (it is a dependency of ``repro.core``'s
dataset/prefetcher); the spec layer is exposed lazily (PEP 562) because it
imports ``repro.core`` back — eager import here would cycle during
``repro.core`` initialization.
"""
from repro.pipeline.tiers import (  # noqa: F401
    LOCAL_TIERS,
    BucketTier,
    DiskTier,
    PeerTier,
    RamTier,
    ReadTier,
    TierResult,
    TierStack,
    local_tiers_for_cache,
    tiers_for_store,
)

_SPEC_EXPORTS = ("DataPlaneSpec", "SimCluster", "RuntimeCluster")
_REGISTRY_EXPORTS = (
    "condition",
    "register_condition",
    "list_conditions",
    "make_sampler",
    "register_sampler",
    "list_samplers",
)
_PARITY_EXPORTS = ("ParityReport", "run_parity", "assert_parity")

__all__ = [
    "LOCAL_TIERS",
    "BucketTier",
    "DiskTier",
    "PeerTier",
    "RamTier",
    "ReadTier",
    "TierResult",
    "TierStack",
    "local_tiers_for_cache",
    "tiers_for_store",
    *_SPEC_EXPORTS,
    *_REGISTRY_EXPORTS,
    *_PARITY_EXPORTS,
]


def __getattr__(name):
    if name in _SPEC_EXPORTS:
        from repro.pipeline import spec

        return getattr(spec, name)
    if name in _REGISTRY_EXPORTS:
        from repro.pipeline import registry

        return getattr(registry, name)
    if name in _PARITY_EXPORTS:
        from repro.pipeline import parity

        return getattr(parity, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
