"""Assigned architecture configs (public literature) + reduced smoke variants.

Each ``<id>.py`` module exports ``CONFIG: ArchConfig`` with the exact
published numbers from the assignment table.  ``get(name)`` resolves ids,
``reduce_for_smoke(cfg)`` produces a tiny same-family config that runs a
real forward/train step on CPU.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

ARCH_IDS: List[str] = [
    "jamba-1.5-large-398b",
    "phi3.5-moe-42b-a6.6b",
    "dbrx-132b",
    "phi-3-vision-4.2b",
    "internlm2-20b",
    "h2o-danube-3-4b",
    "deepseek-coder-33b",
    "command-r-35b",
    "hubert-xlarge",
    "mamba2-130m",
]

_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "dbrx-132b": "dbrx_132b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "internlm2-20b": "internlm2_20b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "command-r-35b": "command_r_35b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-130m": "mamba2_130m",
}


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get(name) for name in ARCH_IDS}


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Same family / layer pattern, toy width — for CPU smoke tests."""
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, n_heads) if cfg.n_kv_heads < cfg.n_heads else n_heads
    head_dim = 16
    replace = dict(
        name=cfg.name + "-smoke",
        n_layers=2 * len(cfg.period),
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        attn_chunk=32,
        window=16 if cfg.window is not None else None,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8),
    )
    if cfg.n_experts:
        replace.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.has_ssm:
        replace.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    return dataclasses.replace(cfg, **replace)
