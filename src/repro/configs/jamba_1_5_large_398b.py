"""Jamba 1.5 Large (398B total / ~94B active) — hybrid Mamba+attention 1:7
interleave with MoE every other layer. [arXiv:2403.19887 / 2408.12570; hf]

Period of 8 layers: attention at position 4 (1:7 attn:mamba), channel mixers
alternate dense-MLP / MoE (16 experts, top-2).  The paper series uses
Mamba-1 mixers; our zoo implements the Mamba-2 (SSD) mixer — recorded as an
adaptation in DESIGN.md §7 (same state-space recurrence family, TPU-native
chunked form).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    period=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    mlp_pattern=("mlp", "moe", "mlp", "moe", "mlp", "moe", "mlp", "moe"),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
)
