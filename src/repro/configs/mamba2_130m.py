"""Mamba2-130M: attention-free SSD (state-space duality) stack.
24 layers, d_model 768, d_state 128, head_dim 64 (H=24), no MLP blocks,
tied embeddings. [arXiv:2405.21060; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    d_ff=0,
    vocab=50280,
    period=("ssm",),
    mlp_pattern=("none",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
)
