"""Phi-3-vision (4.2B): phi3-mini text backbone + CLIP frontend (stubbed —
``input_specs`` supplies precomputed patch embeddings that overwrite the
first ``n_frontend_tokens`` positions).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,  # MHA
    d_ff=8192,
    vocab=32064,
    frontend="patch",
    n_frontend_tokens=576,  # 24x24 CLIP patch grid
)
