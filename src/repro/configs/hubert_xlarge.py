"""HuBERT X-Large (~1B): bidirectional encoder-only audio transformer (same
arch as wav2vec2).  The conv feature extractor is stubbed — ``input_specs``
supplies precomputed frame embeddings; the head classifies each frame over
the 504-unit codebook.  No decode shapes (encoder). [arXiv:2106.07447]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,  # MHA
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend="frame",
    mlp_act="gelu",
)
