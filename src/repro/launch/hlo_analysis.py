"""Trip-count-aware HLO cost analysis.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits
every while-loop body ONCE — for a scan-over-layers model that undercounts
FLOPs, HBM bytes and collective bytes by the layer count (24-72x here).
This module re-derives all three from the post-SPMD HLO text
(``compiled.as_text()``), multiplying loop bodies by their trip counts:

  * FLOPs: dot ops (2*M*N*K from result shape x lhs contracting dims) +
    1 flop/element for float elementwise/reduce ops; descends into fusions,
    calls and while bodies (x trip count).
  * HBM bytes: a *kernel-level* traffic model — each scheduled op (fusion,
    dot, copy, ...) reads its operands and writes its result once; fusion
    internals are free (that is the TPU fusion model).  Loop bodies x trip.
  * Collectives: operand bytes of all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute, x enclosing trip counts.

Trip counts: scan conditions compare the induction variable against a
constant that lives in the condition computation (``constant(N)``); when a
condition passes its bound through the carry tuple instead, we fall back to
the modal leading dimension of the while carry's stacked tensors.

All numbers are PER DEVICE (the module is the partitioned one).
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_FLOAT_TYPES = ("bf16", "f16", "f32", "f64")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "tanh", "logistic", "negate", "abs", "select", "clamp", "cosine",
    "sine", "floor", "ceil", "round-nearest-afz", "sign", "atan2",
}
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id",
}
# Ops that force an HBM materialization on TPU.  Pure elementwise chains,
# broadcasts, selects, converts etc. fuse into their consumers on TPU — the
# CPU backend materializes every one of them, which would inflate the memory
# roofline term by the fusion factor (5-10x).  A fusion op counts iff its
# called computation contains at least one materializing op.
_MATERIALIZING = {
    "dot", "convolution", "reduce", "reduce-window", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "sort", "copy",
    "pad", "reverse", "slice", "rng", "rng-bit-generator", "custom-call",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "cumsum", "iota2",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _dims(dim_str: str) -> int:
    n = 1
    if dim_str:
        for d in dim_str.split(","):
            n *= int(d)
    return n


def _shapes_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(x) for x in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opname: str
    result_shapes: List[Tuple[str, List[int]]]
    operands: List[str]
    attrs: str
    operand_str: str = ""  # raw text inside the parens (constants keep values)

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.result_shapes)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, Op]


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _parse_op(line: str) -> Optional[Op]:
    m = _OP_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # rest = "<type> <opname>(<operands>), attrs..."
    paren = rest.find("(")
    # the type may itself be a tuple "(f32[..], ...)"; the opname is the last
    # token before the operand paren that is a word
    if rest.startswith("("):
        close = _match_paren(rest, 0)
        type_str = rest[: close + 1]
        tail = rest[close + 1 :].strip()
    else:
        sp = rest.find(" ")
        type_str = rest[:sp]
        tail = rest[sp + 1 :].strip()
    pm = re.match(r"([\w\-]+)\(", tail)
    if not pm:
        return None
    opname = pm.group(1)
    op_open = tail.find("(")
    op_close = _match_paren(tail, op_open)
    operand_str = tail[op_open + 1 : op_close]
    attrs = tail[op_close + 1 :]
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return Op(name, opname, _shapes_list(type_str), operands, attrs, operand_str)


def _match_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    current: Optional[Computation] = None
    comment = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        line = comment.sub("", line)
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line and "=" not in line.split("{")[0]:
            m = _HEADER_RE.match(line.strip())
            if m:
                current = Computation(m.group(1), [], {})
                comps[current.name] = current
                if line.startswith("ENTRY"):
                    entry = current.name
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            op = _parse_op(line)
            if op:
                current.ops.append(op)
                current.symtab[op.name] = op
    return comps, entry


def _attr_target(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _contracting_flops(op: Op, comp: Computation) -> int:
    res = 1
    for _, dims in op.result_shapes:
        for d in dims:
            res *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    k = 1
    if m and op.operands:
        lhs = comp.symtab.get(op.operands[0])
        if lhs is not None and lhs.result_shapes:
            dims = lhs.result_shapes[0][1]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2 * res * k


def _group_size(attrs: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return m.group(1).count(",") + 1
    return n_devices


class HloAnalyzer:
    def __init__(self, text: str, n_devices: int = 1):
        self.comps, self.entry = parse_module(text)
        self.n_devices = n_devices
        self._flops_cache: Dict[str, float] = {}
        self._bytes_cache: Dict[str, float] = {}
        self._trip_cache: Dict[str, int] = {}
        self.collectives: List[Dict] = []
        self.loop_trips: Dict[str, int] = {}
        self._walked = False

    # -- trip counts ---------------------------------------------------------
    def trip_count(self, op: Op) -> int:
        cond_name = _attr_target(op.attrs, "condition")
        if cond_name and cond_name in self._trip_cache:
            return self._trip_cache[cond_name]
        trip = 0
        if cond_name and cond_name in self.comps:
            consts = self._cond_constants(cond_name)
            if consts:
                trip = max(consts)
        if trip <= 0:
            # fallback: modal leading dim of stacked carry tensors
            lead = [
                dims[0]
                for _, dims in op.result_shapes
                if len(dims) >= 3 and dims[0] > 1
            ]
            if lead:
                trip = collections.Counter(lead).most_common(1)[0][0]
        if trip <= 0:
            trip = 1
        if cond_name:
            self._trip_cache[cond_name] = trip
        return trip

    def _cond_constants(self, comp_name: str) -> List[int]:
        """Integer constants declared in a loop-condition computation
        (``%c = s32[] constant(24)`` — the value is the operand text)."""
        out = []
        for o in self.comps[comp_name].ops:
            if (
                o.opname == "constant"
                and o.result_shapes
                and o.result_shapes[0][0].startswith(("s", "u"))
                and re.fullmatch(r"\d+", o.operand_str.strip())
            ):
                out.append(int(o.operand_str.strip()))
        return out

    # -- FLOPs ----------------------------------------------------------------
    def flops(self, comp_name: Optional[str] = None) -> float:
        comp_name = comp_name or self.entry
        if comp_name in self._flops_cache:
            return self._flops_cache[comp_name]
        comp = self.comps[comp_name]
        total = 0.0
        for op in comp.ops:
            if op.opname == "dot":
                total += _contracting_flops(op, comp)
            elif op.opname in _ELEMENTWISE:
                if op.result_shapes and op.result_shapes[0][0] in _FLOAT_TYPES:
                    total += _shape_bytes(op.result_shapes) / _DTYPE_BYTES[
                        op.result_shapes[0][0]
                    ]
            elif op.opname in ("reduce", "reduce-window"):
                if op.operands:
                    src = comp.symtab.get(op.operands[0])
                    if src:
                        total += src.result_bytes / max(
                            _DTYPE_BYTES.get(src.result_shapes[0][0], 4), 1
                        )
            elif op.opname == "fusion":
                tgt = _attr_target(op.attrs, "calls")
                if tgt in self.comps:
                    total += self.flops(tgt)
            elif op.opname == "while":
                body = _attr_target(op.attrs, "body")
                cond = _attr_target(op.attrs, "condition")
                trip = self.trip_count(op)
                inner = 0.0
                if body in self.comps:
                    inner += self.flops(body)
                if cond in self.comps:
                    inner += self.flops(cond)
                total += trip * inner
            elif op.opname in ("call", "custom-call", "conditional"):
                tgt = _attr_target(op.attrs, "to_apply")
                if tgt in self.comps:
                    total += self.flops(tgt)
        self._flops_cache[comp_name] = total
        return total

    # -- kernel-level HBM bytes (TPU fusion model) -----------------------------
    #
    # Traffic table: what each materializing op actually moves through HBM.
    # Slicing ops touch their WINDOW, not the buffer they slice from/into —
    # charging a dynamic-slice the whole 40-layer parameter stack it indexes
    # would inflate the memory term ~40x.  Elementwise ops (bare or as pure
    # elementwise fusions) are free: TPU fuses them into their consumers.
    def _op_traffic(self, op: Op, comp: Computation) -> float:
        def operand_bytes(i: int) -> int:
            if i < len(op.operands):
                src = comp.symtab.get(op.operands[i])
                if src is not None:
                    return src.result_bytes
            return 0

        kind = op.opname
        if kind in ("dynamic-slice", "slice", "gather", "copy", "pad",
                    "reverse", "concatenate", "sort", "transpose"):
            return 2 * op.result_bytes  # read window + write result
        if kind == "dynamic-update-slice":
            upd = operand_bytes(1)
            return 2 * (upd or op.result_bytes)  # read update + write window
        if kind == "scatter":
            upd = operand_bytes(2)
            return 2 * (upd or op.result_bytes)
        if kind in ("dot", "convolution", "custom-call"):
            return sum(operand_bytes(i) for i in range(len(op.operands))) + op.result_bytes
        if kind in ("reduce", "reduce-window", "cumsum"):
            return operand_bytes(0) + op.result_bytes
        if kind in ("rng", "rng-bit-generator", "iota2"):
            return op.result_bytes
        # collectives: local HBM side of the transfer
        return sum(operand_bytes(i) for i in range(len(op.operands))) + op.result_bytes

    def _fusion_traffic(self, comp_name: str) -> float:
        """Interior traffic of a fusion: sum of its materializing ops'
        window-based traffic (elementwise interior is fused, i.e. free)."""
        total = 0.0
        comp = self.comps[comp_name]
        for op in comp.ops:
            if op.opname == "fusion":
                tgt = _attr_target(op.attrs, "calls")
                if tgt in self.comps:
                    total += self._fusion_traffic(tgt)
            elif op.opname in _MATERIALIZING:
                total += self._op_traffic(op, comp)
        return total

    def hbm_bytes(self, comp_name: Optional[str] = None) -> float:
        comp_name = comp_name or self.entry
        if comp_name in self._bytes_cache:
            return self._bytes_cache[comp_name]
        comp = self.comps[comp_name]
        total = 0.0
        for op in comp.ops:
            if op.opname in _SKIP_BYTES:
                continue
            if op.opname == "while":
                body = _attr_target(op.attrs, "body")
                cond = _attr_target(op.attrs, "condition")
                trip = self.trip_count(op)
                inner = 0.0
                if body in self.comps:
                    inner += self.hbm_bytes(body)
                if cond in self.comps:
                    inner += self.hbm_bytes(cond)
                total += trip * inner
                continue
            if op.opname in ("call", "conditional"):
                tgt = _attr_target(op.attrs, "to_apply")
                if tgt in self.comps:
                    total += self.hbm_bytes(tgt)
                continue
            if op.opname == "fusion":
                tgt = _attr_target(op.attrs, "calls")
                if tgt in self.comps:
                    total += self._fusion_traffic(tgt)
                continue
            if op.opname not in _MATERIALIZING:
                continue  # bare elementwise op: fuses away on TPU
            total += self._op_traffic(op, comp)
        self._bytes_cache[comp_name] = total
        return total

    # -- collectives -----------------------------------------------------------
    def walk_collectives(self, comp_name: Optional[str] = None, mult: int = 1):
        comp_name = comp_name or self.entry
        comp = self.comps[comp_name]
        for op in comp.ops:
            base = op.opname.replace("-start", "")
            if base in _COLLECTIVES:
                g = _group_size(op.attrs, self.n_devices)
                rb = op.result_bytes
                if base == "all-gather":
                    ob = rb // max(g, 1)
                elif base == "reduce-scatter":
                    ob = rb * max(g, 1)
                else:
                    ob = rb
                self.collectives.append(
                    {"op": base, "operand_bytes": ob, "result_bytes": rb,
                     "group_size": g, "count": mult, "comp": comp_name}
                )
            elif op.opname == "while":
                body = _attr_target(op.attrs, "body")
                cond = _attr_target(op.attrs, "condition")
                trip = self.trip_count(op)
                self.loop_trips[op.name] = trip
                if body in self.comps:
                    self.walk_collectives(body, mult * trip)
                if cond in self.comps:
                    self.walk_collectives(cond, mult * trip)
            elif op.opname == "fusion":
                tgt = _attr_target(op.attrs, "calls")
                if tgt in self.comps:
                    self.walk_collectives(tgt, mult)
            elif op.opname in ("call", "conditional"):
                tgt = _attr_target(op.attrs, "to_apply")
                if tgt in self.comps:
                    self.walk_collectives(tgt, mult)

    def collective_bytes(self) -> float:
        if not self._walked:
            self.walk_collectives()
            self._walked = True
        return float(sum(c["operand_bytes"] * c["count"] for c in self.collectives))

    def collective_summary(self) -> Dict[str, Dict]:
        if not self._walked:
            self.walk_collectives()
            self._walked = True
        agg: Dict[str, Dict] = {}
        for c in self.collectives:
            a = agg.setdefault(c["op"], {"count": 0, "operand_bytes": 0})
            a["count"] += c["count"]
            a["operand_bytes"] += c["operand_bytes"] * c["count"]
        return agg
