"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before first jax init; tests and benches see the single real CPU device.

Production target: TPU v5e pods, 256 chips each.
  single pod:  (data=16, model=16)
  multi-pod:   (pod=2, data=16, model=16)

Hardware constants used by the roofline analysis live here too.
"""
from __future__ import annotations

import dataclasses

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """The mesh axes the global batch is sharded over (FSDP axes)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_smoke_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — integration tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_custom_mesh(n_data: int, n_model: int):
    """Arbitrary single-pod (data, model) split over 256 chips — the §Perf
    hillclimbing explores per-architecture mesh shapes (e.g. 32x8 when the
    head count doesn't divide 16, 256x1 pure-DP for sub-1B models)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e roofline constants (per chip)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16e9  # capacity


TPU_V5E = HardwareSpec()
