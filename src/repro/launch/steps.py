"""Step-function builders: the jit-able train / prefill / decode steps the
launcher, dry-run, and examples all share.

Each builder closes over (cfg, mesh-context, opt settings) and returns a
function of *arrays only*, so ``jax.jit(step).lower(*specs)`` works with
ShapeDtypeStruct stand-ins.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ShardingRules
from repro.models import model as M
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.moe import MeshContext
from repro.training.optimizer import OptSettings, adamw_update


def _fitting_batch_axes(rules: ShardingRules, global_batch: int):
    axes, b = [], global_batch
    for a in rules.fsdp_axes:
        n = rules.mesh.shape[a]
        if b % n == 0:
            axes.append(a)
            b //= n
    return tuple(axes)


def make_mesh_context(
    rules: Optional[ShardingRules], cfg: ArchConfig, global_batch: int
) -> Optional[MeshContext]:
    """MeshContext for the MoE shard_map path: only the fsdp axes that
    evenly divide the batch are used as batch axes (batch=1 long-context
    decode runs with a fully replicated token set inside the MoE)."""
    if rules is None or "moe" not in cfg.mlp_pattern:
        return None
    axes = _fitting_batch_axes(rules, global_batch)
    return MeshContext(rules.mesh, batch_axes=axes, model_axis=rules.model_axis)


def act_partition_spec(
    rules: Optional[ShardingRules], global_batch: int
) -> Optional[P]:
    """Activation spec (B, S, d): batch over the fitting fsdp axes."""
    if rules is None:
        return None
    axes = _fitting_batch_axes(rules, global_batch)
    return P(axes or None, None, None)


def auto_microbatches(
    cfg: ArchConfig, shape: ShapeConfig, rules: Optional[ShardingRules],
    act_budget_bytes: float = 3e9,
) -> int:
    """Gradient-accumulation factor so the per-device remat-saved activation
    carries (n_layers x microbatch_local x S x d x 2B) fit the budget."""
    if rules is None:
        return 1
    dp = 1
    b = shape.global_batch
    for a in rules.fsdp_axes:
        n = rules.mesh.shape[a]
        if b % n == 0:
            dp *= n
            b //= n
    local_b = shape.global_batch // dp
    per_layer = shape.seq_len * cfg.d_model * 2  # bf16 carry per sample
    n = 1
    while (
        n < local_b
        and local_b % (2 * n) == 0
        and cfg.n_layers * (local_b // n) * per_layer > act_budget_bytes
    ):
        n *= 2
    return n


def make_train_step(
    cfg: ArchConfig,
    settings: OptSettings,
    rules: Optional[ShardingRules] = None,
    global_batch: int = 0,
    remat_policy: str = "minimal",
    microbatches: int = 1,
):
    """fwd+bwd+AdamW.  ``microbatches`` > 1 accumulates gradients over a
    lax.scan of batch slices — per-step activation memory drops by the
    factor while arithmetic is unchanged (the standard way the 35-400B
    train_4k cells fit 16 GB/chip HBM)."""
    ctx = make_mesh_context(rules, cfg, global_batch)
    act = act_partition_spec(rules, global_batch)
    grad_fn = jax.value_and_grad(M.train_loss)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_fn(params, cfg, batch, ctx, act, remat_policy)
        else:
            def slice_batch(i):
                def f(x):
                    mb = x.shape[0] // microbatches
                    return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
                return jax.tree.map(f, batch)

            def body(carry, i):
                loss_acc, grads_acc = carry
                loss_i, grads_i = grad_fn(params, cfg, slice_batch(i), ctx, act, remat_policy)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, grads_i
                )
                return (loss_acc + loss_i, grads_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), jnp.arange(microbatches)
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state = adamw_update(params, grads, opt_state, settings)
        return loss, params, opt_state

    return train_step


def make_prefill_step(
    cfg: ArchConfig, rules: Optional[ShardingRules] = None, global_batch: int = 0
):
    ctx = make_mesh_context(rules, cfg, global_batch)
    act = act_partition_spec(rules, global_batch)

    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, ctx, act)

    return prefill_step


def make_decode_step(
    cfg: ArchConfig, rules: Optional[ShardingRules] = None, global_batch: int = 0
):
    ctx = make_mesh_context(rules, cfg, global_batch)
    act = act_partition_spec(rules, global_batch)

    def serve_step(params, state, tokens, cache_pos):
        return M.decode_step(params, cfg, tokens, state, cache_pos, ctx, act)

    return serve_step


def make_encoder_step(
    cfg: ArchConfig, rules: Optional[ShardingRules] = None, global_batch: int = 0
):
    """Encoder-only 'prefill': full forward + per-frame logits (no cache)."""
    ctx = make_mesh_context(rules, cfg, global_batch)
    act = act_partition_spec(rules, global_batch)

    def encode_step(params, batch):
        hidden, _ = M.forward(params, cfg, batch, ctx, remat=False, act_spec=act)
        return M.lm_head(params, cfg, hidden)

    return encode_step


def step_for_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    rules: Optional[ShardingRules] = None,
    settings: Optional[OptSettings] = None,
    microbatches: Optional[int] = None,
    remat_policy: str = "minimal",
):
    """(step_fn, takes_params_and_opt, microbatches) for one cell."""
    B = shape.global_batch
    if shape.kind == "train":
        settings = settings or OptSettings.auto(cfg.param_count())
        if microbatches is None:
            microbatches = auto_microbatches(cfg, shape, rules)
        return (
            make_train_step(
                cfg, settings, rules, B,
                remat_policy=remat_policy, microbatches=microbatches,
            ),
            True,
            microbatches,
        )
    if shape.kind == "prefill":
        if cfg.is_encoder:
            return make_encoder_step(cfg, rules, B), False, 1
        return make_prefill_step(cfg, rules, B), False, 1
    return make_decode_step(cfg, rules, B), False, 1
