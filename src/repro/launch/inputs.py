"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation.  The dry-run lowers against these.

Per the assignment:
  * train_4k / prefill_32k feed (tokens, labels) / (tokens,);
  * decode_32k / long_500k feed ONE new token + a decode state whose KV/SSM
    caches are sized for ``seq_len`` (they lower ``serve_step``);
  * [vlm] adds precomputed patch embeddings, [audio] replaces tokens with
    precomputed frame embeddings (modality frontends are stubs).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    ShardingRules,
    input_shardings,
    state_shardings,
)
from repro.models import model as M
from repro.models.config import ArchConfig, ShapeConfig


def _struct(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_structs(cfg: ArchConfig, shape: ShapeConfig, *, with_labels: bool) -> Dict:
    """Host-batch ShapeDtypeStructs (no shardings attached yet)."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict = {}
    if cfg.frontend == "frame":
        out["frame_embeds"] = _struct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = _struct((B, S), jnp.int32)
    if with_labels:
        out["labels"] = _struct((B, S), jnp.int32)
    if cfg.frontend == "patch":
        out["patch_embeds"] = _struct((B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return out


def input_specs(
    cfg: ArchConfig,
    shape: ShapeConfig,
    rules: Optional[ShardingRules] = None,
) -> Tuple:
    """The non-parameter inputs for this cell's step function, as sharded
    ShapeDtypeStructs, in the step's argument order.

    train:    (batch,)
    prefill:  (batch,)
    decode:   (state, tokens, cache_pos)
    """
    if shape.kind in ("train", "prefill"):
        batch = batch_structs(cfg, shape, with_labels=shape.kind == "train")
        if rules is not None:
            sh = input_shardings(rules, cfg, batch)
            batch = {k: _struct(v.shape, v.dtype, sh[k]) for k, v in batch.items()}
        return (batch,)

    # decode: one new token against a seq_len-sized cache
    B, S = shape.global_batch, shape.seq_len
    state_shapes = jax.eval_shape(lambda: M.init_decode_state(cfg, B, S))
    tokens = _struct((B, 1), jnp.int32)
    cache_pos = _struct((), jnp.int32)
    if rules is not None:
        csh, ksh = state_shardings(rules, cfg, state_shapes)
        caches = jax.tree.map(
            lambda l, s: _struct(l.shape, l.dtype, s), state_shapes[0], csh
        )
        kv_len = _struct((B,), jnp.int32, ksh)
        tok_sh = input_shardings(rules, cfg, {"t": tokens})["t"]
        tokens = _struct((B, 1), jnp.int32, tok_sh)
        state_shapes = (caches, kv_len)
    return (state_shapes, tokens, cache_pos)
