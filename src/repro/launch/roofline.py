"""Roofline analysis from a compiled dry-run artifact.

Three terms, reported in seconds per step (TPU v5e constants):

    compute    = HLO_FLOPs          / (chips * 197e12)
    memory     = HLO_bytes_accessed / (chips * 819e9)
    collective = collective_bytes   / (chips * 50e9)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective bytes
are NOT in cost_analysis: we parse the post-SPMD HLO (``compiled.as_text()``)
and sum *operand* bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, reconstructing operand size from the result
shape and the replica-group size where they differ (all-gather).

Also reported: MODEL_FLOPS (6·N_active·tokens for training, 2·N_active·tokens
for inference) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs, which
catches remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

from repro.launch.mesh import HardwareSpec, TPU_V5E
from repro.models.config import ArchConfig, ShapeConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

# result-type block at line start: "f32[1,2]{1,0}" or "(bf16[..], f32[..])"
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    """Replica-group size from either list or iota format."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota: [ngroups,size]
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return m.group(1).count(",") + 1
    return 1


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Per-op collective records from post-SPMD HLO text."""
    out = []
    for line in hlo_text.splitlines():
        s = line.strip()
        # match " = <result types> <op-name>(" with op in our set
        m = re.search(r"=\s+(\(?[\w\[\],{}\s/]*?)\s*((?:all|reduce|collective)[\w-]*)\(", s)
        if not m or m.group(2) not in _COLLECTIVES:
            continue
        op = m.group(2)
        if "-start" in s.split(op)[1][:8]:
            pass  # async start counted; matching -done has no shape cost
        result_bytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(m.group(1)))
        g = _group_size(s)
        if op == "all-gather":
            operand_bytes = result_bytes // max(g, 1)
        elif op == "reduce-scatter":
            operand_bytes = result_bytes * max(g, 1)
        else:  # all-reduce / all-to-all / collective-permute
            operand_bytes = result_bytes
        out.append(
            {"op": op, "operand_bytes": operand_bytes, "result_bytes": result_bytes,
             "group_size": g, "count": 1}
        )
    return out


def collective_summary(records: List[Dict]) -> Dict[str, Dict]:
    agg: Dict[str, Dict] = {}
    for r in records:
        a = agg.setdefault(r["op"], {"count": 0, "operand_bytes": 0})
        a["count"] += 1
        a["operand_bytes"] += r["operand_bytes"]
    return agg


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N_active·D for training, 2·N_active·D for inference (D = tokens
    processed in the step: B·S for train/prefill, B for decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens_per_step
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1)
    return 2.0 * n_active * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per-device flops * chips (total)
    hlo_bytes: float
    collective_bytes: float  # total operand bytes across chips
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    collectives: Dict[str, Dict]
    xla_flops: float = 0.0  # cost_analysis reference (while bodies x1 — low)
    xla_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak FLOP/s the step would achieve if it
        ran exactly at the max(terms) bound: model-useful MFU upper bound."""
        if not self.bound_s:
            return 0.0
        chips_peak = self.chips * TPU_V5E.peak_flops
        return self.model_flops / (self.bound_s * chips_peak)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_ratio"] = self.useful_ratio
        d["roofline_fraction"] = self.roofline_fraction
        return d


def analyze(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict,
    hlo_text: str,
    cfg: ArchConfig,
    shape_cfg: ShapeConfig,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineReport:
    """FLOPs / HBM bytes / collective bytes from the trip-count-aware HLO
    walker (``hlo_analysis``) — XLA's cost_analysis() counts while bodies
    once, undercounting scanned models by the layer count, so its numbers
    are kept only as reference fields.  All analyzer numbers are PER DEVICE
    on the SPMD-partitioned module; totals scale by chips."""
    from repro.launch.hlo_analysis import HloAnalyzer

    an = HloAnalyzer(hlo_text, n_devices=chips)
    flops_dev = an.flops()
    bytes_dev = an.hbm_bytes()
    per_dev_collective = an.collective_bytes()
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops_dev * chips,
        hlo_bytes=bytes_dev * chips,
        collective_bytes=per_dev_collective * chips,
        compute_s=flops_dev / hw.peak_flops,
        memory_s=bytes_dev / hw.hbm_bw,
        collective_s=per_dev_collective / hw.ici_bw,
        model_flops=model_flops(cfg, shape_cfg),
        collectives=an.collective_summary(),
        xla_flops=float(cost.get("flops", 0.0)) * chips,
        xla_bytes=float(cost.get("bytes accessed", 0.0)) * chips,
    )
