import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init.  512 host devices let jax.make_mesh build the production meshes
# (16x16 single-pod, 2x16x16 multi-pod) on this CPU-only container.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production mesh, prove it fits (memory analysis), and
extract the roofline terms (cost analysis + post-SPMD collective bytes).

    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out results/

Results are written one JSON per cell so the sweep is resumable
(--skip-existing) — a failed cell never loses completed work.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro import configs
from repro.distributed.sharding import ShardingRules, param_shardings
from repro.launch import roofline as R
from repro.launch.inputs import input_specs
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.steps import step_for_cell
from repro.models import model as M
from repro.models.config import applicable_shapes
from repro.training.optimizer import OptSettings, opt_state_shapes


def _sharded_structs(shapes, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), shapes, shardings
    )


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    save_hlo: str = "",
    microbatches: int = None,
    step_builder=step_for_cell,
    mesh_shape: tuple = None,  # §Perf variants: e.g. (32, 8), (256, 1)
    fsdp_params: bool = True,
    cfg_overrides: dict = None,  # §Perf variants: e.g. {"attn_chunk": 4096}
    remat_policy: str = "minimal",
) -> dict:
    import dataclasses as _dc

    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape_cfg = {s.name: s for s in applicable_shapes(cfg)}[shape_name]
    if mesh_shape is not None:
        from repro.launch.mesh import make_custom_mesh

        mesh = make_custom_mesh(*mesh_shape)
        mesh_name = f"pod{mesh_shape[0]}x{mesh_shape[1]}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rules = ShardingRules(mesh, fsdp_axes=batch_axes(mesh), fsdp_params=fsdp_params)

    pshapes = M.param_shapes(cfg)
    pshard = param_shardings(rules, cfg, pshapes)
    params_in = _sharded_structs(pshapes, pshard)

    step, takes_opt, n_micro = step_builder(
        cfg, shape_cfg, rules, microbatches=microbatches, remat_policy=remat_policy
    )
    args = list(input_specs(cfg, shape_cfg, rules))
    if takes_opt:
        settings = OptSettings.auto(cfg.param_count())
        oshapes = opt_state_shapes(pshapes, settings)
        oshard = {
            "m": pshard,
            "v": pshard,
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        args = [params_in, _sharded_structs(oshapes, oshard)] + args
    else:
        args = [params_in] + args

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(mem)  # proves it fits
        cost = compiled.cost_analysis()
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
        hlo = compiled.as_text()

    if save_hlo:
        import gzip

        pathlib.Path(save_hlo).parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(save_hlo, "wt") as f:
            f.write(hlo)
    report = R.analyze(
        arch, shape_name, mesh_name, mesh.size, cost, hlo, cfg, shape_cfg
    )
    mem_fields = {}
    for f in (
        "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
        "generated_code_size_in_bytes", "alias_size_in_bytes",
    ):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "microbatches": n_micro,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_fields,
        "roofline": report.to_json(),
    }
    if verbose:
        r = report
        print(
            f"[{arch} x {shape_name} x {mesh_name}] compute={r.compute_s:.4f}s "
            f"memory={r.memory_s:.4f}s collective={r.collective_s:.4f}s "
            f"dominant={r.dominant} useful={r.useful_ratio:.2f} "
            f"roofline_frac={r.roofline_fraction:.3f}"
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all applicable)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun", help="output dir (one JSON per cell)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument(
        "--save-hlo", action="store_true",
        help="also write <out>/hlo/<cell>.txt.gz (post-SPMD module, for offline analysis)",
    )
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else configs.ARCH_IDS
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = configs.get(arch)
        shapes = [s.name for s in applicable_shapes(cfg)]
        if args.shape:
            if args.shape not in shapes:
                print(f"SKIP {arch} x {args.shape}: not applicable")
                continue
            shapes = [args.shape]
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                path = outdir / f"{arch}__{shape_name}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    print(f"SKIP (exists) {path.name}")
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_name} ===", flush=True)
                hlo_path = (
                    str(outdir / "hlo" / f"{arch}__{shape_name}__{mesh_name}.txt.gz")
                    if args.save_hlo
                    else ""
                )
                try:
                    result = run_cell(arch, shape_name, multi, save_hlo=hlo_path)
                except Exception as e:  # record the failure, keep sweeping
                    traceback.print_exc()
                    result = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "fail", "error": f"{type(e).__name__}: {e}",
                    }
                    failures.append(path.name)
                path.write_text(json.dumps(result, indent=1))
    if failures:
        print(f"FAILED cells ({len(failures)}): {failures}")
        raise SystemExit(1)
    print("all requested cells OK")


if __name__ == "__main__":
    main()
