"""Training launcher: ``--arch <id>`` selects any assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --smoke --steps 30

On this CPU container only reduced (--smoke) configs are runnable end to
end; full configs are exercised via the dry-run (launch/dryrun.py).  On a
real pod this driver is launched once per host: each process feeds its
local devices from its own DELI pipeline (rank/world partition the sample
space), and the pjit step runs over the production mesh from launch/mesh.py.
"""
from __future__ import annotations

import argparse
import tempfile

from repro import configs
from repro.core import PrefetchConfig, RealClock
from repro.data import decode_tokens, make_lm_spec
from repro.training.loop import Trainer, TrainerConfig
from repro.training.optimizer import OptSettings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--world", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.reduce_for_smoke(cfg)
    elif cfg.param_count() > 1e9:
        raise SystemExit(
            f"{args.arch} has {cfg.param_count()/1e9:.0f}B params — full-size "
            "training needs the pod runtime; use --smoke here, or "
            "launch/dryrun.py to compile the full config."
        )
    if cfg.frontend == "frame":
        raise SystemExit("audio encoder training uses precomputed frame "
                         "embeds; see tests/test_arch_smoke.py for the path")
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")

    # The LM pipeline is a declarative condition now (ISSUE 4 satellite):
    # one DataPlaneSpec, projected into this host's free-running threaded
    # node pipeline.  On a pod, every host runs this same spec and picks
    # its own rank's loader/service.
    spec = make_lm_spec(
        n_samples=max(1024, args.batch * 64),
        seq_len=args.seq_len,
        vocab=cfg.vocab,
        batch_size=args.batch,
        cache_items=args.cache,
        world=args.world,
        policy=PrefetchConfig.fifty_fifty(args.cache),
    )
    cluster = spec.build_runtime(clock=RealClock())
    loader, service = cluster.loaders[args.rank], cluster.services[args.rank]
    trainer = Trainer(
        cfg,
        loader,
        TrainerConfig(
            seq_len=args.seq_len,
            batch_size=args.batch,
            checkpoint_dir=args.ckpt_dir or tempfile.mkdtemp(prefix="deli_"),
            checkpoint_every=max(10, args.steps // 3),
            log_every=10,
        ),
        decode_fn=decode_tokens,
        settings=OptSettings.auto(cfg.param_count()),
    )
    if args.resume and trainer.try_restore():
        print(f"resumed from step {trainer.step}")
    with service:
        metrics = trainer.train(args.steps)
    wait = sum(m.data_wait_s for m in metrics)
    comp = sum(m.compute_s for m in metrics)
    print(
        f"done: step {trainer.step} loss {metrics[-1].loss:.4f} | "
        f"data-wait {wait:.2f}s / compute {comp:.1f}s "
        f"({wait/(wait+comp):.1%} wait fraction)"
    )


if __name__ == "__main__":
    main()
