"""Cooperative peer-cache tier: nodes serve each other's cache misses.

The paper's DELI design caches bucket data node-locally, so every node pays
full Class B traffic for samples its *peers* already hold.  Hoard (Pinto et
al., 2018) showed a distributed cache tier across training nodes recovers
most of that bandwidth; Clairvoyant Prefetching / NoPFS (Dryden et al.,
2021) multiplies the benefit with locality-aware sample assignment.  This
module adds that tier to both execution paths:

  * ``PeerCacheRegistry`` — the cluster-wide directory: which node's
    ``CappedCache`` to ask for a given sample index.  In this repo the
    "network" is a ``NetworkModel`` (timing only); the registry is the
    integration point for a real RPC transport (gRPC sidecar, NCCL
    broadcast, ...) later.
  * ``PeerStore`` — a ``SampleStore`` that, on a local-cache miss, first
    asks its peers' caches over the modelled inter-node network and only
    then falls back to the wrapped bucket store.  A peer hit costs an RTT +
    payload/bandwidth instead of a bucket GET (no Class B request billed).

Consistency note: caches are keyed by (session, index) and entries are
immutable once inserted (payloads are content-addressed by dataset index),
so serving a peer's copy can never return stale data — eviction races
simply degrade to a bucket fallback.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from repro.core.bandwidth import DEFAULT_NETWORK, NetworkModel
from repro.core.cache import CappedCache
from repro.core.clock import Clock, RealClock
from repro.core.store import SampleStore


class PeerCacheRegistry:
    """Directory of every node's cache, shared by all ``PeerStore``s.

    Thread-safe: the threaded runtime registers/looks up concurrently from
    per-node prefetch workers and training loops.  ``lookup`` returns the
    id of a node (other than the requester) whose cache currently holds the
    index — preferring the lowest node id for determinism — or ``None``.
    """

    def __init__(self) -> None:
        self._caches: Dict[int, CappedCache] = {}
        self._lock = threading.Lock()
        self.lookups = 0
        self.peer_hits = 0

    def register(self, node: int, cache: CappedCache) -> None:
        with self._lock:
            if node in self._caches and self._caches[node] is not cache:
                raise ValueError(f"node {node} already registered")
            self._caches[node] = cache

    def nodes(self) -> List[int]:
        with self._lock:
            return sorted(self._caches)

    def cache_of(self, node: int) -> CappedCache:
        with self._lock:
            return self._caches[node]

    def lookup(self, index: int, requester: Optional[int] = None) -> Optional[int]:
        """Find a peer (not the requester) whose cache holds ``index``.

        A positive lookup is only a *candidate*: the holder may evict the
        entry before the payload read.  Callers confirm the hit with
        :meth:`record_hit` once the payload is actually in hand, so
        ``peer_hits`` never overcounts the eviction race.
        """
        with self._lock:
            candidates = sorted(self._caches)
            self.lookups += 1
        for node in candidates:
            if node == requester:
                continue
            if self._caches[node].contains(index):
                return node
        return None

    def record_hit(self) -> None:
        """Count one confirmed peer-served read (payload obtained)."""
        with self._lock:
            self.peer_hits += 1

    def cache_views(self) -> List[List[int]]:
        """Per-node cached index sets, ordered by node id (the all-gather a
        real deployment would perform for ``LocalityAwareSampler``)."""
        with self._lock:
            items = sorted(self._caches.items())
        return [cache.keys() for _, cache in items]


class PeerStore(SampleStore):
    """Store wrapper: peers' caches first, wrapped bucket store second.

    ``get`` resolution order (the local cache itself is in front of this
    store, inside ``CachingDataset``/``NodeSimulator``):

      1. registry lookup -> peer cache ``get`` + modelled network transfer
         (no Class B request, no bucket latency);
      2. fallback to ``inner.get`` (the usual bucket miss path).

    The eviction race (peer listed as holder, entry gone by the time we
    read) degrades to the fallback, never to an error.
    """

    def __init__(
        self,
        inner: SampleStore,
        registry: PeerCacheRegistry,
        node: int,
        network: NetworkModel = DEFAULT_NETWORK,
        clock: Optional[Clock] = None,
        charge_lookup_on_miss: bool = True,
    ):
        super().__init__()
        self.inner = inner
        self.registry = registry
        self.node = node
        self.network = network
        self.clock = clock or getattr(inner, "clock", None) or RealClock()
        self.charge_lookup_on_miss = charge_lookup_on_miss
        self.peer_hits = 0
        self.peer_bytes = 0
        self.peer_seconds = 0.0
        self._peer_lock = threading.Lock()

    def get(self, index: int, **kw) -> bytes:
        return self.get_with_origin(index, **kw)[0]

    def get_with_origin(self, index: int, **kw) -> "tuple[bytes, bool]":
        """GET returning ``(payload, served_by_peer)``.

        The flag is per-call, so callers attributing hits (e.g.
        ``CachingDataset``) stay correct when a prefetch worker and the
        training loop share this store concurrently.
        """
        holder = self.registry.lookup(index, requester=self.node)
        if holder is not None:
            # peek(): don't pollute the holder's own hit/miss accounting.
            payload = self.registry.cache_of(holder).peek(index)
            if payload is not None:
                dt = self.network.transfer_seconds(len(payload))
                self.clock.sleep(dt)
                with self._peer_lock:
                    self.peer_hits += 1
                    self.peer_bytes += len(payload)
                    self.peer_seconds += dt
                self.registry.record_hit()
                return payload, True
        if self.charge_lookup_on_miss:
            self.clock.sleep(self.network.lookup_seconds())
        return self.inner.get(index, **kw), False

    def size_of(self, index: int) -> int:
        return self.inner.size_of(index)

    def list_objects(self) -> List[int]:
        return self.inner.list_objects()

    @property
    def stats(self):  # type: ignore[override]
        # Class A/B accounting lives where the requests are billed: the
        # wrapped bucket store.  Peer traffic is tracked separately above.
        return self.inner.stats

    @stats.setter
    def stats(self, v) -> None:
        if hasattr(self, "inner"):
            self.inner.stats = v
        else:  # abc __init__ assigns before inner exists
            self.__dict__["_pre_init_stats"] = v
