"""Cooperative peer-cache tier: nodes serve each other's cache misses.

The paper's DELI design caches bucket data node-locally, so every node pays
full Class B traffic for samples its *peers* already hold.  Hoard (Pinto et
al., 2018) showed a distributed cache tier across training nodes recovers
most of that bandwidth; Clairvoyant Prefetching / NoPFS (Dryden et al.,
2021) multiplies the benefit with locality-aware sample assignment.  This
module adds that tier to both execution paths:

  * ``PeerCacheRegistry`` — the cluster-wide directory: which node's
    ``CappedCache`` to ask for a given sample index.  In this repo the
    "network" is a ``NetworkModel`` (timing only); the registry is the
    integration point for a real RPC transport (gRPC sidecar, NCCL
    broadcast, ...) later.  It also maintains *resident-copy counts* per
    sample; with ``replication_aware=True`` member caches decline to evict
    the last cluster-resident copy of a sample (Hoard keeps one), so peers
    keep serving it instead of someone re-paying a bucket GET.
  * ``PeerStore`` — a ``SampleStore`` whose ``peer_lookup`` serves a read
    from a peer's cache over the modelled inter-node network, returning the
    explicit per-tier attribution (``repro.pipeline.tiers.TierResult``); a
    miss charges the lookup RTT and returns None so the next tier (the
    wrapped bucket) takes over.  A peer hit costs an RTT + payload/bandwidth
    instead of a bucket GET (no Class B request billed).

Consistency note: caches are keyed by (session, index) and entries are
immutable once inserted (payloads are content-addressed by dataset index),
so serving a peer's copy can never return stale data — eviction races
simply degrade to a bucket fallback.

Visibility note (ISSUE 3): what a ``lookup`` *observes* depends on the
cluster schedule.  Under the event-interleaved scheduler (the default for
both execution paths) a probe sees every peer's **mid-epoch** cache state —
same-epoch fills and evictions alike — because all nodes advance through
one virtual-time event queue and fold their pre-fetch completions before
any node is stepped.  The legacy sequential schedule
(``interleaved=False``) froze peers at epoch boundaries, which overstated
this tier for capped caches; ``benchmarks/fig10_peer_cache.py`` reports
the delta.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from repro.core.bandwidth import DEFAULT_NETWORK, NetworkModel
from repro.core.cache import CappedCache
from repro.core.clock import Clock
from repro.core.store import SampleStore
from repro.pipeline.tiers import TierResult


class PeerCacheRegistry:
    """Directory of every node's cache, shared by all ``PeerStore``s.

    Thread-safe: the threaded runtime registers/looks up concurrently from
    per-node prefetch workers and training loops.  ``lookup`` returns the
    id of a node (other than the requester) whose cache currently holds the
    index — preferring the lowest node id for determinism — or ``None``.

    ``replication_aware=True`` (Hoard-style, beyond-paper) wires an
    eviction guard into every registered cache: the FIFO victim search
    skips entries whose cluster-wide resident-copy count is 1, so the last
    copy of a sample survives as long as anything else can be evicted
    instead.  Copy counts are maintained via the caches' residency
    listeners (updated under each cache's own lock, then this registry's
    lock; the registry never takes a cache lock while holding its own, so
    the lock order is acyclic).
    """

    def __init__(self, replication_aware: bool = False) -> None:
        self.replication_aware = replication_aware
        self._caches: Dict[int, CappedCache] = {}
        self._copies: Dict[int, int] = {}  # index -> cluster-resident copies
        self._lock = threading.Lock()
        self.lookups = 0
        self.peer_hits = 0

    # -- residency bookkeeping ----------------------------------------------
    def _note_insert(self, index: int) -> None:
        with self._lock:
            self._copies[index] = self._copies.get(index, 0) + 1

    def _note_evict(self, index: int) -> None:
        with self._lock:
            left = self._copies.get(index, 0) - 1
            if left > 0:
                self._copies[index] = left
            else:
                self._copies.pop(index, None)

    def _guard_last_copy(self, index: int) -> bool:
        """Eviction guard: True = protected (last cluster-resident copy).

        Called once per probed entry with the probing cache's lock held, so
        this reads ``_copies`` WITHOUT the registry lock: a single
        ``dict.get`` is atomic under the GIL, and the guard is advisory —
        a racing insert/evict at worst yields one momentarily stale
        protection decision, never a wrong eviction.  (Caches report how
        often protection redirected an eviction via
        ``CacheStats.guard_skips``.)
        """
        return self._copies.get(index, 0) <= 1

    def resident_copies(self, index: int) -> int:
        """How many member caches currently hold ``index``."""
        with self._lock:
            return self._copies.get(index, 0)

    def register(self, node: int, cache: CappedCache) -> None:
        with self._lock:
            if node in self._caches and self._caches[node] is not cache:
                raise ValueError(f"node {node} already registered")
            already = self._caches.get(node) is cache
            self._caches[node] = cache
        if already:
            return
        # Fold pre-registration residents into the copy counts.  Read the
        # key set without holding the registry lock (lock-order discipline).
        resident = cache.keys()
        with self._lock:
            for idx in resident:
                self._copies[idx] = self._copies.get(idx, 0) + 1
        cache.set_residency_listener(self._note_insert, self._note_evict)
        if self.replication_aware:
            cache.eviction_guard = self._guard_last_copy

    def nodes(self) -> List[int]:
        with self._lock:
            return sorted(self._caches)

    def cache_of(self, node: int) -> CappedCache:
        with self._lock:
            return self._caches[node]

    def lookup(self, index: int, requester: Optional[int] = None) -> Optional[int]:
        """Find a peer (not the requester) whose cache holds ``index``.

        A positive lookup is only a *candidate*: the holder may evict the
        entry before the payload read.  Callers confirm the hit with
        :meth:`record_hit` once the payload is actually in hand, so
        ``peer_hits`` never overcounts the eviction race.
        """
        with self._lock:
            candidates = sorted(self._caches)
            self.lookups += 1
        for node in candidates:
            if node == requester:
                continue
            if self._caches[node].contains(index):
                return node
        return None

    def record_hit(self) -> None:
        """Count one confirmed peer-served read (payload obtained)."""
        with self._lock:
            self.peer_hits += 1

    def cache_views(self) -> List[List[int]]:
        """Per-node cached index sets, ordered by node id (the all-gather a
        real deployment would perform for ``LocalityAwareSampler``)."""
        with self._lock:
            items = sorted(self._caches.items())
        return [cache.keys() for _, cache in items]


class PeerStore(SampleStore):
    """Store wrapper: peers' caches first, wrapped bucket store second.

    ``peer_lookup`` is the ``PeerTier`` entry point (the local cache itself
    sits in front of this store, inside ``CachingDataset``/
    ``NodeSimulator``):

      1. registry lookup -> peer cache read + modelled network transfer
         (no Class B request, no bucket latency) -> ``TierResult``;
      2. None on a miss (after charging the lookup RTT), so the stack falls
         through to the wrapped bucket — the usual Class B miss path.

    The eviction race (peer listed as holder, entry gone by the time we
    read) degrades to the fallback, never to an error.
    """

    def __init__(
        self,
        inner: SampleStore,
        registry: PeerCacheRegistry,
        node: int,
        network: NetworkModel = DEFAULT_NETWORK,
        clock: Optional[Clock] = None,
        charge_lookup_on_miss: bool = True,
    ):
        super().__init__()
        self.inner = inner
        self.registry = registry
        self.node = node
        self.network = network
        self.clock = clock or inner.clock
        self.charge_lookup_on_miss = charge_lookup_on_miss
        self.peer_hits = 0
        self.peer_bytes = 0
        self.peer_seconds = 0.0
        self._peer_lock = threading.Lock()

    def peer_lookup(self, index: int) -> Optional[TierResult]:
        """Serve ``index`` from a peer's cache; None = not cluster-resident.

        The returned ``TierResult`` is the per-call attribution (tier
        "peer", zero Class B), so callers sharing this store concurrently
        (prefetch workers + the training loop) can never misattribute a
        read.
        """
        holder = self.registry.lookup(index, requester=self.node)
        if holder is not None:
            # peek(): don't pollute the holder's own hit/miss accounting.
            payload = self.registry.cache_of(holder).peek(index)
            if payload is not None:
                dt = self.network.transfer_seconds(len(payload))
                self.clock.sleep(dt)
                with self._peer_lock:
                    self.peer_hits += 1
                    self.peer_bytes += len(payload)
                    self.peer_seconds += dt
                self.registry.record_hit()
                return TierResult(
                    payload, "peer", class_b=0, nbytes=len(payload), seconds=dt
                )
        if self.charge_lookup_on_miss:
            self.clock.sleep(self.network.lookup_seconds())
        return None

    def get(self, index: int, **kw) -> bytes:
        result = self.peer_lookup(index)
        if result is not None:
            return result.payload
        return self.inner.get(index, **kw)

    def get_with_origin(self, index: int, **kw) -> "tuple[bytes, bool]":
        """Legacy shim: GET returning ``(payload, served_by_peer)``.

        Pre-tier callers used this per-call flag for attribution; new code
        reads ``TierResult.tier`` from ``peer_lookup`` / the tier stack.
        """
        result = self.peer_lookup(index)
        if result is not None:
            return result.payload, True
        return self.inner.get(index, **kw), False

    def size_of(self, index: int) -> int:
        return self.inner.size_of(index)

    def list_objects(self) -> List[int]:
        return self.inner.list_objects()

    @property
    def stats(self):  # type: ignore[override]
        # Class A/B accounting lives where the requests are billed: the
        # wrapped bucket store.  Peer traffic is tracked separately above.
        return self.inner.stats

    @stats.setter
    def stats(self, v) -> None:
        if hasattr(self, "inner"):
            self.inner.stats = v
        else:  # abc __init__ assigns before inner exists
            self.__dict__["_pre_init_stats"] = v
