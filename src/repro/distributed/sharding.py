"""Sharding rules: parameter / optimizer / activation / decode-state
PartitionSpecs for the (pod, data, model) production mesh.

Scheme (MaxText-style 2-D sharding):
  * tensor parallel on ``model``: attention q/kv projections sharded on the
    flattened head dim, MLP on d_ff, MoE experts on E (expert parallelism),
    vocab on V;
  * FSDP on (``pod``, ``data``): the *other* matrix dim of every large
    parameter (and its optimizer moments) is sharded across the batch axes;
    XLA GSPMD inserts the per-layer all-gather inside the scan-over-periods
    loop, which is exactly FSDP's gather-on-use.

Every rule is applied *best-effort*: a dim is only sharded if the axis size
divides it (``_fit``), so odd published shapes (56 heads, vocab 504, SSM
in_proj widths) degrade to replication of that dim instead of failing to
lower.  The roofline report calls out where this costs performance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    fsdp_axes: Tuple[str, ...]  # ("pod","data") or ("data",)
    model_axis: str = "model"
    fsdp_params: bool = True  # False => pure TP (params replicated over data)

    @property
    def fsdp_size(self) -> int:
        n = 1
        for a in self.fsdp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def _fit(self, dim: int, axes, size: int):
        """axes if they evenly divide dim, else None (replicate)."""
        return axes if dim % size == 0 else None

    def tp(self, dim: int):
        return self._fit(dim, self.model_axis, self.model_size)

    def fsdp(self, dim: int):
        if not self.fsdp_params:
            return None
        return self._fit(dim, self.fsdp_axes, self.fsdp_size)

    def matrix(self, rows: int, cols: int, tp_dim: int) -> P:
        """2-D param (rows, cols); ``tp_dim`` says which dim is TP."""
        if tp_dim == 1:
            return P(self.fsdp(rows), self.tp(cols))
        return P(self.tp(rows), self.fsdp(cols))


def _leaf_spec(rules: ShardingRules, cfg: ArchConfig, path: Tuple[str, ...], leaf) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path.

    Stacked layer params carry a leading n_periods axis (never sharded).
    """
    name = path[-1]
    shape = leaf.shape
    stacked = path[0] == "stack"
    dims = shape[1:] if stacked else shape  # strip period axis
    lead = (None,) if stacked else ()

    def out(*spec):
        return P(*lead, *spec)

    # ---- embeddings / head -------------------------------------------------
    if name == "embed":
        return P(rules.tp(shape[0]), rules.fsdp(shape[1]))  # (V, d)
    if name == "head":
        return P(rules.fsdp(shape[0]), rules.tp(shape[1]))  # (d, V)
    if name in ("final_norm",):
        return P(None)

    # ---- norms / small vectors --------------------------------------------
    if name.startswith("norm") or name in ("gate_norm", "A_log", "D", "dt_bias", "conv_b"):
        return out(*([None] * len(dims)))
    if name in ("bq", "bk", "bv"):
        return out(rules.tp(dims[0]))

    # ---- attention ----------------------------------------------------------
    if name in ("wq", "wk", "wv"):
        return out(rules.fsdp(dims[0]), rules.tp(dims[1]))
    if name == "wo":
        return out(rules.tp(dims[0]), rules.fsdp(dims[1]))

    # ---- dense MLP ----------------------------------------------------------
    if name in ("w_gate", "w_up", "w_down") and len(dims) == 2:
        if name == "w_down":
            return out(rules.tp(dims[0]), rules.fsdp(dims[1]))
        return out(rules.fsdp(dims[0]), rules.tp(dims[1]))

    # ---- MoE (leading E dim -> expert parallelism on model) ----------------
    if name == "router":
        return out(rules.fsdp(dims[0]), None)
    if name in ("w_gate", "w_up", "w_down") and len(dims) == 3:
        return out(rules.tp(dims[0]), rules.fsdp(dims[1]), None)

    # ---- SSM ----------------------------------------------------------------
    if name == "in_proj":
        return out(rules.fsdp(dims[0]), rules.tp(dims[1]))
    if name == "out_proj":
        return out(rules.tp(dims[0]), rules.fsdp(dims[1]))
    if name == "conv_w":
        return out(None, rules.tp(dims[1]))

    return out(*([None] * len(dims)))  # default: replicate


def _tree_paths(tree, prefix=()):
    for k, v in tree.items():
        if isinstance(v, dict):
            yield from _tree_paths(v, prefix + (k,))
        else:
            yield prefix + (k,), v


def param_shardings(rules: ShardingRules, cfg: ArchConfig, shapes: Dict) -> Dict:
    """NamedSharding pytree matching a param (or opt-moment) shape pytree."""
    out = jax.tree.map(lambda _: None, shapes)

    def build(tree, spec_tree):
        for path, leaf in _tree_paths(tree):
            spec = _leaf_spec(rules, cfg, path, leaf)
            node = spec_tree
            for k in path[:-1]:
                node = node[k]
            node[path[-1]] = NamedSharding(rules.mesh, spec)

    build(shapes, out)
    return out


# ---------------------------------------------------------------------------
# Inputs / activations / decode state
# ---------------------------------------------------------------------------
def batch_spec(rules: ShardingRules, global_batch: int, extra_dims: int = 1) -> P:
    """Spec for a (B, ...) input: batch over as many fsdp axes as divide."""
    axes = []
    b = global_batch
    for a in rules.fsdp_axes:
        n = rules.mesh.shape[a]
        if b % n == 0:
            axes.append(a)
            b //= n
    bspec = tuple(axes) if axes else None
    return P(bspec, *([None] * extra_dims))


def input_shardings(rules: ShardingRules, cfg: ArchConfig, batch: Dict) -> Dict:
    """Shardings for a host batch dict (tokens/labels/embeds)."""
    out = {}
    for k, v in batch.items():
        out[k] = NamedSharding(
            rules.mesh, batch_spec(rules, v.shape[0], extra_dims=v.ndim - 1)
        )
    return out


def _greedy_batch_axes(rules: ShardingRules, batch_dim: int):
    """fsdp axes that evenly divide the batch (prefix-greedy); remainder axes."""
    axes_b, b = [], batch_dim
    for a in rules.fsdp_axes:
        n = rules.mesh.shape[a]
        if b % n == 0:
            axes_b.append(a)
            b //= n
    leftover = [a for a in rules.fsdp_axes if a not in axes_b]
    return axes_b, leftover


def kv_cache_spec(rules: ShardingRules, batch_dim: int, seq_dim: int, kv_heads: int) -> P:
    """(B, S, KV, hd) KV-cache spec.

    Batch over the fsdp axes that fit.  The ``model`` axis (plus any fsdp
    axis batch couldn't use, e.g. long_500k's batch=1) then shards KV heads
    when divisible, else the *sequence*: a sequence-sharded cache makes
    decode attention a partial-reduction + small all-reduce over scores —
    flash-decode's parallelism, expressed through GSPMD."""
    axes_b, leftover = _greedy_batch_axes(rules, batch_dim)
    extra = leftover + [rules.model_axis]
    kv_axes, s_axes = [], []
    kv, s = kv_heads, seq_dim
    for a in extra:
        n = rules.mesh.shape[a]
        if kv % n == 0:
            kv_axes.append(a)
            kv //= n
        elif s % n == 0:
            s_axes.append(a)
            s //= n
    return P(tuple(axes_b) or None, tuple(s_axes) or None, tuple(kv_axes) or None, None)


def ssm_state_spec(rules: ShardingRules, batch_dim: int, n_heads: int) -> P:
    """(B, H, P, N) SSD-state spec: batch over fitting fsdp axes, heads over
    the model axis (+ unused fsdp axes) when divisible."""
    axes_b, leftover = _greedy_batch_axes(rules, batch_dim)
    h_axes, h = [], n_heads
    for a in leftover + [rules.model_axis]:
        n = rules.mesh.shape[a]
        if h % n == 0:
            h_axes.append(a)
            h //= n
    return P(tuple(axes_b) or None, tuple(h_axes) or None, None, None)


def state_shardings(rules: ShardingRules, cfg: ArchConfig, state_shapes) -> object:
    """Shardings for the decode state (caches, kv_len).

    Cache leaves are stacked (n_periods, B, S, KV, hd) / (n_periods, B, ...).
    """
    caches, kv_len = state_shapes

    def spec_for(path, leaf):
        name = path[-1]
        if name in ("k", "v"):
            _, B, S, KV, hd = leaf.shape
            return NamedSharding(rules.mesh, P(None, *kv_cache_spec(rules, B, S, KV)))
        if name == "state":
            _, B, H, Pd, N = leaf.shape
            return NamedSharding(rules.mesh, P(None, *ssm_state_spec(rules, B, H)))
        # conv tail (n_periods, B, K-1, C): batch + channel best-effort
        _, B, K1, C = leaf.shape
        axes_b, leftover = _greedy_batch_axes(rules, B)
        c_axes, c = [], C
        for a in leftover + [rules.model_axis]:
            n = rules.mesh.shape[a]
            if c % n == 0:
                c_axes.append(a)
                c //= n
        return NamedSharding(
            rules.mesh, P(None, tuple(axes_b) or None, None, tuple(c_axes) or None)
        )

    out = jax.tree.map(lambda _: None, caches)
    for path, leaf in _tree_paths(caches):
        node = out
        for k in path[:-1]:
            node = node[k]
        node[path[-1]] = spec_for(path, leaf)
    kv_spec = NamedSharding(rules.mesh, batch_spec(rules, kv_len.shape[0], extra_dims=0))
    return out, kv_spec
