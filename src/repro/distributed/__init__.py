"""repro.distributed — cluster-scale layers.

``peer_cache`` (pure Python, no jax) is imported eagerly; the sharding
rules pull in jax, so they are exposed lazily (PEP 562) to keep the core
data plane importable without paying the jax import in tests/tools that
never touch a mesh.
"""
from repro.distributed.peer_cache import PeerCacheRegistry, PeerStore

_SHARDING_EXPORTS = (
    "ShardingRules",
    "batch_spec",
    "input_shardings",
    "param_shardings",
    "state_shardings",
)

__all__ = ["PeerCacheRegistry", "PeerStore", *_SHARDING_EXPORTS]


def __getattr__(name):
    if name in _SHARDING_EXPORTS:
        from repro.distributed import sharding

        return getattr(sharding, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
