from repro.distributed.sharding import (
    ShardingRules,
    batch_spec,
    input_shardings,
    param_shardings,
    state_shardings,
)
