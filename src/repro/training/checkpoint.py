"""Step-atomic checkpointing of (params, optimizer, data-plane cursor, RNG).

Layout: one directory per step —

    <dir>/step_000123/
        manifest.json    tree structure + dtypes + loader state + metadata
        arrays.npz       flat leaves keyed by tree path

Writes are ATOMIC (tmp dir + os.rename) so a preempted node never leaves a
half-written checkpoint, and ``latest_step`` only believes a directory that
contains a manifest.  ``save_async`` runs serialization on a worker thread —
the training loop donates a host copy and keeps stepping (compute/IO
overlap, the same trick DELI's pre-fetcher plays on the input side).

Restore is sharding-aware: pass ``like`` (ShapeDtypeStructs with shardings)
and leaves are placed with jax.device_put against each leaf's sharding.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(
    directory: str,
    step: int,
    params,
    opt_state,
    loader_state: Optional[dict] = None,
    rng: Optional[jax.Array] = None,
    extra: Optional[dict] = None,
) -> str:
    """Synchronous atomic save; returns the checkpoint path."""
    base = pathlib.Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=base, prefix=".tmp_ckpt_"))
    try:
        tree = {"params": params, "opt": opt_state}
        if rng is not None:
            tree["rng"] = rng
        flat = _flatten(tree)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "loader_state": loader_state or {},
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return str(final)


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training compute.

    ``save()`` snapshots the pytrees to host memory synchronously (cheap),
    then writes on a background thread; ``wait()`` joins before the next
    save or at shutdown so at most one write is in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None
        self.error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err

    def save(self, step: int, params, opt_state, loader_state=None, rng=None, extra=None):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), {"p": params, "o": opt_state})

        def work():
            try:
                self.last_path = save_checkpoint(
                    self.directory, step, host["p"], host["o"], loader_state, rng, extra
                )
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = list_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(
                pathlib.Path(self.directory) / f"step_{s:08d}", ignore_errors=True
            )


def list_steps(directory: str):
    base = pathlib.Path(directory)
    if not base.exists():
        return []
    out = []
    for d in base.iterdir():
        m = re.fullmatch(r"step_(\d+)", d.name)
        if m and (d / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    step: Optional[int] = None,
    like: Optional[Tuple] = None,
) -> Tuple[Any, Any, dict, dict]:
    """Returns (params, opt_state, loader_state, extra).

    ``like`` = (params_like, opt_like) pytrees of ShapeDtypeStruct (with
    shardings for distributed restore) or arrays; leaves are device_put
    against the target sharding when present."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}

    def unflatten(like_tree, prefix):
        flat_paths = jax.tree_util.tree_flatten_with_path(like_tree)
        leaves = []
        for p, leaf in flat_paths[0]:
            key = prefix + "/" + "/".join(
                str(getattr(q, "key", getattr(q, "idx", q))) for q in p
            )
            arr = arrays[key]
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None:
                leaves.append(jax.device_put(arr.astype(leaf.dtype), sharding))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(flat_paths[1], leaves)

    if like is not None:
        params = unflatten(like[0], "params")
        opt = unflatten(like[1], "opt")
    else:
        params = {
            k[len("params/"):]: v for k, v in arrays.items() if k.startswith("params/")
        }
        opt = {k[len("opt/"):]: v for k, v in arrays.items() if k.startswith("opt/")}
    return params, opt, manifest.get("loader_state", {}), manifest.get("extra", {})
