"""Sharded AdamW, implemented directly over param pytrees (no optax in the
container).  Moments inherit each parameter's sharding, so optimizer state
is FSDP+TP sharded exactly like the parameters.

``moment_dtype="bfloat16"`` halves optimizer HBM — required to fit the
~400B-class archs on a 256-chip pod (16 GB/chip: f32 moments alone would be
12.4 GB for jamba-398B).  This is the distributed-optimization trick the
dry-run memory analysis validates; f32 is the default for <50B models.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptSettings:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" for >=50B models

    @classmethod
    def auto(cls, n_params: int) -> "OptSettings":
        return cls(moment_dtype="bfloat16" if n_params >= 50e9 else "float32")


def adamw_init(params, settings: OptSettings):
    dt = jnp.dtype(settings.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_shapes(param_shapes, settings: OptSettings):
    """ShapeDtypeStruct mirror of adamw_init (dry-run path)."""
    dt = jnp.dtype(settings.moment_dtype)
    struct = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree.map(struct, param_shapes),
        "v": jax.tree.map(struct, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _global_norm(grads) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )


def adamw_update(params, grads, opt_state, settings: OptSettings) -> Tuple[Dict, Dict]:
    """One AdamW step.  Math in f32; params/moments cast back to storage
    dtypes.  Weight decay skips 1-D leaves (norms, biases)."""
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, settings.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1, b2 = settings.beta1, settings.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + settings.eps)
        if p.ndim > 1:
            update = update + settings.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - settings.lr * update
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(leaf, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
