"""The training loop: DELI data plane -> device arrays -> jit'd train step,
with the paper's data-wait accounting at STEP granularity, step-atomic
async checkpointing, restart recovery, and elastic re-partitioning.

This is where the paper's mechanism meets the TPU training stack: the
loader's miss/wait metrics decide whether the input pipeline (not the mesh)
is the bottleneck, exactly the measurement DELI §V makes — but per training
step instead of per epoch, because a pod-scale job wants to see data-wait
within the step budget, not after an epoch is lost.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loader import Batch, DeliLoader
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.training import checkpoint as ckpt
from repro.training.optimizer import OptSettings, adamw_init
from repro.launch.steps import make_train_step


@dataclasses.dataclass
class StepMetrics:
    step: int
    loss: float
    data_wait_s: float
    compute_s: float
    hits: int
    misses: int

    @property
    def wait_fraction(self) -> float:
        tot = self.data_wait_s + self.compute_s
        return self.data_wait_s / tot if tot else 0.0


@dataclasses.dataclass
class TrainerConfig:
    seq_len: int
    batch_size: int  # per-host samples per step
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    log_every: int = 10


class Trainer:
    """Single-host driver (CPU container); the same step/ckpt code paths the
    pod launcher uses, minus the multi-process runtime."""

    def __init__(
        self,
        cfg: ArchConfig,
        loader: DeliLoader,
        tcfg: TrainerConfig,
        decode_fn: Callable[[bytes], np.ndarray],
        settings: Optional[OptSettings] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.loader = loader
        self.tcfg = tcfg
        self.decode_fn = decode_fn
        self.settings = settings or OptSettings.auto(cfg.param_count())
        self.params = M.init_params(jax.random.PRNGKey(seed), cfg)
        self.opt_state = adamw_init(self.params, self.settings)
        self.step = 0
        self.metrics: List[StepMetrics] = []
        self._step_fn = jax.jit(make_train_step(cfg, self.settings))
        self._ckpt = (
            ckpt.AsyncCheckpointer(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
            if tcfg.checkpoint_dir
            else None
        )

    # -- data ----------------------------------------------------------------
    def _to_device_batch(self, batch: Batch) -> Dict[str, jax.Array]:
        tokens = batch.stacked(self.decode_fn).astype(np.int32)
        tokens = tokens[:, : self.tcfg.seq_len + 1]
        return {
            "tokens": jnp.asarray(tokens[:, :-1]),
            "labels": jnp.asarray(tokens[:, 1:]),
        }

    # -- checkpoint/restore ----------------------------------------------------
    def try_restore(self) -> bool:
        if not self.tcfg.checkpoint_dir:
            return False
        step = ckpt.latest_step(self.tcfg.checkpoint_dir)
        if step is None:
            return False
        like = (
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.opt_state),
        )
        params, opt, loader_state, extra = ckpt.restore_checkpoint(
            self.tcfg.checkpoint_dir, step, like=like
        )
        self.params, self.opt_state = params, opt
        self.step = int(extra.get("step", step))
        if loader_state:
            self.loader.load_state_dict(loader_state)
        return True

    def _maybe_checkpoint(self):
        if self._ckpt and self.step % self.tcfg.checkpoint_every == 0:
            self._ckpt.save(
                self.step,
                self.params,
                self.opt_state,
                loader_state=self.loader.state_dict(),
                extra={"step": self.step},
            )

    # -- the loop ---------------------------------------------------------------
    def train(self, num_steps: int, epochs: int = 10_000) -> List[StepMetrics]:
        target = self.step + num_steps
        epoch = self.loader.state_dict()["epoch"]
        while self.step < target and epoch < epochs:
            self.loader.set_epoch(epoch)
            for batch in self.loader:
                dev_batch = self._to_device_batch(batch)
                t0 = time.monotonic()
                loss, self.params, self.opt_state = self._step_fn(
                    self.params, self.opt_state, dev_batch
                )
                loss = float(loss)  # blocks; includes device compute
                compute_s = time.monotonic() - t0
                self.step += 1
                m = StepMetrics(
                    self.step, loss, batch.data_wait_s, compute_s,
                    batch.hits, batch.misses,
                )
                self.metrics.append(m)
                if self.step % self.tcfg.log_every == 0:
                    print(
                        f"step {self.step} loss {loss:.4f} "
                        f"wait {m.data_wait_s*1e3:.1f}ms ({m.wait_fraction:.0%}) "
                        f"miss {batch.misses}/{batch.hits + batch.misses}"
                    )
                self._maybe_checkpoint()
                if self.step >= target:
                    break
            epoch += 1
        if self._ckpt:
            self._ckpt.wait()
        return self.metrics

    # -- paper metrics ------------------------------------------------------------
    def epoch_wait_summary(self) -> Dict[int, float]:
        out: Dict[int, float] = {}
        for s in self.loader.epoch_history:
            out[s.epoch] = s.data_wait_seconds
        return out


def elastic_repartition(loader: DeliLoader, new_rank: int, new_world: int) -> None:
    """Elastic scaling: re-partition the sample space when the data-parallel
    world changes (nodes joined/left).  The cache is preserved — entries are
    keyed by dataset index, so samples that stay on this node keep hitting;
    the prefetcher simply starts announcing the new partition."""
    from repro.core.sampler import DistributedPartitionSampler

    old = loader.sampler
    loader.sampler = DistributedPartitionSampler(
        n_samples=old.n_samples,
        rank=new_rank,
        world=new_world,
        seed=getattr(old, "seed", 0),
    )
    loader.sampler.set_epoch(loader.state_dict()["epoch"])
    loader._resume_cursor = 0  # partition changed: restart the epoch slice
