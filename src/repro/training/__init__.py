from repro.training.optimizer import (
    OptSettings,
    adamw_init,
    adamw_update,
    opt_state_shapes,
)
