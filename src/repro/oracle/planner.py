"""Clairvoyant prefetch scheduling: fetch rounds from the oracle, not knobs.

The paper's pre-fetch service is driven by two hand-tuned knobs —
``fetch_size`` and ``prefetch_threshold`` (``repro.core.policy``) — and its
best setting (the 50/50 rule) was found by a parameter sweep.  NoPFS's
observation applies here too: the sampler's exact future order is known, so
the *schedule itself* can be derived instead of tuned.
:class:`OraclePrefetchPlanner` is a drop-in replacement for
``PrefetchPlanner`` (same ``(index, fetch_round_or_None)`` iteration
protocol) that plans each round clairvoyantly:

  * **deadline order** — rounds are prefixes of the exact future access
    sequence, so every round is earliest-deadline-first by construction;
  * **capacity-aware window** — announced-but-unconsumed keys never exceed
    the cache capacity ``W``, so a fetch can never evict a sample that is
    still needed before it (the Fig. 7 cache-churn regime is impossible by
    construction); refills trigger at half a window, keeping the pipeline
    full without the paper's threshold knob;
  * **ramped round sizes** — sizes double from 1 up to the window: the
    first sample's deadline is *now*, so the opening rounds are small
    (nothing stalls behind a big bulk transfer), while steady-state rounds
    grow to half-window for bulk-GET parallelism — this is what removes
    the 50/50 schedule's cold-start stall;
  * **residency filter** — keys already in the local cache (last epoch's
    residue) are skipped at announce time: no re-fetched Class B GETs for
    bytes the node already holds.  Cluster-resident keys are additionally
    pulled from peers (never billed to Class B) by the peer partition the
    shared ``LockstepPrefetchService.issue`` already performs — the planner
    composes with it rather than duplicating it.

Round sizing comes in two flavours (ISSUE 7 satellite):

  * ``sizing="ramp"`` (default) — the historical doubling ramp above,
    pinned byte-for-byte;
  * ``sizing="cost"`` — deadline-solved sizes from the calibrated
    bandwidth models (:class:`RoundCostModel`): each round is the largest
    one whose modelled bulk-GET duration still completes within the
    virtual time the training loop needs to drain the keys already
    announced (every pending key costs at least the RAM-hit + CPU floor).
    The opening rounds stay small for the same cold-start reason; steady-
    state rounds grow exactly as fast as the models say the loop can hide
    them, instead of by powers of two.

Pure logic, no clocks, no I/O — the same discipline as
``repro.core.policy`` — so both projections iterate the identical plan.
``planner_for``/``make_planner_factory`` are THE construction points: the
simulator (``NodeSimulator.begin_epoch``) and the lock-step runtime
(``RuntimeCluster`` via ``DeliLoader(planner_factory=...)``) both build
their epoch planner here — including the cluster-placement planner
(``policy="cluster-oracle"``, ``repro.oracle.placement``) — which is what
keeps oracle specs inside the exact-parity domain (docs/PARITY.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

from repro.core.policy import PrefetchConfig, PrefetchPlanner


@dataclasses.dataclass(frozen=True)
class RoundCostModel:
    """Calibrated inputs of cost-aware round sizing (``sizing="cost"``).

    ``bucket`` is the node's (profile-scaled) ``BucketModel``; ``floor_s``
    is the per-sample virtual-time floor of the consuming loop — the
    RAM-hit latency plus the per-sample CPU overhead, i.e. the fastest the
    training loop can possibly drain one already-cached key.  Both
    projections construct this from the same profile-scaled models, so the
    solved sizes are identical floats on both sides.
    """

    bucket: object  # duck-typed BucketModel (repro.core.bandwidth)
    sample_bytes: int
    floor_s: float
    n_connections: int = 16

    @classmethod
    def from_models(cls, *, bucket, pipeline, sample_bytes: int, n_connections: int = 16):
        return cls(
            bucket=bucket,
            sample_bytes=sample_bytes,
            floor_s=pipeline.ram_hit_s + pipeline.cpu_overhead_s,
            n_connections=n_connections,
        )

    def round_seconds(self, size: int) -> float:
        """Modelled duration of one ``size``-key bulk fetch round."""
        return self.bucket.bulk_get_seconds(
            [self.sample_bytes] * size, self.n_connections
        )

    def deadline_size(self, pending: int, cap: int) -> int:
        """The largest round size in ``[1, cap]`` whose modelled duration
        still fits inside the loop-time the ``pending`` already-announced
        keys buy (``max(pending, 1) * floor_s``): the round completes
        before the consumer runs dry, so its first key's deadline is met
        without a cold-start stall.  Returns at least 1 — a refill point
        must announce *something*.  Deterministic integer search (doubling
        then bisection) over a pure float function, so both projections
        solve the identical size."""
        if cap <= 1:
            return 1
        budget = max(pending, 1) * self.floor_s
        if self.round_seconds(1) > budget:
            return 1
        lo, hi = 1, 2  # round_seconds(lo) is known to fit
        while hi < cap and self.round_seconds(hi) <= budget:
            lo, hi = hi, min(hi * 2, cap)
        if self.round_seconds(hi) <= budget:
            return hi
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if self.round_seconds(mid) <= budget:
                lo = mid
            else:
                hi = mid
        return lo


def _window(capacity: Optional[int], n: int) -> int:
    """The planner's look-ahead window: cache capacity, clamped to the
    epoch (``None``/``-1`` = unlimited = the whole epoch)."""
    if capacity is None or capacity < 0:
        return max(1, n)
    return max(1, min(capacity, n))


class OraclePrefetchPlanner:
    """Clairvoyant drop-in for ``PrefetchPlanner``.

    Parameters
    ----------
    order: the epoch's exact access sequence (the oracle's knowledge).
    capacity: local cache size in items (``None``/``-1`` = unlimited).
    resident: optional predicate "is this key already cached locally?",
        evaluated lazily at announce time — both projections evaluate it
        against identical cache states at identical points, so the
        filtered rounds agree exactly.

    Iteration yields ``(index, round_or_None)`` exactly like
    ``PrefetchPlanner``; a round whose keys are all resident collapses to
    ``None`` (no listing, no worker time, no Class B).
    """

    #: Flight-recorder provenance (ISSUE 10): per-rank clairvoyant rounds.
    provenance = "oracle"

    def __init__(
        self,
        order: Sequence[int],
        capacity: Optional[int] = None,
        resident: Optional[Callable[[int], bool]] = None,
        sizing: str = "ramp",
        cost_model: Optional[RoundCostModel] = None,
    ):
        if sizing not in ("ramp", "cost"):
            raise ValueError(f"unknown round sizing {sizing!r}; expected 'ramp' or 'cost'")
        if sizing == "cost" and cost_model is None:
            raise ValueError("sizing='cost' requires a RoundCostModel")
        self.order = list(order)
        self.capacity = capacity
        self.resident = resident
        self.sizing = sizing
        self.cost_model = cost_model
        self.rounds_issued = 0
        #: Keys skipped at announce time because they were already cached
        #: locally (the re-fetches the heuristic planner would have paid).
        self.resident_skips = 0

    def announce_schedule(self) -> List[Tuple[int, List[int]]]:
        """The epoch's *unfiltered* announce points as ``(consume_position,
        chunk)`` pairs, ascending in position.  The window/ramp arithmetic
        is purely positional — ``pending`` counts every announced key,
        resident or not, so skipped keys still hold their window slot and
        the schedule is precomputable.  Only the residency filter is
        stateful: it must be evaluated *at the announce point*, against the
        cache as it stands then — ``__iter__`` does so here, and the vector
        engine does so at each segment boundary (``repro.engine.vector``),
        the same cache state at the same position either way."""
        n = len(self.order)
        window = _window(self.capacity, n)
        refill_at = window // 2  # announce when pending drops to half-window
        schedule: List[Tuple[int, List[int]]] = []
        announced = 0
        consumed = 0
        size = 1  # ramp: 1, 2, 4, ... — early deadlines never stall
        while consumed < n:
            pending = announced - consumed
            if announced < n and pending <= refill_at:
                cap = min(window - pending, n - announced)
                if self.sizing == "cost":
                    take = min(self.cost_model.deadline_size(pending, cap), cap)
                else:
                    take = min(size, cap)
                chunk = self.order[announced : announced + take]
                announced += len(chunk)
                if self.sizing == "ramp" and size < window:
                    size = min(size * 2, window)
                schedule.append((consumed, chunk))
            consumed += 1
        return schedule

    def filter_chunk(self, chunk: List[int]) -> List[int]:
        """Apply the residency filter to one announced chunk (call exactly
        once per chunk, at its announce point — updates the skip counter)."""
        if self.resident is None:
            return list(chunk)
        kept = [k for k in chunk if not self.resident(k)]
        self.resident_skips += len(chunk) - len(kept)
        return kept

    def __iter__(self) -> Iterator[Tuple[int, Optional[List[int]]]]:
        rounds = {pos: chunk for pos, chunk in self.announce_schedule()}
        for consumed, idx in enumerate(self.order):
            round_: Optional[List[int]] = None
            chunk = rounds.get(consumed)
            if chunk is not None:
                chunk = self.filter_chunk(chunk)
                if chunk:
                    round_ = chunk
                    self.rounds_issued += 1
            yield idx, round_


def planner_for(
    order: Sequence[int],
    *,
    policy: str,
    config: Optional[PrefetchConfig],
    capacity: Optional[int] = None,
    resident: Optional[Callable[[int], bool]] = None,
    sizing: str = "ramp",
    cost_model: Optional[RoundCostModel] = None,
    placement=None,
    rank: int = 0,
):
    """THE epoch-planner construction, shared verbatim by both projections.

    ``policy="paper"`` builds the heuristic ``PrefetchPlanner`` from the
    fetch-size/threshold ``config``; ``policy="oracle"`` builds the
    clairvoyant planner (``config`` is ignored — the oracle has no knobs);
    ``policy="cluster-oracle"`` asks the cluster-wide ``placement``
    (:class:`repro.oracle.placement.ClusterPlacementPlanner`) for this
    rank's epoch planner — same announce schedule, plus the ownership set
    that partitions bucket fetches across the cluster.
    """
    if policy == "cluster-oracle":
        if placement is None:
            raise ValueError("policy='cluster-oracle' requires a ClusterPlacementPlanner")
        return placement.planner(
            rank,
            order,
            capacity=capacity,
            resident=resident,
            sizing=sizing,
            cost_model=cost_model,
        )
    if policy == "oracle":
        return OraclePrefetchPlanner(
            order,
            capacity=capacity,
            resident=resident,
            sizing=sizing,
            cost_model=cost_model,
        )
    if policy != "paper":
        raise ValueError(
            f"unknown prefetch policy {policy!r}; "
            "expected 'paper', 'oracle' or 'cluster-oracle'"
        )
    if sizing != "ramp":
        raise ValueError("round sizing overrides require a clairvoyant policy")
    if config is None:
        config = PrefetchConfig.disabled()
    return PrefetchPlanner(order, config)


def make_planner_factory(
    *,
    policy: str,
    config: Optional[PrefetchConfig],
    capacity: Optional[int] = None,
    resident: Optional[Callable[[int], bool]] = None,
    sizing: str = "ramp",
    cost_model: Optional[RoundCostModel] = None,
    placement=None,
    rank: int = 0,
) -> Callable[[Sequence[int]], object]:
    """Bind everything but the epoch order (``DeliLoader.planner_factory``)."""
    return lambda order: planner_for(
        order,
        policy=policy,
        config=config,
        capacity=capacity,
        resident=resident,
        sizing=sizing,
        cost_model=cost_model,
        placement=placement,
        rank=rank,
    )
