"""repro.oracle — the clairvoyant data-plane policy subsystem (ISSUE 5).

DL samplers are seeded PRNG permutations: the exact future access sequence
of every node is known before the epoch starts.  NoPFS ("Clairvoyant
Prefetching", Dryden et al.) turns that into provably better prefetching;
Belady's MIN turns it into provably optimal eviction.  This package holds
both, as policy objects the existing data plane plugs in:

  * :class:`AccessOracle` / :class:`NodeAccessView`
    (``repro.oracle.oracle``) — replay the registry samplers ahead of time
    and answer ``next_use(key)`` in O(1);
  * :class:`BeladyEviction` (``repro.oracle.eviction``) — farthest-future-
    use victim selection behind ``CappedCache``'s ``EvictionPolicy``
    protocol, composing with the replication-aware guard;
  * :class:`OraclePrefetchPlanner` / :func:`planner_for`
    (``repro.oracle.planner``) — deadline-ordered, capacity-windowed,
    residency-filtered fetch rounds replacing the paper's
    fetch-size/threshold knobs, with ramped or cost-model-solved round
    sizes (:class:`RoundCostModel`);
  * :class:`ClusterPlacementPlanner` / :class:`PlacementPrefetchPlanner`
    (``repro.oracle.placement``, ISSUE 7) — the cross-rank plan: each key
    bucket-fetched by exactly ONE owner rank ahead of its cluster-wide
    first use, everyone else served over the peer tier;
  * :class:`OracleSpillOrder` (``repro.oracle.eviction``) — farthest-
    future-use RAM→disk spill selection behind ``CappedCache``'s
    ``spill_order`` hook (FIFO spill stays the default).

Surfaced declaratively as ``DataPlaneSpec(eviction="belady",
prefetch_policy="oracle"|"cluster-oracle", round_sizing="ramp"|"cost")``
and the registry conditions ``"oracle"``, ``"oracle+peer"``,
``"oracle-cost"``, ``"cluster-oracle"``, ``"cluster-oracle+peer-capped"``
and ``"belady-only"``; quantified against the heuristics by
``benchmarks/fig12_oracle_gap.py`` and against per-rank planning by
``benchmarks/fig14_cluster_placement.py``.  Everything here is pure logic
instantiated by BOTH projections, so oracle specs stay inside the
exact-parity domain (docs/PARITY.md).

Import discipline: ``repro.oracle`` imports ``repro.core`` submodules;
``repro.core`` modules import this package only lazily (function scope),
never at module level — same rule as ``repro.distributed``.
"""
from repro.oracle.eviction import BeladyEviction, OracleSpillOrder
from repro.oracle.oracle import NEVER, AccessOracle, NodeAccessView, replayable
from repro.oracle.placement import ClusterPlacementPlanner, PlacementPrefetchPlanner
from repro.oracle.planner import (
    OraclePrefetchPlanner,
    RoundCostModel,
    make_planner_factory,
    planner_for,
)

__all__ = [
    "NEVER",
    "AccessOracle",
    "BeladyEviction",
    "ClusterPlacementPlanner",
    "NodeAccessView",
    "OraclePrefetchPlanner",
    "OracleSpillOrder",
    "PlacementPrefetchPlanner",
    "RoundCostModel",
    "make_planner_factory",
    "planner_for",
    "replayable",
]
