"""AccessOracle: the exact future access order of a seeded sampler.

DL samplers are seeded PRNG permutations — the "randomness" of an epoch's
access order is a pure function of ``(seed, epoch[, rank])``.  NoPFS
(Dryden et al., "Clairvoyant Prefetching for Distributed Machine Learning
I/O") builds its entire system on this observation: the *exact* sequence of
future accesses is known before the epoch starts, so prefetch and eviction
decisions can be provably optimal rather than heuristic.  This module is
that knowledge, reified:

  * :class:`NodeAccessView` — one rank's clairvoyant window: the current
    epoch's exact order (fed by the epoch driver — hence exact for *every*
    sampler, including the cache-view-dependent locality sampler) plus, for
    replayable samplers, the next ``horizon`` epochs' orders replayed ahead
    of time.  A consumption cursor advances sample by sample;
    ``next_use(key)`` answers "when is this key needed again?" in O(1).
  * :class:`AccessOracle` — the cluster-level factory: one view per rank,
    each wired to replay that rank's registry-built sampler.

Parity discipline (docs/PARITY.md): both projections construct their own
oracle from identically-constructed samplers and drive the views through
the same mirrored call points (``begin_epoch`` at epoch start,
``on_consume`` per sample), so every ``next_use`` answer — and therefore
every Belady eviction and every clairvoyant fetch round — is identical on
both sides.

Replayability: a sampler is replayable when its future orders are pure
functions of the epoch (``partition``, ``shared-shuffle``, and the plain
sequential/random samplers).  ``LocalityAwareSampler`` orders depend on
cluster cache state at epoch start, which does not exist yet for future
epochs — its views replay nothing and the oracle's horizon is the current
epoch only (still exact: the driver feeds the realized order).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

#: "Never used again within the oracle's horizon" — compares greater than
#: every real position, so unneeded keys are always the preferred victims.
NEVER = float("inf")


def replayable(sampler) -> bool:
    """True when ``sampler``'s future epochs can be replayed ahead of time
    (a pure function of the epoch).  Samplers whose order depends on
    runtime cluster state — the locality sampler's ``update_cache_views``
    hook is the marker — cannot be replayed without predicting that state,
    so the oracle refuses rather than replaying a wrong future."""
    return not hasattr(sampler, "update_cache_views")


class NodeAccessView:
    """One rank's exact future access sequence + consumption cursor.

    ``begin_epoch(epoch, order)`` installs the epoch's realized order (and
    appends any replayable future epochs up to the horizon); the driver
    calls ``on_consume(idx)`` once per consumed sample — at the *start* of
    the access, so a just-consumed key is immediately "in the past" and a
    demand insert of it competes on its *next* occurrence, exactly Belady's
    "don't cache what isn't needed soon" behaviour.

    ``next_use(key)`` returns the key's next position in the concatenated
    future sequence (an absolute index — only the ordering matters) or
    :data:`NEVER`.  Positions are kept as ascending per-key lists; stale
    heads (already consumed) are dropped lazily, so both queries and
    consumption are O(1) amortized.
    """

    def __init__(
        self,
        future_orders: Optional[Callable[[int], Optional[Sequence[int]]]] = None,
        horizon: int = 1,
    ):
        self._future = future_orders
        self.horizon = horizon
        self._positions: Dict[int, List[int]] = {}
        self._cursor = 0
        self.epoch = -1
        #: How many epochs beyond the current one the view could see at the
        #: last ``begin_epoch`` (0 for non-replayable samplers).
        self.lookahead_epochs = 0

    def begin_epoch(self, epoch: int, order: Sequence[int]) -> None:
        """Install the epoch's exact order; replay up to ``self.horizon``
        future epochs when the sampler allows it."""
        self.epoch = epoch
        segments: List[Sequence[int]] = [list(order)]
        self.lookahead_epochs = 0
        if self._future is not None:
            for ahead in range(1, self.horizon + 1):
                nxt = self._future(epoch + ahead)
                if nxt is None:
                    break
                segments.append(nxt)
                self.lookahead_epochs += 1
        positions: Dict[int, List[int]] = {}
        offset = 0
        for seg in segments:
            for i, key in enumerate(seg):
                positions.setdefault(key, []).append(offset + i)
            offset += len(seg)
        self._positions = positions
        self._cursor = 0

    def on_consume(self, idx: int) -> None:
        """Advance the cursor past one consumed sample (driver-mirrored on
        both projections; ``idx`` is accepted for readability/debugging —
        consumption follows the installed order by construction)."""
        self._cursor += 1

    def on_consume_many(self, n: int) -> None:
        """Advance the cursor past ``n`` consumed samples at once — the
        vector engine's segment commit.  Equivalent to ``n`` calls to
        :meth:`on_consume`: the cursor is the only state either touches."""
        self._cursor += n

    def next_use(self, idx: int) -> float:
        """Next future position of ``idx`` (>= cursor), or :data:`NEVER`."""
        positions = self._positions.get(idx)
        if not positions:
            return NEVER
        while positions and positions[0] < self._cursor:
            positions.pop(0)  # lazily discard consumed occurrences
        return positions[0] if positions else NEVER


class AccessOracle:
    """Cluster-level clairvoyance: one :class:`NodeAccessView` per rank.

    Constructed from the per-rank samplers both projections already share
    verbatim (``DataPlaneSpec.build_samplers`` / the ``samplers=`` argument
    of ``simulate_cluster``).  Replaying a future epoch temporarily moves
    the sampler's epoch and restores it — safe because every registered
    replayable sampler's ``indices()`` is a pure function of its epoch.
    """

    def __init__(self, samplers: Sequence, horizon: int = 1):
        if horizon < 0:
            raise ValueError("horizon must be >= 0")
        self.samplers = list(samplers)
        self.horizon = horizon
        self.views = [
            NodeAccessView(self._replay_fn(rank), horizon=horizon)
            for rank in range(len(self.samplers))
        ]

    def _replay_fn(self, rank: int) -> Optional[Callable[[int], Optional[List[int]]]]:
        sampler = self.samplers[rank]
        if not replayable(sampler):
            return None

        def future_order(epoch: int) -> Optional[List[int]]:
            saved = sampler.epoch
            try:
                sampler.set_epoch(epoch)
                return list(sampler.indices())
            finally:
                sampler.set_epoch(saved)

        return future_order

    def view(self, rank: int) -> NodeAccessView:
        return self.views[rank]
