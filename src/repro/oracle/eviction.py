"""Belady (farthest-future-use) eviction — the optimal offline policy.

Belady's MIN algorithm evicts the cached entry whose next use lies
farthest in the future; for a fixed access sequence it provably minimizes
misses.  DL training *has* a fixed access sequence — the seeded sampler's
permutation (see ``repro.oracle.oracle``) — so MIN is implementable, not
just a paper bound.  This module plugs it behind ``CappedCache`` through
the :class:`repro.core.cache.EvictionPolicy` protocol:

  * victim = the unguarded entry with the largest ``next_use`` (keys never
    used again within the oracle horizon sort past everything); ties break
    by FIFO insertion order, so Belady degrades *exactly* to FIFO when the
    oracle sees no future (e.g. a drained horizon) — deterministic on both
    projections;
  * the Hoard-style replication-aware ``eviction_guard`` composes: guarded
    entries are skipped, ``guard_skips`` counts the guarded entries that
    would otherwise have been evicted (farther next use than the chosen
    victim), and when *everything* is guarded the unrestricted Belady
    choice is evicted anyway — capacity always wins, mirroring
    ``FifoEviction``'s fallback.

The scan is O(cache size) per eviction with O(1) ``next_use`` lookups;
the capped caches in this repo's experiments hold sample counts, not
gigabytes, so the scan is the same order of work the guarded FIFO path
already did.

:class:`OracleSpillOrder` applies the same farthest-future-use idea one
tier down (ISSUE 7 satellite): when the RAM tier overflows its
``ram_items`` budget and payloads spill to the disk tier, spill the keys
whose next use is farthest away — the near-future keys stay in RAM and are
served at RAM-hit latency instead of paying a disk read.  FIFO spill
(oldest inserts first) remains ``CappedCache``'s default, pinned
byte-for-byte.
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Optional, Tuple

from repro.core.cache import EvictionPolicy
from repro.core.types import SampleKey
from repro.oracle.oracle import NodeAccessView


class BeladyEviction(EvictionPolicy):
    """Farthest-future-use victim selection over a :class:`NodeAccessView`.

    ``view`` may be bound after construction (``attach_view``): the cache —
    and its policy — outlive epochs, while the clairvoyant view is
    installed per epoch by the driver.  Evictions can only happen after the
    first insert, which follows the first ``begin_epoch`` on both
    projections, so the view is always bound by the time it is consulted.
    """

    name = "belady"

    def __init__(self, view: Optional[NodeAccessView] = None):
        self.view = view

    def attach_view(self, view: NodeAccessView) -> None:
        self.view = view

    def select_victim(
        self,
        entries: Iterable[SampleKey],
        guard: Optional[Callable[[int], bool]],
    ) -> Tuple[SampleKey, int]:
        if self.view is None:
            raise RuntimeError(
                "BeladyEviction has no NodeAccessView bound; the epoch "
                "driver installs one via attach_view()/begin_epoch before "
                "any insert can evict"
            )
        victim: Optional[SampleKey] = None
        victim_use = -1.0
        fallback: Optional[SampleKey] = None  # unrestricted Belady choice
        fallback_use = -1.0
        guarded_uses: List[float] = []
        for key in entries:  # FIFO order: first-seen maximum = oldest tie
            use = self.view.next_use(key.index)
            if fallback is None or use > fallback_use:
                fallback, fallback_use = key, use
            if guard is not None and guard(key.index):
                guarded_uses.append(use)
                continue
            if victim is None or use > victim_use:
                victim, victim_use = key, use
        if victim is None:
            assert fallback is not None, "select_victim on an empty cache"
            return fallback, 0  # everything guarded: capacity wins
        skips = sum(1 for use in guarded_uses if use > victim_use)
        return victim, skips


class OracleSpillOrder:
    """Farthest-future-use RAM→disk spill selection (``CappedCache``'s
    ``spill_order`` hook).

    Same attach-after-construction shape as :class:`BeladyEviction` — the
    cache outlives epochs, the clairvoyant view is installed per epoch —
    but spilling is *graceful* where eviction is not: with no view bound
    (or a drained horizon, where every ``next_use`` is :data:`NEVER`) the
    selection degrades exactly to the FIFO slice, because the sort below is
    stable and equal keys keep insertion order.
    """

    name = "oracle-spill"

    def __init__(self, view: Optional[NodeAccessView] = None):
        self.view = view

    def attach_view(self, view: NodeAccessView) -> None:
        self.view = view

    def select(self, in_ram: List[SampleKey], excess: int) -> List[SampleKey]:
        """Pick ``excess`` of the RAM-resident ``in_ram`` keys (given in
        FIFO insertion order) to spill to disk: farthest next use first,
        FIFO tie-break via sort stability; never-again keys (``NEVER`` =
        inf) spill before everything."""
        if self.view is None:
            return in_ram[:excess]
        ranked = sorted(in_ram, key=lambda k: -self.view.next_use(k.index))
        return ranked[:excess]
